#!/usr/bin/env bash
# Tier-1 verification — the exact pytest command from ROADMAP.md — plus
# dev-deps install (so the hypothesis property tests in
# tests/test_quantizers_properties.py stop self-skipping) and a benchmark
# harness smoke run.
# Usage: scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

# Best effort: offline images keep working — without hypothesis the
# property tests self-skip via pytest.importorskip.
python -m pip install -q -r requirements-dev.txt 2>/dev/null \
  || echo "warning: could not install requirements-dev.txt (offline?);" \
          "property tests will self-skip" >&2

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# Benchmark harness smoke: roofline reads dry-run artifacts (emits a
# 'missing' row and succeeds when results/dryrun is empty).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m benchmarks.run --fast --only roofline

# Adaptive-wire smoke: codec A/B rows + the loss-vs-bytes curve on the
# VLM connector boundary — asserts the entropy-sorted grouped plan
# dominates static 2-bit (<= bytes, < CE); writes results/quant_curve.json
# and BENCH_quant.json.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m benchmarks.run --fast --only quant

# Serving-engine smoke: continuous-batching engine vs static-batch
# generate on a mixed-length workload; writes BENCH_serve.json (tokens/s,
# p50/p99 per-token latency) at the repo root.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m benchmarks.serve_bench --smoke

# Split-pipeline smoke: N=4-stage dry-run on 8 fake devices (asserts the
# static per-link CommPayload wire bytes against the HLO
# collective-permute measurement, incl. a mixed 2/4-bit topology) + a
# short reduced-config training run (asserts the loss decreases across
# the quantized wire).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m repro.launch.split_pipeline --smoke

# Split-hub smoke: 3 clients + 1 server on 8 fake devices with
# heterogeneous per-client quants — per-link HLO byte assertions, the
# hub(N=1) == pipeline loss parity check, and a short async-mode
# (staleness-tolerant) training run.  Both this and the split-pipeline
# smoke above include the SplitLoRA dry-runs: adapter-only training
# with base weights bit-frozen and the quantized adapter-grad return
# wire asserted against compiled HLO.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m repro.launch.split_hub --smoke

# SplitLoRA bench smoke: full-vs-LoRA gradient-return wire bytes (per
# rank), adapter-sized optimizer moments, and async-hub full-vs-LoRA
# training rows; writes BENCH_lora.json.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m benchmarks.run --fast --only lora

# Weight-only quantization smoke: asserts the int4/g128 packed store is
# <= 0.27x the bf16 stack, the compiled server-stage ENTRY-parameter
# weight bytes drop >= 3.7x, and GPTQ held-out KL-to-dense beats RTN at
# int3 (and stays within tolerance at int4); writes BENCH_wq.json.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m benchmarks.run --fast --only wq
