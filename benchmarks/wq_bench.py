"""Weight-only serving quantization benchmark (ROADMAP item 5).

Four row families, all on the reduced tinyllava arch:

- ``wq/bytes/*``: packed weight-store bytes vs the bf16 dense stack —
  the physical PackedLinear store (codes + fp16 scale/min side info)
  across every quantized site.  Asserts the int4/g128 store is at most
  0.27x bf16 (analytic: 4/16 + 2*16/(128*16) = 0.2656).
- ``wq/hlo/*``: ENTRY-parameter bytes of the compiled server-stage
  forward (``launch.hlo_analysis.entry_parameter_bytes``), dense-bf16 vs
  packed — proves the cut survives compilation (XLA widened nothing).
  Asserts the weight-parameter bytes drop >= 3.7x.
- ``wq/fidelity/*``: held-out KL to the dense model's own distribution
  for GPTQ vs round-to-nearest at int4/int3.  The embedding table gets a
  power-law column scaling first (random-init activations are white, and
  with an isotropic Hessian GPTQ provably degenerates to RTN — trained
  feature spectra are what give error compensation its edge).  Asserts
  GPTQ beats RTN at int3.
- ``wq/speed/*``: engine tokens/s, dense vs int4 (wall time on this
  host's backend; informational — the bytes rows are the claim).

The document goes to ``BENCH_wq.json`` at the repo root.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro import wq
from repro.configs import get_config
from repro.core import split_stage as ss
from repro.data.pipeline import make_pipeline
from repro.launch.hlo_analysis import entry_parameter_bytes
from repro.models import transformer as tf
from repro.serve.engine import ServeEngine
from repro.utils.tree import weight_sites

ROOT = pathlib.Path(__file__).resolve().parent.parent

ARCH = "tinyllava"
INT4_BF16_MAX_RATIO = 0.27     # 0.53125 B/elt vs 2 B/elt = 0.2656
HLO_MIN_CUT = 3.7              # weight ENTRY-param bytes, dense-bf16/int4


def _anisotropic(params, cfg):
    """Power-law column scaling on embedding + connector outputs —
    a stand-in for the anisotropic feature spectra of trained nets."""
    d = cfg.d_model
    scale = (1.0 / jnp.sqrt(1.0 + jnp.arange(d, dtype=jnp.float32))) * 3.0

    def f(x):
        if hasattr(x, "ndim") and x.ndim >= 1 and x.shape[-1] == d:
            return x * scale
        return x

    out = dict(params)
    for k in ("embed", "connector"):
        out[k] = jax.tree_util.tree_map(f, params[k])
    return out


def _bytes_rows(params, cfg) -> Dict:
    rows = {}
    for name, group in (("int4", 128), ("int3", 128)):
        wcfg = wq.parse_weight_quant(name, group=group)
        _, report = wq.quantize_params(params, wcfg)
        elems = sum(d // 4 for d, _ in report.values())  # fp32 dense store
        bf16 = elems * 2
        packed = sum(p for _, p in report.values())
        ratio = packed / bf16
        rows[name] = dict(sites=len(report), bf16_bytes=bf16,
                          packed_bytes=packed, ratio=round(ratio, 4))
        emit(f"wq/bytes/{name}", 0.0,
             f"sites={len(report)};bf16={bf16}B;packed={packed}B;"
             f"ratio={ratio:.4f};group={group}")
    assert rows["int4"]["ratio"] <= INT4_BF16_MAX_RATIO, rows["int4"]
    return rows


def _hlo_rows(cfg) -> Dict:
    """Compiled server-stage forward: ENTRY-parameter weight bytes."""
    sp = ss.init_stage_params(jax.random.PRNGKey(0), cfg, 3,
                              per_stage=cfg.n_layers // 2)
    stage = ss.hub_programs(cfg, 2)[-1]
    packed, _ = ss.quantized_stage_blocks(sp, stage, "int4", group=128)
    dense = jax.tree_util.tree_map(
        lambda v: v[stage.index].astype(jnp.bfloat16), sp["blocks"])

    x = jnp.zeros((2, 32, cfg.d_model), jnp.bfloat16)
    pos = jnp.arange(32, dtype=jnp.int32)
    act_bytes = x.size * x.dtype.itemsize + pos.size * pos.dtype.itemsize

    def fwd(blocks, xx):
        return ss.run_blocks(cfg, blocks, xx, pos)

    def weight_param_bytes(blocks):
        hlo = jax.jit(fwd).lower(blocks, x).compile().as_text()
        return entry_parameter_bytes(hlo) - act_bytes

    bd = weight_param_bytes(dense)
    bq = weight_param_bytes(packed)
    cut = bd / bq
    emit("wq/hlo/server_stage", 0.0,
         f"dense_bf16={bd}B;int4={bq}B;cut={cut:.3f}x")
    assert cut >= HLO_MIN_CUT, (bd, bq, cut)
    return dict(dense_bf16_bytes=bd, int4_bytes=bq, cut=round(cut, 3))


def _fidelity_rows(cfg, fast: bool) -> Dict:
    params = _anisotropic(
        tf.init_params(jax.random.PRNGKey(0), cfg), cfg)
    calib = next(make_pipeline(cfg, 8 if fast else 16, 64))
    held = next(make_pipeline(cfg, 4, 48, seed=123))
    hessians = wq.collect_hessians(params, cfg, calib)
    logits_d, _ = tf.forward(params, cfg, held)
    pd = jax.nn.log_softmax(logits_d.astype(jnp.float32))

    def kl(qp) -> float:
        lq, _ = tf.forward(qp, cfg, held)
        pq = jax.nn.log_softmax(lq.astype(jnp.float32))
        return float((jnp.exp(pd) * (pd - pq)).sum(-1).mean())

    rows = {}
    for name, group in (("int4", 128), ("int3", 32)):
        wcfg = wq.parse_weight_quant(name, group=group)
        gq, _ = wq.quantize_params(params, wcfg, hessians=hessians)
        rt, _ = wq.quantize_params(params, wcfg)
        k_g, k_r = kl(gq), kl(rt)
        rows[name] = dict(group=group, gptq_kl=round(k_g, 5),
                          rtn_kl=round(k_r, 5))
        emit(f"wq/fidelity/{name}", 0.0,
             f"gptq_kl={k_g:.5f};rtn_kl={k_r:.5f};group={group};"
             f"heldout_tokens={held['tokens'].size}")
    # the coarse config is where compensation matters; int4/g128 error is
    # small enough that the two land within noise of each other
    assert rows["int3"]["gptq_kl"] < rows["int3"]["rtn_kl"], rows
    assert rows["int4"]["gptq_kl"] < 0.25, rows  # int4 held-out tolerance
    return rows


def _speed_rows(cfg, fast: bool) -> Dict:
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    b, p, n_new, pg = 4, 16, 8 if fast else 16, 8
    n_img = cfg.n_image_tokens
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size, size=(b, p)).astype(np.int32)
    imgs = rng.normal(size=(b, n_img, cfg.d_vision)).astype(np.float32)
    n_pages = 1 + b * (-(-(n_img + p + n_new) // pg))
    calib = next(make_pipeline(cfg, 4, 32))

    rows = {}
    for name, kw in (("bf16", {}),
                     ("int4", dict(weight_quant="int4", wq_calib=calib))):
        eng = ServeEngine(params, cfg, n_slots=b, page_size=pg,
                          n_pages=n_pages, **kw)
        for i in range(b):  # warmup: compile prefill + decode
            eng.submit(list(toks[i]), max_new=n_new, image_embeds=imgs[i])
        eng.run()
        t0 = time.perf_counter()
        for i in range(b):
            eng.submit(list(toks[i]), max_new=n_new, image_embeds=imgs[i])
        res = eng.run()
        dt = time.perf_counter() - t0
        n_tok = sum(len(v) for v in res.values())
        tps = n_tok / dt
        rows[name] = dict(tokens=n_tok, wall_s=round(dt, 4),
                          tokens_per_s=round(tps, 1))
        if name == "int4":
            rows[name]["weight_bytes_packed"] = \
                eng.stats["weight_bytes_packed"]
            rows[name]["weight_bytes_dense"] = \
                eng.stats["weight_bytes_dense"]
        emit(f"wq/speed/{name}", dt / max(n_tok, 1) * 1e6,
             f"tokens={n_tok};tokens_per_s={tps:.1f}")
    return rows


def run(fast: bool = False):
    cfg = get_config(ARCH).reduced()
    params = tf.init_params(jax.random.PRNGKey(1), cfg)
    doc = dict(
        arch=ARCH,
        n_sites=len(weight_sites(params["client"])) +
        len(weight_sites(params["server"])),
        bytes=_bytes_rows(params, cfg),
        hlo=_hlo_rows(cfg),
        fidelity=_fidelity_rows(cfg, fast),
        speed=_speed_rows(cfg, fast),
    )
    path = ROOT / "BENCH_wq.json"
    path.write_text(json.dumps(doc, indent=2) + "\n")
    emit("wq/doc", 0.0, f"wrote {path.name}")
    return doc


if __name__ == "__main__":
    run()
