"""Paper Figure 4/5: feature-inversion attack robustness.

Synthetic images (class-templated 32x32 patterns) pass through the stub
vision tower (fixed random patch projection) and the client connector;
the attacker trains a convolutional inversion decoder on the features it
can observe on the wire under each compression method.

Reproduced claim: validation reconstruction loss ordering
RD-FSQ > QLoRA(NF) > original  (higher loss = more private).
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.attack import train_attack
from repro.configs import get_config
from repro.core import QuantConfig, roundtrip
from repro.models import transformer as tf
from repro.models.layers.mlp import mlp_forward

IMG = 32
PATCH = 8  # -> 4x4 = 16 patches (matches reduced tinyllava)
N_CLASSES = 8
N_TRAIN, N_VAL = 512, 128


def _make_images(key, n):
    """Per-sample multi-scale random structure + a small class component.

    Reconstruction quality is then limited by *feature fidelity* (the
    paper's regime), not by memorizing class templates: the per-sample
    low/mid-frequency content must survive the quantized wire to be
    recoverable."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    templates = jax.image.resize(
        jax.random.normal(k1, (N_CLASSES, 4, 4, 1)),
        (N_CLASSES, IMG, IMG, 1), "bilinear")
    cls = jax.random.randint(k2, (n,), 0, N_CLASSES)
    coarse = jax.image.resize(
        jax.random.normal(k3, (n, 4, 4, 1)), (n, IMG, IMG, 1), "bilinear")
    mid = jax.image.resize(
        jax.random.normal(k4, (n, 8, 8, 1)), (n, IMG, IMG, 1), "bilinear")
    return jnp.tanh(1.5 * coarse + 0.8 * mid + 0.5 * templates[cls]), cls


def _patchify(imgs):
    n = imgs.shape[0]
    g = IMG // PATCH
    x = imgs.reshape(n, g, PATCH, g, PATCH, 1).transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(n, g * g, PATCH * PATCH)


def run(n_steps: int = 250):
    cfg = get_config("tinyllava").reduced()
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(42)
    k_img, k_proj, k_attack = jax.random.split(key, 3)

    imgs, _ = _make_images(k_img, N_TRAIN + N_VAL)
    patches = _patchify(imgs)  # (N, 16, 64)
    # stub vision tower: fixed random projection to d_vision
    proj = jax.random.normal(k_proj, (PATCH * PATCH, cfg.d_vision)) \
        * (PATCH * PATCH) ** -0.5
    vis = patches @ proj
    feats_clean = mlp_forward(params["connector"], vis)  # (N, 16, d_model)

    results: Dict[str, float] = {}
    for name, qcfg in [
        ("original_16bit", None),
        ("qlora_nf_2bit", QuantConfig(method="nf", bits=2)),
        ("rdfsq_2bit", QuantConfig(method="rdfsq", bits=2)),
    ]:
        feats = feats_clean if qcfg is None else roundtrip(
            qcfg, feats_clean)[0]
        t0 = time.perf_counter()
        _, history = train_attack(
            k_attack, feats[:N_TRAIN], imgs[:N_TRAIN],
            feats[N_TRAIN:], imgs[N_TRAIN:],
            grid=(4, 4), n_steps=n_steps)
        dt = time.perf_counter() - t0
        results[name] = history[-1]
        emit(f"fig4/{name}", dt / n_steps * 1e6,
             f"final_val_loss={history[-1]:.4f}")

    ordered = (results["rdfsq_2bit"] >= results["qlora_nf_2bit"] >=
               results["original_16bit"])
    emit("fig4/privacy_ordering", 0.0,
         f"rdfsq>=nf>=original={ordered}")
    return results


if __name__ == "__main__":
    run()
