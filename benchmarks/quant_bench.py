"""Wire codec A/B + the adaptive-wire rate/distortion curve.

Two suites:

1. **Codec A/B** (``quant/*`` rows): jnp oracle vs fused Pallas
   quantize+pack kernels.  The compressor runs serially on the
   split-learning wire (every microbatch crosses it before the
   collective-permute), so encode+decode latency adds directly to the
   communication-critical path.  One row per (method, bits, impl) on a
   decode-heavy boundary-activation shape; on CPU the pallas rows run
   the interpreter (correct but slow — the comparison is meaningful on
   TPU, the parity is checked everywhere).

2. **Adaptive curve** (``quant/curve*`` rows): the loss-vs-wire-bytes
   frontier of the entropy-adaptive grouped wire (ROADMAP item 3) on
   the paper's split-serve boundary — the VLM connector activations.  A
   reduced tinyllava trains briefly with an uncompressed wire, which
   leaves the connector channels strongly heterogeneous (~1.7-bit
   channel-entropy spread: the MLP maps low-rank synthetic images onto
   a few live channels).  Held-out CE (same-stream batches) is then
   measured with the connector wire quantized at: identity, static
   RD-FSQ 2/3/4 bits, and the entropy-sorted grouped plan
   (``channel_perm`` + ``group_widths`` from ``entropy.plan_grouped``)
   whose TOTAL payload bytes — codes plus the per-(sample, group) scale
   side-info the grouped wire multiplies — are budgeted at or below the
   static 2-bit payload.  Two mechanisms pay for the side-info: sorted
   grouping hands each group an entropy-homogeneous channel set, so
   the allocator's 1-bit starvations land on genuinely near-dead
   channels (whose per-group grids shrink to match — RD-FSQ scales to
   the group, so 1-bit codes there are almost free), and the per-group
   grids fit the live channels far tighter than one global grid.  The
   acceptance claim — adaptive strictly dominates static 2-bit
   (<= bytes, < loss) — is asserted here and recorded in
   ``results/quant_curve.json``; the full document (A/B rows + curve)
   goes to ``BENCH_quant.json``.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import entropy as entropy_mod
from repro.core import quantizers as Q
from repro.core.quantizers import QuantConfig

ROOT = pathlib.Path(__file__).resolve().parent.parent

SHAPE = (32, 1024, 512)  # (micro_batch, seq, d_model) boundary slab


def _codec_ab(fast: bool = False) -> List[Dict]:
    shape = (8, 256, 256) if fast else SHAPE
    x = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
    impls = ("jnp",) if (fast and jax.default_backend() != "tpu") \
        else ("jnp", "pallas")
    rows = []
    for method in ("rdfsq", "nf"):
        for bits in (2, 3, 4):
            cfg = QuantConfig(method=method, bits=bits)
            for impl in impls:
                enc = jax.jit(lambda v, c=cfg, i=impl: Q.encode(
                    c, v, impl=i).data)
                t_enc = time_fn(enc, x, iters=3, warmup=1)
                payload = Q.encode(cfg, x, impl=impl)
                dec = jax.jit(lambda p, c=cfg: Q.decode(c, p))
                t_dec = time_fn(dec, payload, iters=3, warmup=1)
                emit(f"quant/{method}{bits}_encode_{impl}", t_enc,
                     f"wire={payload.wire_bytes()}B")
                emit(f"quant/{method}{bits}_decode_{impl}", t_dec,
                     f"impl={payload.meta['impl']}")
                rows.append(dict(method=method, bits=bits, impl=impl,
                                 encode_us=t_enc, decode_us=t_dec,
                                 wire_bytes=payload.wire_bytes()))
    return rows


# ---------------------------------------------------------------------------
# the adaptive-wire rate/distortion curve
# ---------------------------------------------------------------------------

_N_GROUPS = 32


def _payload_bytes(q: QuantConfig, sds) -> int:
    """Static total wire bytes (codes + side-info) of one activation."""
    from functools import partial

    return jax.eval_shape(partial(Q.encode, q),
                          jax.ShapeDtypeStruct(sds.shape,
                                               sds.dtype)).wire_bytes()


def _curve(fast: bool = False) -> Dict:
    from repro.configs import get_config
    from repro.data.pipeline import make_pipeline
    from repro.models import transformer as tf
    from repro.models.layers.mlp import mlp_forward
    from repro.optim import AdamWConfig, init_opt_state
    from repro.train.loop import TrainState, apply_gradients
    from repro.train.losses import cross_entropy

    cfg = get_config("tinyllava").reduced()
    batch, seq = (8, 32)
    n_train = 120  # fast == full: the assertion below runs in CI
    n_eval = 8 if fast else 16
    dtype = tf.cdtype(cfg)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)

    def vlm_loss(p, b, wire_q: Optional[QuantConfig]):
        """Full VLM forward with the connector wire quantized in-graph."""
        b = dict(b)
        feats = mlp_forward(p["connector"],
                            b.pop("image_embeds").astype(dtype))
        if wire_q is not None:
            if wire_q.scale_dq:
                # the STE roundtrip keeps exact fp16 scales; the dq'd
                # wire must be measured through the real encode/decode
                # pair so the CE pays for the 8-bit scale codes it ships
                feats = Q.decode(wire_q, Q.encode(wire_q, feats))
            else:
                f_hat, _ = Q.roundtrip(wire_q, feats)
                feats = f_hat
        b["image_features"] = feats.astype(dtype)
        logits, _ = tf.forward(p, cfg, b, rng=None)
        return cross_entropy(logits, b["labels"])

    # -- train with the uncompressed wire; the connector channels come
    #    out strongly heterogeneous (the signal the allocator exploits)
    opt_cfg = AdamWConfig(lr=5e-3, weight_decay=0.0)
    state = TrainState(params=params, opt=init_opt_state(params, opt_cfg),
                       step=jnp.zeros((), jnp.int32))
    grad_fn = jax.jit(jax.value_and_grad(lambda p, b: vlm_loss(p, b, None)))
    pipe = make_pipeline(cfg, batch, seq, seed=0)
    for _ in range(n_train):
        b = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        _, grads = grad_fn(state.params, b)
        state, _ = apply_gradients(state, grads, opt_cfg)

    # -- per-channel entropy signal from the trained connector wire
    probe = jax.jit(lambda p, img: mlp_forward(p["connector"],
                                               img.astype(dtype)))
    ema = entropy_mod.init_entropy_ema(cfg.d_model)
    for _ in range(4):
        b = next(pipe)
        ema = entropy_mod.update_entropy_ema(
            ema, probe(state.params, jnp.asarray(b["image_embeds"])))
    ent = entropy_mod.entropy_ema_bits(ema)

    # -- entropy-sorted grouped plan whose TOTAL bytes sit at the static
    #    2-bit payload: code budget = static total - grouped side-info
    #    (width-independent, so it cancels out of plan comparisons)
    n_img = cfg.n_image_tokens
    f_sds = jax.ShapeDtypeStruct((batch, n_img, cfg.d_model), dtype)
    static2 = QuantConfig(method="rdfsq", bits=2)
    static2_bytes = _payload_bytes(static2, f_sds)
    floor = dataclasses.replace(static2, group_widths=(1,) * _N_GROUPS)
    code_1bit = batch * n_img * cfg.d_model * 1 // 8
    side_bytes = _payload_bytes(floor, f_sds) - code_1bit
    perm, plan = entropy_mod.plan_grouped(
        ent, static2_bytes - side_bytes,
        group_size=cfg.d_model // _N_GROUPS,
        scalars_per_channel=batch * n_img)
    adaptive = dataclasses.replace(static2, group_widths=plan,
                                   channel_perm=perm)
    # double-quantized scale side-info: 8-bit scale codes against one
    # per-payload fp16 range halve the side bytes, and the freed budget
    # goes back to the allocator as code bits
    side_dq = (_payload_bytes(dataclasses.replace(floor, scale_dq=True),
                              f_sds) - code_1bit)
    assert side_dq < side_bytes, (side_dq, side_bytes)
    perm_dq, plan_dq = entropy_mod.plan_grouped(
        ent, static2_bytes - side_dq,
        group_size=cfg.d_model // _N_GROUPS,
        scalars_per_channel=batch * n_img)
    adaptive_dq = dataclasses.replace(static2, group_widths=plan_dq,
                                      channel_perm=perm_dq, scale_dq=True)

    # -- held-out CE per wire config: same-stream batches (the synthetic
    #    task is seed-specific, so a different seed would be OOD), same
    #    batches for every point
    eval_batches = [{k: jnp.asarray(v) for k, v in next(pipe).items()}
                    for _ in range(n_eval)]
    points = {}
    settings: List[Tuple[str, Optional[QuantConfig]]] = [
        ("identity-16bit", None),
        ("static-2bit", static2),
        ("static-3bit", dataclasses.replace(static2, bits=3)),
        ("static-4bit", dataclasses.replace(static2, bits=4)),
        ("adaptive-grouped", adaptive),
        ("adaptive-dq-scales", adaptive_dq),
    ]
    for name, wq in settings:
        loss_fn = jax.jit(lambda p, b, w=wq: vlm_loss(p, b, w))
        ces = [float(loss_fn(state.params, b)) for b in eval_batches]
        wire_bytes = (int(np.prod(f_sds.shape)) * 2 if wq is None
                      else _payload_bytes(wq, f_sds))
        points[name] = dict(eval_ce=float(np.mean(ces)),
                            wire_bytes=wire_bytes,
                            widths=list(wq.group_widths) if wq else [],
                            bits=None if wq is None else wq.mean_bits())
        emit(f"quant/curve/{name}", 0.0,
             f"eval_ce={points[name]['eval_ce']:.4f};"
             f"wire_bytes={wire_bytes}")

    st = points["static-2bit"]
    for pname in ("adaptive-grouped", "adaptive-dq-scales"):
        ad = points[pname]
        print(f"quant/curve {pname} plan {ad['widths']}: "
              f"{ad['wire_bytes']}B ce={ad['eval_ce']:.4f} vs static-2bit "
              f"{st['wire_bytes']}B ce={st['eval_ce']:.4f}")
        assert ad["wire_bytes"] <= st["wire_bytes"], (
            f"{pname} plan exceeds the static 2-bit byte budget: "
            f"{ad['wire_bytes']} > {st['wire_bytes']}")
        assert ad["eval_ce"] < st["eval_ce"], (
            f"{pname} plan does not beat static 2-bit CE: "
            f"{ad['eval_ce']} >= {st['eval_ce']}")

    curve = dict(config="tinyllava.reduced", batch=batch, seq=seq,
                 boundary="connector (split-serve wire)",
                 n_train_steps=n_train, n_eval_batches=n_eval,
                 n_groups=_N_GROUPS, plan=list(plan),
                 channel_perm=list(perm), plan_dq=list(plan_dq),
                 side_bytes=int(side_bytes), side_bytes_dq=int(side_dq),
                 entropy_bits=[round(float(v), 4) for v in np.asarray(ent)],
                 points=points)
    results_dir = ROOT / "results"
    results_dir.mkdir(exist_ok=True)
    (results_dir / "quant_curve.json").write_text(
        json.dumps(curve, indent=1) + "\n")
    print(f"wrote {results_dir / 'quant_curve.json'}")
    return curve


def run(fast: bool = False):
    rows = _codec_ab(fast)
    curve = _curve(fast)
    doc = dict(backend=jax.default_backend(), smoke=fast,
               codec_ab=rows, curve=curve)
    path = ROOT / "BENCH_quant.json"
    path.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {path}")
    return doc
