"""Wire codec A/B: jnp oracle vs fused Pallas quantize+pack kernels.

The compressor runs serially on the split-learning wire (every microbatch
crosses it before the collective-permute), so encode+decode latency adds
directly to the communication-critical path.  One row per
(method, bits, impl) on a decode-heavy boundary-activation shape; on CPU
the pallas rows run the interpreter (correct but slow — the comparison is
meaningful on TPU, the parity is checked everywhere).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import quantizers as Q
from repro.core.quantizers import QuantConfig

SHAPE = (32, 1024, 512)  # (micro_batch, seq, d_model) boundary slab


def run(fast: bool = False):
    shape = (8, 256, 256) if fast else SHAPE
    x = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
    impls = ("jnp",) if (fast and jax.default_backend() != "tpu") \
        else ("jnp", "pallas")
    for method in ("rdfsq", "nf"):
        for bits in (2, 4):
            cfg = QuantConfig(method=method, bits=bits)
            for impl in impls:
                enc = jax.jit(lambda v, c=cfg, i=impl: Q.encode(
                    c, v, impl=i).data)
                t_enc = time_fn(enc, x, iters=3, warmup=1)
                payload = Q.encode(cfg, x, impl=impl)
                dec = jax.jit(lambda p, c=cfg: Q.decode(c, p))
                t_dec = time_fn(dec, payload, iters=3, warmup=1)
                emit(f"quant/{method}{bits}_encode_{impl}", t_enc,
                     f"wire={payload.wire_bytes()}B")
                emit(f"quant/{method}{bits}_decode_{impl}", t_dec,
                     f"impl={payload.meta['impl']}")
