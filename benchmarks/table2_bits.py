"""Paper Table 2: average transmitted bits per scalar, per method.

Analytic closed forms (the paper's table) AND measured wire bytes from the
actual bit-packed CommPayload, which additionally expose each method's
side-info overhead (block minima / double-quantized scales for NF,
indices for Top-K).
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.core import (QuantConfig, analytic_bits_per_scalar,
                        bits_per_scalar, encode)


def run():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 128, 1280))
    h = x.size // x.shape[0]
    rng = jax.random.PRNGKey(1)
    out = {}
    for method in ("fsq", "rdfsq", "nf", "topk", "identity"):
        bit_list = (16,) if method == "identity" else (1, 2, 3, 4)
        for bits in bit_list:
            cfg = QuantConfig(method=method, bits=min(bits, 8))
            t_us = time_fn(lambda: encode(cfg, x, rng), iters=3, warmup=1)
            payload = encode(cfg, x, rng)
            measured = bits_per_scalar(payload, x.size)
            analytic = analytic_bits_per_scalar(cfg, h)
            out[(method, bits)] = (analytic, measured)
            emit(f"table2/{method}_{bits}bit", t_us,
                 f"analytic={analytic:.3f};measured={measured:.3f};"
                 f"wire_bytes={payload.wire_bytes()}")
    return out


if __name__ == "__main__":
    run()
