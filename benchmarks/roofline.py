"""Deliverable (g): roofline table from the dry-run artifacts.

Reads results/dryrun/*.json (produced by repro.launch.dryrun) and prints,
per (arch x shape x mesh): the three roofline terms, the dominant
bottleneck, peak memory, and the useful-FLOPs ratio.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def load_results():
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def _next_lever(r) -> str:
    """One sentence: what would move the dominant term down (SSRoofline)."""
    dom = r["roofline"]["dominant"]
    kind = r["kind"]
    arch = r["arch"]
    moe = arch in ("deepseek_v2_236b", "arctic_480b")
    if dom == "collective":
        if kind == "train":
            return ("fewer FSDP weight regathers (larger microbatches or "
                    "gather-once-per-step weight caching)" if not moe else
                    "manual shard_map expert-parallel all-to-all instead of "
                    "GSPMD weight gathers")
        return "co-locate cache and projection shardings (SSPerf B-style)"
    if dom == "memory":
        if kind == "train":
            return ("sequence/context parallelism to shard activations "
                    "beyond batch, or deeper remat grouping")
        if kind == "decode":
            return ("KV-cache quantization (the paper's own technique "
                    "applied to the cache) to cut cache-read bytes")
        return "larger attention chunks to raise arithmetic intensity"
    return "already compute-bound: larger per-chip batch or int8 matmuls"


def weight_bytes_rows():
    """Analytic per-precision serve-time weight HBM bytes per decode tick.

    The ``repro.wq`` packed stores shrink exactly this stream; rows give
    the dense-bf16 baseline and the int4/int3 (group 128) packed bytes +
    cut ratios per arch, from the same ``param_counts`` the roofline
    memory term uses.  Independent of dry-run artifacts.
    """
    from repro.configs import get_config
    from repro.launch.roofline import decode_weight_bytes

    table = {}
    for arch in ("tinyllava", "llama3_2_3b", "granite_3_8b"):
        cfg = get_config(arch)
        dense = decode_weight_bytes(cfg, bits=16)
        row = {"bf16": dense}
        for bits in (4, 3):
            packed = decode_weight_bytes(cfg, bits=bits, group=128)
            row[f"int{bits}"] = packed
            row[f"int{bits}_ratio"] = dense / packed
        table[arch] = row
        emit(f"roofline/weight_bytes/{arch}", dense / 2 ** 20,
             f"bf16_MiB={dense / 2**20:.1f};"
             f"int4_MiB={row['int4'] / 2**20:.1f};"
             f"int4_cut={row['int4_ratio']:.2f}x;"
             f"int3_MiB={row['int3'] / 2**20:.1f};"
             f"int3_cut={row['int3_ratio']:.2f}x;group=128")
    return table


def run():
    wb = weight_bytes_rows()
    results = load_results()
    if not results:
        emit("roofline/missing", 0.0,
             "no dry-run artifacts; run python -m repro.launch.dryrun --all")
        return {"weight_bytes": wb}
    table = {"weight_bytes": wb}
    for r in results:
        rl = r["roofline"]
        mem_gib = r["memory"]["peak_adjusted_per_device"] / 2 ** 30
        key = f"{r['arch']}__{r['shape']}__{r['mesh']}"
        table[key] = rl
        emit(f"roofline/{key}", rl["step_lower_bound_s"] * 1e6,
             f"compute_s={rl['compute_s']:.4f};memory_s={rl['memory_s']:.4f};"
             f"collective_s={rl['collective_s']:.4f};"
             f"dominant={rl['dominant']};"
             f"useful={rl['useful_flops_ratio']:.3f};"
             f"peak_GiB={mem_gib:.2f};"
             f"next_lever={_next_lever(r)}")
    doms = {}
    for r in results:
        doms[r["roofline"]["dominant"]] = \
            doms.get(r["roofline"]["dominant"], 0) + 1
    emit("roofline/summary", 0.0,
         ";".join(f"{k}={v}" for k, v in sorted(doms.items())) +
         f";combos={len(results)}")
    return table


if __name__ == "__main__":
    run()
