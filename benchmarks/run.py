"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).

    PYTHONPATH=src python -m benchmarks.run [--only table2,roofline]
    PYTHONPATH=src python -m benchmarks.run --fast   # smaller train budgets
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (attention_bench, fig4_attack, lora_bench,
                        quant_bench, roofline, serve_bench, table1_entropy,
                        table2_bits, table3_performance, table4_comm,
                        wq_bench)

SUITES = {
    "table1": lambda fast: table1_entropy.run(),
    "table2": lambda fast: table2_bits.run(),
    "table3": lambda fast: table3_performance.run(
        n_steps=30 if fast else 120),
    "table4": lambda fast: table4_comm.run(),
    "fig4": lambda fast: fig4_attack.run(n_steps=60 if fast else 250),
    "roofline": lambda fast: roofline.run(),
    "attention": lambda fast: attention_bench.run(fast=fast),
    "quant": lambda fast: quant_bench.run(fast=fast),
    "lora": lambda fast: lora_bench.run(fast=fast),
    "serve": lambda fast: serve_bench.run(fast=fast),
    "wq": lambda fast: wq_bench.run(fast=fast),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    names = list(SUITES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        try:
            SUITES[name](args.fast)
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"benchmark failures: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
