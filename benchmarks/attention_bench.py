"""Attention kernel A/B: jnp reference vs Pallas, as printed numbers.

Emits ``attention/<case>/<impl>`` rows (us_per_call) plus a
``attention/<case>/speedup`` summary row per case, for:

* ``flash_fwd``   — train/prefill forward (GQA, causal)
* ``flash_grad``  — forward + backward through the custom VJP
* ``flash_window``— sliding-window forward (block-skip path)
* ``decode``      — single-token bf16-cache decode
* ``decode_q8``   — single-token int8-cache decode (fused scales)

On TPU the Pallas rows are the fused kernels; elsewhere they run in
interpret mode (correctness A/B, not a fair timing — the row says so).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.attention_ops import _interpret
from repro.models.layers.attention import (decode_attention,
                                           decode_attention_q8,
                                           flash_attention,
                                           quantize_kv_token)

IMPLS = ("jnp", "pallas")


def _flash_args(s, h, kh, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, s, h, d))
    k = jax.random.normal(ks[1], (1, s, kh, d))
    v = jax.random.normal(ks[2], (1, s, kh, d))
    return q, k, v


def _decode_args(b, length, kh, g, d, seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, 1, kh * g, d))
    k_cache = jax.random.normal(ks[1], (b, length, kh, d))
    v_cache = jax.random.normal(ks[2], (b, length, kh, d))
    kpos = jnp.broadcast_to(jnp.arange(length, dtype=jnp.int32),
                            (b, length))
    qpos = jnp.full((b,), length - 1, jnp.int32)
    return q, k_cache, v_cache, kpos, qpos


def _ab(case, fns, args, iters):
    """Time both impls on identical args; emit per-impl + speedup rows."""
    us = {}
    for impl in IMPLS:
        us[impl] = time_fn(fns[impl], *args, iters=iters)
        emit(f"attention/{case}/{impl}", us[impl],
             f"impl={impl};interpret={_interpret()}")
    emit(f"attention/{case}/speedup", 0.0,
         f"pallas_vs_jnp={us['jnp'] / max(us['pallas'], 1e-9):.3f}x")


def run(fast: bool = False):
    s = 256 if fast else 512
    chunk = 128
    iters = 3 if fast else 5
    h, kh, d = 8, 2, 64
    q, k, v = _flash_args(s, h, kh, d)

    def flash(impl, window=None):
        return jax.jit(functools.partial(
            flash_attention, window=window, q_chunk=chunk, kv_chunk=chunk,
            impl=impl))

    _ab("flash_fwd", {i: flash(i) for i in IMPLS}, (q, k, v), iters)
    _ab("flash_window", {i: flash(i, window=chunk) for i in IMPLS},
        (q, k, v), iters)

    def grad(impl):
        fn = flash(impl)
        return jax.jit(jax.grad(
            lambda q, k, v: (fn(q, k, v) ** 2).sum(), argnums=(0, 1, 2)))

    _ab("flash_grad", {i: grad(i) for i in IMPLS}, (q, k, v), iters)

    length = 512 if fast else 2048
    dq, kc, vc, kpos, qpos = _decode_args(4, length, kh, 4, d)

    def dec(impl):
        return jax.jit(functools.partial(decode_attention, impl=impl))

    _ab("decode", {i: dec(i) for i in IMPLS}, (dq, kc, vc, kpos, qpos),
        iters)

    k_codes, k_scale = quantize_kv_token(kc)
    v_codes, v_scale = quantize_kv_token(vc)

    def dec8(impl):
        return jax.jit(functools.partial(decode_attention_q8, impl=impl))

    _ab("decode_q8", {i: dec8(i) for i in IMPLS},
        (dq, k_codes, v_codes, k_scale, v_scale, kpos, qpos), iters)
    return {}


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(fast=True)
