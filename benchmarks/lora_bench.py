"""SplitLoRA full-vs-LoRA split fine-tuning benchmark (ROADMAP item 4).

Three row families, all on the N-client hub of the reduced llama3 arch:

- ``lora/wire/*``: static gradient-return bytes per optimizer step —
  shipping one stage's FULL param-grads through the hub's 8-bit grad
  codec vs the SplitLoRA adapter-grad payload at ranks 2/4/8 (the same
  accounting ``assert_links_match_hlo`` verifies against compiled HLO in
  the dry-runs/tests, so these numbers are HLO-backed, not estimates).
- ``lora/opt/*``: AdamW moment bytes — full parameter moments vs the
  adapter-only optimizer state.
- ``lora/train/*``: the async hub (mesh-free in-graph wire, runs on one
  host device) trained full vs ``lora_rank=4`` on identical tick
  streams; rows carry head/tail windowed loss means and wall time per
  tick.  LoRA starts at the base model (B = 0) and must still learn.

The document — per-rank wire table + opt sizes + both loss histories —
goes to ``BENCH_lora.json`` (the README's wire-bytes table reads it).
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.quantizers import QuantConfig
from repro.core.split import HubConfig, tree_payload_bytes
from repro.data.pipeline import make_pipeline
from repro.launch.split_hub import (hub_wire_bytes, init_hub_params,
                                    train_hub)
from repro.optim import AdamWConfig, init_opt_state, param_bytes
from repro.peft import adapter_bytes

ROOT = pathlib.Path(__file__).resolve().parent.parent

ARCH = "llama3_2_3b"
RANKS = (2, 4, 8)


def _wire_table(cfg, hub: HubConfig, mb: int, seq: int) -> Dict:
    """Per-rank gradient-return bytes: full param-grads vs adapter-grads
    through the same grad codec (one stage slice, up + back per step)."""
    full_sds = jax.eval_shape(
        lambda: init_hub_params(jax.random.PRNGKey(0), cfg, hub))
    stage = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
        full_sds["blocks"])
    full_b = tree_payload_bytes(hub.grad_quant, stage)
    rows = {}
    for rank in RANKS:
        wire = hub_wire_bytes(cfg, hub, mb, seq, lora_rank=rank)
        grads = {f"{src}->{dst}": v["grad"]
                 for (src, dst), v in wire["links"].items()}
        ad_b = next(iter(grads.values()))
        rows[rank] = dict(adapter_grad_bytes=ad_b,
                          full_grad_bytes=full_b,
                          reduction=round(full_b / max(ad_b, 1), 1),
                          per_link=grads)
        emit(f"lora/wire/r{rank}", 0.0,
             f"adapter_grad={ad_b}B;full_grad={full_b}B;"
             f"reduction={rows[rank]['reduction']}x")
    return rows


def _opt_table(cfg, hub: HubConfig, opt_cfg: AdamWConfig,
               rank: int) -> Dict:
    params = init_hub_params(jax.random.PRNGKey(0), cfg, hub,
                             lora_rank=rank)
    base = {k: v for k, v in params.items() if k != "adapters"}
    full_m = 2 * param_bytes(init_opt_state(base, opt_cfg)["m"])
    ad_m = 2 * adapter_bytes(params["adapters"])  # m + v moments
    emit(f"lora/opt/r{rank}", 0.0,
         f"full_moments={full_m}B;adapter_moments={ad_m}B;"
         f"reduction={full_m / max(ad_m, 1):.1f}x")
    return dict(full_moment_bytes=full_m, adapter_moment_bytes=ad_m,
                reduction=round(full_m / max(ad_m, 1), 1))


def _train_rows(cfg, hub: HubConfig, opt_cfg: AdamWConfig, mb: int,
                seq: int, n_ticks: int, rank: int) -> Dict:
    n = hub.n_clients
    out = {}
    for name, r in (("full", 0), (f"lora-r{rank}", rank)):
        pipe = make_pipeline(cfg, n * mb, seq, seed=0)

        def batches():
            while True:
                b = next(pipe)
                yield (b["tokens"].reshape(n, mb, seq),
                       b["labels"].reshape(n, mb, seq))

        t0 = time.perf_counter()
        res = train_hub(cfg, hub, opt_cfg, batches(), micro_batch=mb,
                        seq=seq, mode="async", n_ticks=n_ticks,
                        lora_rank=r)
        dt_us = (time.perf_counter() - t0) / n_ticks * 1e6
        hist = res["history"]
        k = max(3, n_ticks // 6)
        head, tail = float(np.mean(hist[:k])), float(np.mean(hist[-k:]))
        assert tail < head, f"{name} hub loss did not decrease: {hist}"
        emit(f"lora/train/{name}", dt_us,
             f"head_ce={head:.4f};tail_ce={tail:.4f};ticks={n_ticks}")
        out[name] = dict(loss_history=[round(v, 4) for v in hist],
                         head_mean=round(head, 4),
                         tail_mean=round(tail, 4), us_per_tick=dt_us)
    return out


def run(fast: bool = False):
    cfg = get_config(ARCH).reduced()
    n_clients, mb, seq = 3, 4, 32
    rank = 4
    hub = HubConfig(n_clients=n_clients,
                    quant=QuantConfig(method="rdfsq", bits=2),
                    grad_quant=QuantConfig(method="rdfsq", bits=8,
                                           stats_axis="tensor"),
                    tick_rates=(1,) * n_clients)
    opt_cfg = AdamWConfig(lr=3e-2, weight_decay=0.0)
    doc = dict(backend=jax.default_backend(), smoke=fast, arch=ARCH,
               n_clients=n_clients, micro_batch=mb, seq=seq,
               grad_codec="rdfsq-8bit-tensor",
               wire=_wire_table(cfg, hub, mb, seq),
               opt=_opt_table(cfg, hub, opt_cfg, rank),
               train=_train_rows(cfg, hub, opt_cfg, mb, seq,
                                 12 if fast else 24, rank))
    path = ROOT / "BENCH_lora.json"
    path.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {path}")
    return doc


if __name__ == "__main__":
    run()
