"""Paper Table 1 + Appendix A: KDE entropy of boundary activations.

Estimates H(X) of the client->server boundary activations across 8 batches
of the tinyllava model and derives the optimal bit width via Shannon's
source coding theorem.  Paper values: ~1.80-1.84 bits -> 2-bit optimal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs import get_config
from repro.core.entropy import differential_entropy_bits, optimal_bits
from repro.core.split import client_encode_pre
from repro.data.pipeline import make_pipeline
from repro.models import transformer as tf
from repro.models.layers.mlp import mlp_forward


def boundary_activations(cfg, params, batch):
    """Client-side features right before the quantizer (cut after
    connector for the paper's model)."""
    img = mlp_forward(params["connector"],
                      batch["image_embeds"].astype(jnp.float32))
    h = client_encode_pre(params.get("codec"), cfg.split, img)
    return h


def run(n_batches: int = 8, seed: int = 0):
    cfg = get_config("tinyllava").reduced()
    params = tf.init_params(jax.random.PRNGKey(seed), cfg)
    pipe = make_pipeline(cfg, batch_size=8, seq_len=32, seed=seed)
    ents = []
    t_us = None
    for i in range(n_batches):
        batch = next(pipe)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        h = boundary_activations(cfg, params, batch)
        if t_us is None:
            t_us = time_fn(
                lambda hh: differential_entropy_bits(hh)[0] * jnp.ones(()),
                h, iters=3, warmup=1)
        ent, _ = differential_entropy_bits(h, seed=i)
        ents.append(ent)
        emit(f"table1/entropy_batch{i + 1}", t_us, f"H={ent:.4f}bits")
    mean_ent = sum(ents) / len(ents)
    bits = optimal_bits(mean_ent)
    spread = max(ents) - min(ents)
    emit("table1/optimal_bits", t_us,
         f"mean_H={mean_ent:.4f};spread={spread:.4f};optimal_bits={bits}")
    return dict(entropies=ents, optimal_bits=bits)


if __name__ == "__main__":
    run()
