"""Paper Table 4: communication cost under a realistic split deployment.

For each method x bit-width we measure, per forward-pass transmission of
the tinyllava boundary activations:

  * transmitted bytes (the bit-packed CommPayload — ground truth),
  * serialization + deserialization wall time (pickle, as in the paper),
  * simulated wire time on a 1 Gbit/s client<->server link (the paper's
    two-device LAN regime) and on a 50 GB/s TPU ICI link (our target).

Reported per 100 batches to match the paper's units.
"""
from __future__ import annotations

import pickle
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import QuantConfig, SplitConfig, wire_payload
from repro.data.pipeline import make_pipeline
from repro.models import transformer as tf
from repro.models.layers.mlp import mlp_forward

LAN_BPS = 1e9 / 8  # 1 Gbit/s in bytes/s
ICI_BPS = 50e9

BATCHES = 20
SCALE = 100 / BATCHES  # report per 100 batches


def run():
    cfg = get_config("tinyllava").reduced()
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    pipe = make_pipeline(cfg, batch_size=8, seq_len=32, seed=0)
    batches = [next(pipe) for _ in range(BATCHES)]
    feats = []
    for b in batches:
        img = mlp_forward(params["connector"],
                          jnp.asarray(b["image_embeds"], jnp.float32))
        feats.append(img)

    rows = {}
    for method, bits in [("identity", 16), ("rdfsq", 2), ("nf", 2),
                         ("rdfsq", 3), ("nf", 3), ("rdfsq", 4), ("nf", 4)]:
        split = SplitConfig(quant=QuantConfig(method=method, bits=bits),
                            learnable_codec=False)
        total_bytes = 0
        ser_time = 0.0
        for h in feats:
            payload = wire_payload(split, None, h)
            arrays = [np.asarray(a) for a in payload.arrays()]
            t0 = time.perf_counter()
            blob = pickle.dumps(arrays, protocol=4)
            _ = pickle.loads(blob)
            ser_time += time.perf_counter() - t0
            total_bytes += payload.wire_bytes()
        lan_s = total_bytes / LAN_BPS
        ici_s = total_bytes / ICI_BPS
        comm_time_lan = (ser_time + lan_s) * SCALE
        name = "original" if method == "identity" else method
        rows[(method, bits)] = dict(mb=total_bytes * SCALE / 2 ** 20,
                                    time_lan=comm_time_lan)
        emit(f"table4/{name}_{bits}bit",
             ser_time / BATCHES * 1e6,
             f"amount_MB_per100={total_bytes * SCALE / 2 ** 20:.2f};"
             f"time_s_per100_LAN={comm_time_lan:.4f};"
             f"time_s_per100_ICI={(ser_time + ici_s) * SCALE:.4f}")

    base = rows[("identity", 16)]["mb"]
    red = 1 - rows[("rdfsq", 2)]["mb"] / base
    emit("table4/reduction_2bit_vs_16bit", 0.0,
         f"byte_reduction={red:.4f};paper_claims=0.875")
    return rows


if __name__ == "__main__":
    run()
