"""Paper Table 4: communication cost under a realistic split deployment.

For each method x bit-width we measure, per forward-pass transmission of
the tinyllava boundary activations:

  * transmitted bytes (the bit-packed CommPayload — ground truth),
  * serialization + deserialization wall time (pickle, as in the paper),
  * simulated wire time on a 1 Gbit/s client<->server link (the paper's
    two-device LAN regime) and on a 50 GB/s TPU ICI link (our target).

Reported per 100 batches to match the paper's units.

BEYOND-PAPER: ``run`` additionally scales the many-client hub
(``launch/split_hub``): per-link wire traffic for N clients sharing one
server, heterogeneous 2-bit/4-bit compressors, written to
``results/table4_hub_links.json``.
"""
from __future__ import annotations

import json
import os
import pickle
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import HubConfig, QuantConfig, SplitConfig, wire_payload
from repro.data.pipeline import make_pipeline
from repro.launch import schedules
from repro.models import transformer as tf
from repro.models.layers.mlp import mlp_forward

LAN_BPS = 1e9 / 8  # 1 Gbit/s in bytes/s
ICI_BPS = 50e9

BATCHES = 20
SCALE = 100 / BATCHES  # report per 100 batches


def run():
    cfg = get_config("tinyllava").reduced()
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    pipe = make_pipeline(cfg, batch_size=8, seq_len=32, seed=0)
    batches = [next(pipe) for _ in range(BATCHES)]
    feats = []
    for b in batches:
        img = mlp_forward(params["connector"],
                          jnp.asarray(b["image_embeds"], jnp.float32))
        feats.append(img)

    rows = {}
    for method, bits in [("identity", 16), ("rdfsq", 2), ("nf", 2),
                         ("rdfsq", 3), ("nf", 3), ("rdfsq", 4), ("nf", 4)]:
        split = SplitConfig(quant=QuantConfig(method=method, bits=bits),
                            learnable_codec=False)
        total_bytes = 0
        ser_time = 0.0
        for h in feats:
            payload = wire_payload(split, None, h)
            arrays = [np.asarray(a) for a in payload.arrays()]
            t0 = time.perf_counter()
            blob = pickle.dumps(arrays, protocol=4)
            _ = pickle.loads(blob)
            ser_time += time.perf_counter() - t0
            total_bytes += payload.wire_bytes()
        lan_s = total_bytes / LAN_BPS
        ici_s = total_bytes / ICI_BPS
        comm_time_lan = (ser_time + lan_s) * SCALE
        name = "original" if method == "identity" else method
        rows[(method, bits)] = dict(mb=total_bytes * SCALE / 2 ** 20,
                                    time_lan=comm_time_lan)
        emit(f"table4/{name}_{bits}bit",
             ser_time / BATCHES * 1e6,
             f"amount_MB_per100={total_bytes * SCALE / 2 ** 20:.2f};"
             f"time_s_per100_LAN={comm_time_lan:.4f};"
             f"time_s_per100_ICI={(ser_time + ici_s) * SCALE:.4f}")

    base = rows[("identity", 16)]["mb"]
    red = 1 - rows[("rdfsq", 2)]["mb"] / base
    emit("table4/reduction_2bit_vs_16bit", 0.0,
         f"byte_reduction={red:.4f};paper_claims=0.875")
    rows["hub"] = run_hub(cfg)
    return rows


def run_hub(cfg, micro_batch: int = 8, seq: int = 32,
            clients_list=(1, 2, 4, 8)) -> dict:
    """Per-link hub wire traffic vs number of clients.

    Static CommPayload accounting over the star topology: each client's
    link carries its own compressor's payload (alternating 2-bit RD-FSQ /
    4-bit NF), so total server ingress grows with the MIX of clients, not
    just their count.  The dry-run in ``launch/split_hub`` asserts this
    same table against the lowered HLO; here we tabulate its scaling.
    """
    out = {}
    for n in clients_list:
        quants = tuple(QuantConfig(method="rdfsq", bits=2) if c % 2 == 0
                       else QuantConfig(method="nf", bits=4)
                       for c in range(n))
        hub = HubConfig(n_clients=n, client_quants=quants)
        wire = schedules.hub_wire_bytes(cfg, hub, micro_batch, seq)
        links = {f"{s}->{d}": v["fwd"]
                 for (s, d), v in sorted(wire["links"].items())}
        ingress = wire["fwd_total"]
        out[n] = dict(links=links, server_ingress_bytes_per_tick=ingress,
                      lan_s_per_tick=ingress / LAN_BPS)
        emit(f"table4/hub_{n}clients", 0.0,
             f"server_ingress_B_per_tick={ingress};"
             f"lan_s_per_tick={ingress / LAN_BPS:.6f};"
             f"links={len(links)}")
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "table4_hub_links.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump({str(k): v for k, v in out.items()}, f, indent=1)
    print(f"saved {path}")
    return out


if __name__ == "__main__":
    run()
