"""Serving benchmark: continuous-batching engine vs static-batch generate.

Drives a mixed workload (random prompt lengths, random output budgets,
optionally Poisson arrivals) through

* the static baseline — ``serve/decode.generate`` over fixed groups of
  ``n_slots`` requests: every row in a group decodes until the LONGEST
  budget in the group finishes, which is exactly the head-of-line cost
  the engine removes; and
* the continuous-batching engine — slot admission/retirement over the
  paged KV pool, prefill separated from the decode tick.

Both count only USEFUL tokens (each request's own budget), so the static
baseline's wasted worst-case steps show up as lost tokens/s rather than
being flattered.  Reported per mode: tokens/s, per-token latency p50/p99,
time-to-first-token p50, and (engine) the page-table compile buckets.

Results go to stdout as the harness CSV rows and to ``BENCH_serve.json``
at the repo root (``--out`` overrides).

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.models import transformer as tf
from repro.serve import decode as sd
from repro.serve import paged
from repro.serve.engine import ServeEngine

ROOT = pathlib.Path(__file__).resolve().parent.parent

import jax  # noqa: E402  (after ROOT so --help works without a device)


def _percentile(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


def make_workload(rng: np.random.Generator, n: int, vocab: int,
                  p_lo: int, p_hi: int, n_lo: int, n_hi: int
                  ) -> List[Tuple[List[int], int]]:
    """Mixed request lengths: the regime where static batching loses."""
    return [(list(rng.integers(1, vocab, int(rng.integers(p_lo, p_hi + 1)))),
             int(rng.integers(n_lo, n_hi + 1))) for _ in range(n)]


def run_static(params, cfg, reqs, *, n_slots: int, cache_len: int) -> Dict:
    """Fixed groups of ``n_slots``; each group decodes to its max budget."""
    t0 = time.perf_counter()
    lat: List[float] = []
    ttft: List[float] = []
    useful = 0
    for g in range(0, len(reqs), n_slots):
        group = reqs[g:g + n_slots]
        maxp = max(len(t) for t, _ in group)
        n_new = max(m for _, m in group)
        toks = np.zeros((len(group), maxp), np.int32)
        for i, (t, _) in enumerate(group):
            toks[i, :len(t)] = t
        gt0 = time.perf_counter()
        out = sd.generate(params, cfg, dict(tokens=jnp.asarray(toks)),
                          n_new=n_new, cache_len=cache_len)
        jax.block_until_ready(out)
        gel = time.perf_counter() - gt0
        useful += sum(m for _, m in group)
        # generate is opaque per-token: attribute the group wall time
        # uniformly across its decode steps (prefill included in step 0)
        per_step = gel / n_new
        for _, m in group:
            ttft.append(per_step)
            lat.extend([per_step] * m)
    elapsed = time.perf_counter() - t0
    return dict(mode="static", tokens=useful, elapsed_s=elapsed,
                tokens_per_s=useful / elapsed,
                p50_ms=_percentile(lat, 50) * 1e3,
                p99_ms=_percentile(lat, 99) * 1e3,
                ttft_p50_ms=_percentile(ttft, 50) * 1e3)


def run_engine(params, cfg, reqs, *, n_slots: int, page_size: int,
               n_pages: int, arrivals: Optional[List[float]] = None,
               split_wire=None) -> Dict:
    """Continuous batching; ``arrivals`` (s, relative) enables open-loop
    Poisson load — None means every request is queued at t=0."""
    eng = ServeEngine(params, cfg, n_slots=n_slots, page_size=page_size,
                      n_pages=n_pages, split_wire=split_wire)
    arrivals = arrivals or [0.0] * len(reqs)
    order = np.argsort(arrivals, kind="stable")
    pending = [(arrivals[i], reqs[i]) for i in order]
    t0 = time.perf_counter()
    submitted = []
    while pending or not eng.idle:
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            at, (toks, m) = pending.pop(0)
            submitted.append(eng.submit(toks, max_new=m, arrival_time=at))
        if eng.idle:
            time.sleep(max(0.0, pending[0][0] - now))
            continue
        eng.step()
    elapsed = time.perf_counter() - t0
    lat: List[float] = []
    ttft: List[float] = []
    useful = 0
    for rid in submitted:
        r = eng.request(rid)
        useful += len(r.out)
        ttft.append((r.emit_times[0] - t0) - r.arrival_time)
        lat.extend(np.diff(r.emit_times).tolist())
    return dict(mode="engine", tokens=useful, elapsed_s=elapsed,
                tokens_per_s=useful / elapsed,
                p50_ms=_percentile(lat, 50) * 1e3,
                p99_ms=_percentile(lat, 99) * 1e3,
                ttft_p50_ms=_percentile(ttft, 50) * 1e3,
                wire_bytes=eng.stats["wire_bytes"],
                decode_ticks=eng.stats["decode_ticks"],
                prefill_batches=eng.stats["prefill_batches"],
                page_table_buckets=sorted(eng.stats["page_table_buckets"]))


def run(fast: bool = True, out: Optional[str] = None,
        seed: int = 0) -> Dict:
    cfg16 = get_config("llama3_2_3b").reduced()
    rng = np.random.default_rng(seed)
    n_req = 8 if fast else 24
    n_slots = 4
    page_size = 8
    p_lo, p_hi = 4, 24
    n_lo, n_hi = 2, 12 if fast else 24
    reqs = make_workload(rng, n_req, cfg16.vocab_size, p_lo, p_hi,
                         n_lo, n_hi)
    max_target = p_hi + n_hi
    cache_len = paged.next_pow2(max_target)
    n_pages = 1 + n_slots * (-(-cache_len // page_size))

    results = []
    for bits in (16, 8):
        cfg = cfg16 if bits == 16 else dataclasses.replace(
            cfg16, kv_cache_bits=bits)
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        common = dict(n_slots=n_slots, page_size=page_size, n_pages=n_pages)
        # warmup pass populates every jit bucket, then the measured pass
        run_static(params, cfg, reqs, n_slots=n_slots, cache_len=cache_len)
        st = run_static(params, cfg, reqs, n_slots=n_slots,
                        cache_len=cache_len)
        run_engine(params, cfg, reqs, **common)
        en = run_engine(params, cfg, reqs, **common)
        for row in (st, en):
            row.update(kv_bits=bits, offered_load_rps=None)
            results.append(row)
            emit(f"serve/{row['mode']}/kv{bits}",
                 1e6 * row["elapsed_s"] / max(row["tokens"], 1),
                 f"{row['tokens_per_s']:.1f}tok/s "
                 f"p50={row['p50_ms']:.1f}ms p99={row['p99_ms']:.1f}ms")
        if bits == 16 and not fast:
            # open-loop Poisson arrivals at fractions of the closed-system
            # service rate (requests/s)
            closed_rps = en["tokens_per_s"] / np.mean(
                [m for _, m in reqs])
            for frac in (0.5, 1.0):
                lam = closed_rps * frac
                arr = np.cumsum(rng.exponential(1.0 / lam,
                                                len(reqs))).tolist()
                row = run_engine(params, cfg, reqs, arrivals=arr, **common)
                row.update(kv_bits=bits, offered_load_rps=lam)
                results.append(row)
                emit(f"serve/engine/kv16/load{frac}",
                     1e6 * row["elapsed_s"] / max(row["tokens"], 1),
                     f"{row['tokens_per_s']:.1f}tok/s "
                     f"p99={row['p99_ms']:.1f}ms")

    doc = dict(
        config="llama3_2_3b.reduced", n_requests=n_req, n_slots=n_slots,
        page_size=page_size, n_pages=n_pages,
        prompt_len=[p_lo, p_hi], max_new=[n_lo, n_hi],
        backend=jax.default_backend(), smoke=fast, results=results)
    path = pathlib.Path(out) if out else ROOT / "BENCH_serve.json"
    path.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {path}")
    eng16 = next(r for r in results
                 if r["mode"] == "engine" and r["kv_bits"] == 16
                 and r["offered_load_rps"] is None)
    st16 = next(r for r in results
                if r["mode"] == "static" and r["kv_bits"] == 16)
    speedup = eng16["tokens_per_s"] / st16["tokens_per_s"]
    print(f"engine vs static (kv16): {speedup:.2f}x tokens/s")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: BENCH_serve.json)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(fast=args.smoke, out=args.out, seed=args.seed)


if __name__ == "__main__":
    main()
