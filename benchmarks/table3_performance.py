"""Paper Table 3: task performance per compression method x bit width.

Trains the (reduced) Quantized-TinyLLaVA on the synthetic VQA task under
every compressor and reports eval CE + answer accuracy, normalized to the
16-bit original model ("Overall Comparison").  The paper's claims checked
here: RD-FSQ robust at 1-2 bits; QLoRA(NF) weak at 1 bit but matching the
original at >= 2; everything approaching the original with more bits.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import QuantConfig, SplitConfig
from repro.data.pipeline import make_pipeline
from repro.models import transformer as tf
from repro.optim import AdamWConfig
from repro.train.loop import train_loop
from repro.train.losses import IGNORE, cross_entropy

N_STEPS = 200
BATCH = 8
SEQ = 24
SEEDS = 2  # averaged: single-seed orderings are noisy at this scale


def _cfg(method: str, bits: int):
    base = get_config("tinyllava").reduced()
    split = SplitConfig(cut_layer=0,
                        quant=QuantConfig(method=method, bits=bits),
                        learnable_codec=True,
                        enabled=method != "none")
    return dataclasses.replace(base, split=split)


def _eval(state, cfg, n_batches: int = 8, seed: int = 123) -> Dict:
    pipe = make_pipeline(cfg, BATCH, SEQ, seed=seed)
    ces, accs = [], []
    fwd = jax.jit(lambda p, b: tf.forward(p, cfg, b)[0])
    for _ in range(n_batches):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        logits = fwd(state.params, batch)
        labels = batch["labels"]
        ces.append(float(cross_entropy(logits, labels)))
        mask = labels != IGNORE
        pred = jnp.argmax(logits, -1)
        accs.append(float((jnp.where(mask, pred == labels, False)).sum() /
                          mask.sum()))
    return dict(ce=float(np.mean(ces)), acc=float(np.mean(accs)))


def run(n_steps: int = N_STEPS):
    settings = [("identity", 16)]
    for method in ("rdfsq", "fsq", "topk", "nf"):
        for bits in (1, 2, 4):
            settings.append((method, bits))

    results = {}
    base_score = None
    for method, bits in settings:
        cfg = _cfg(method, bits)
        accs, ces, dts = [], [], []
        for seed in range(SEEDS):
            data = make_pipeline(cfg, BATCH, SEQ, seed=seed)
            t0 = time.perf_counter()
            state, _ = train_loop(cfg, AdamWConfig(lr=2e-3), data,
                                  n_steps=n_steps, seed=seed,
                                  log_every=max(n_steps - 1, 1))
            dts.append(time.perf_counter() - t0)
            ev = _eval(state, cfg)
            accs.append(ev["acc"])
            ces.append(ev["ce"])
        ev = dict(ce=float(np.mean(ces)), acc=float(np.mean(accs)))
        score = ev["acc"] - 0.05 * ev["ce"]  # single overall scalar
        if method == "identity":
            base_score = score
        rel = (1.0 if base_score in (None, 0.0)
               else (1.0 + score - base_score))
        results[(method, bits)] = dict(**ev, overall=score, rel=rel)
        emit(f"table3/{method}_{bits}bit",
             np.mean(dts) / n_steps * 1e6,
             f"eval_ce={ev['ce']:.4f};eval_acc={ev['acc']:.4f};"
             f"overall_vs_16bit={rel:.4f}")
    return results


if __name__ == "__main__":
    run()
