"""Pallas kernel sweeps (interpret mode) against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packing import unpack_bits
from repro.core.quantizers.nf import nf_codebook
from repro.kernels import ops, ref

SHAPES = [(4, 700), (8, 1024), (3, 257), (16, 2048), (1, 64)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _x(shape, dtype, seed=0):
    return (jax.random.normal(jax.random.PRNGKey(seed), shape) * 3.0
            ).astype(dtype)


# ---------------------------------------------------------------------------
# RD-FSQ kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("bits", [1, 2, 4])
def test_rdfsq_kernel_codes_exact(shape, dtype, bits):
    x = _x(shape, dtype)
    words, stats = ops.rdfsq_quantize(x, bits)
    x2d = x.reshape(shape[0], -1)
    lo, hi = ref.rdfsq_stats(x2d)
    codes_ref = ref.rdfsq_codes_ref(x2d, lo, hi, bits)
    n_cols = x2d.shape[1]
    codes_kern = jax.vmap(lambda r: unpack_bits(r, bits, n_cols))(words)
    np.testing.assert_array_equal(np.asarray(codes_kern),
                                  np.asarray(codes_ref))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("bits", [2, 4])
def test_rdfsq_kernel_dequant_allclose(shape, bits):
    x = _x(shape, jnp.float32)
    words, stats = ops.rdfsq_quantize(x, bits)
    n_cols = int(np.prod(shape[1:]))
    x_hat = ops.rdfsq_dequantize(words, stats, bits, n_cols)
    # oracle with the same fp16 wire precision for (lo, hi)
    lo = stats[:, 0:1].astype(jnp.float32)
    hi = stats[:, 1:2].astype(jnp.float32)
    x2d = x.reshape(shape[0], -1)
    lo_f, hi_f = ref.rdfsq_stats(x2d)
    codes = ref.rdfsq_codes_ref(x2d, lo_f, hi_f, bits)
    d = 2 ** bits
    half = (d - 1) / 2.0
    x_ref = ((codes.astype(jnp.float32) - half) / half + 1) / 2 * \
        (hi - lo) + lo
    np.testing.assert_allclose(np.asarray(x_hat), np.asarray(x_ref),
                               atol=1e-5, rtol=1e-5)


def test_rdfsq_kernel_matches_core_quantizer():
    """Kernel path reproduces core.quantizers.rdfsq reconstruction."""
    from repro.core import QuantConfig, roundtrip
    x = _x((4, 512), jnp.float32)
    bits = 2
    words, stats = ops.rdfsq_quantize(x, bits)
    x_hat = ops.rdfsq_dequantize(words, stats, bits, 512)
    x_core, _ = roundtrip(QuantConfig(method="rdfsq", bits=bits), x)
    np.testing.assert_allclose(np.asarray(x_hat), np.asarray(x_core),
                               atol=2e-2, rtol=1e-2)


# ---------------------------------------------------------------------------
# NF kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("bits", [2, 3, 4])
def test_nf_kernel_codes_exact(shape, dtype, bits):
    x = _x(shape, dtype)
    words, scales, aux = ops.nf_quantize(x, bits, block=64)
    book = jnp.asarray(nf_codebook(bits), jnp.float32)
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % 64
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, 64)
    pw, _, _ = ref.nf_quantize_ref(blocks, book, bits)
    np.testing.assert_array_equal(np.asarray(words), np.asarray(pw))


@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("double_quant", [False, True])
def test_nf_kernel_dequant(bits, double_quant):
    """Kernel dequant == oracle dequant at the same wire precision."""
    x = _x((4, 700), jnp.float32)
    n = x.size
    words, scales, aux = ops.nf_quantize(x, bits, block=64,
                                         double_quant=double_quant)
    x_hat = ops.nf_dequantize(words, scales, aux, bits, n, block=64,
                              double_quant=double_quant)
    book = jnp.asarray(nf_codebook(bits), jnp.float32)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % 64
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, 64)
    pw, m, rng = ref.nf_quantize_ref(blocks, book, bits)
    m16 = m.astype(jnp.float16).astype(jnp.float32)
    rng16 = rng.astype(jnp.float16).astype(jnp.float32)  # kernel emits fp16
    if double_quant:
        gq = 256
        nb = rng16.shape[0]
        gpad = (-nb) % gq
        groups = jnp.pad(rng16, ((0, gpad), (0, 0))).reshape(-1, gq)
        gscale = jnp.max(jnp.abs(groups), axis=-1, keepdims=True)
        codes = jnp.round(groups / (gscale + 1e-8) * 255.0)
        gscale16 = gscale.astype(jnp.float16).astype(jnp.float32)
        rng_used = (codes / 255.0 * gscale16).reshape(-1, 1)[:nb]
        rng_used = rng_used.astype(jnp.float16).astype(jnp.float32)
    else:
        rng_used = rng16
    xr = ref.nf_dequantize_ref(pw, m16, rng_used, book, bits,
                               64).reshape(-1)[:n]
    np.testing.assert_allclose(np.asarray(x_hat), np.asarray(xr),
                               atol=2e-3, rtol=1e-3)
    # and the reconstruction is genuinely close to the data at 4 bits
    if bits == 4:
        rmse = float(jnp.sqrt(jnp.mean((x_hat - flat) ** 2)))
        assert rmse < 0.5


def test_nf_kernel_matches_core_quantizer():
    from repro.core import QuantConfig, roundtrip
    x = _x((4, 512), jnp.float32)
    bits = 4
    words, scales, aux = ops.nf_quantize(x, bits, block=64)
    x_hat = ops.nf_dequantize(words, scales, aux, bits, x.size,
                              block=64).reshape(x.shape)
    x_core, _ = roundtrip(
        QuantConfig(method="nf", bits=bits, block_size=64), x)
    np.testing.assert_allclose(np.asarray(x_hat), np.asarray(x_core),
                               atol=0.1, rtol=5e-2)
