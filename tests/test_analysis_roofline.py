"""HLO analysis parser + roofline/param-count sanity checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.hlo_analysis import analyze, split_computations
from repro.launch.roofline import derive_roofline, param_counts
from repro.launch.shapes import SHAPES, input_specs, window_for


def test_dot_flops_counted_with_loop_trips():
    """flops of a matmul inside a scan must be multiplied by trip count."""
    w = jnp.zeros((64, 64))

    def f(x):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    hlo = jax.jit(f).lower(jnp.ones((32, 64))).compile().as_text()
    res = analyze(hlo)
    expected = 2 * 32 * 64 * 64 * 10
    assert res["dot_flops"] == pytest.approx(expected, rel=0.01), res


def test_collective_bytes_parsed():
    import subprocess, sys, os, textwrap
    # needs >1 device: check parser on a tiny psum program in-process is
    # not possible (1 device -> no collectives); parse a synthetic HLO.
    hlo = """HloModule m

ENTRY %main (p: f32[16,128]) -> f32[16,128] {
  %p = f32[16,128] parameter(0)
  ROOT %ar = f32[16,128] all-reduce(%p), to_apply=%add
}
"""
    total, by_op = (analyze(hlo)["collective_bytes"],
                    analyze(hlo)["collective_by_op"])
    assert by_op.get("all-reduce") == 16 * 128 * 4


# a conditional whose branches do a 64x64 @ 64x64 dot (true) and a
# 32x64 @ 64x64 dot (false): exactly one branch runs per execution, so
# the analyzer must charge max(branch) = the true branch, once
_COND_HLO = """HloModule m

%true_comp (t: f32[64,64]) -> f32[64,64] {
  %t = f32[64,64] parameter(0)
  ROOT %d1 = f32[64,64] dot(f32[64,64] %t, f32[64,64] %t), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%false_comp (f: f32[64,64]) -> f32[64,64] {
  %f = f32[64,64] parameter(0)
  %s = f32[32,64] slice(%f), slice={[0:32], [0:64]}
  %d2 = f32[32,64] dot(f32[32,64] %s, f32[64,64] %f), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %p = f32[64,64] pad(f32[32,64] %d2, f32[] %c), padding=0_32x0_0
}

ENTRY %main (pred.1: pred[], x: f32[64,64]) -> f32[64,64] {
  %pred.1 = pred[] parameter(0)
  %x = f32[64,64] parameter(1)
  ROOT %cond = f32[64,64] conditional(%pred.1, %x, %x), true_computation=%true_comp, false_computation=%false_comp
}
"""


def test_conditional_counts_max_branch_once():
    res = analyze(_COND_HLO)
    true_flops = 2 * 64 * 64 * 64
    false_flops = 2 * 32 * 64 * 64
    # not 0 (branches ignored), not true+false (always-taken): max, once
    assert res["dot_flops"] == pytest.approx(true_flops), res
    assert res["dot_flops"] < true_flops + false_flops


def test_conditional_branch_computations_form():
    hlo = _COND_HLO.replace(
        "true_computation=%true_comp, false_computation=%false_comp",
        "branch_computations={%true_comp, %false_comp}")
    res = analyze(hlo)
    assert res["dot_flops"] == pytest.approx(2 * 64 * 64 * 64), res


def test_conditional_inside_loop_scales_with_trips():
    """max-over-branches composes with while trip counts."""
    hlo = """HloModule m

%true_comp (t: f32[64,64]) -> f32[64,64] {
  %t = f32[64,64] parameter(0)
  ROOT %d1 = f32[64,64] dot(f32[64,64] %t, f32[64,64] %t), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%false_comp (f: f32[64,64]) -> f32[64,64] {
  ROOT %f = f32[64,64] parameter(0)
}

%body (b: f32[64,64]) -> f32[64,64] {
  %b = f32[64,64] parameter(0)
  ROOT %cond = f32[64,64] conditional(%pr, %b, %b), true_computation=%true_comp, false_computation=%false_comp
}

%cond_comp (c: f32[64,64]) -> pred[] {
  %c = f32[64,64] parameter(0)
  %k = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

ENTRY %main (x: f32[64,64]) -> f32[64,64] {
  %x = f32[64,64] parameter(0)
  ROOT %w = f32[64,64] while(%x), condition=%cond_comp, body=%body
}
"""
    res = analyze(hlo)
    assert res["dot_flops"] == pytest.approx(10 * 2 * 64 * 64 * 64), res


def test_split_computations_entry():
    hlo = """HloModule m

%helper (a: f32[2]) -> f32[2] {
  ROOT %a = f32[2] parameter(0)
}

ENTRY %main (p: f32[4]) -> f32[4] {
  ROOT %p = f32[4] parameter(0)
}
"""
    comps, entry = split_computations(hlo)
    assert entry == "main"
    assert set(comps) == {"helper", "main"}


@pytest.mark.parametrize("arch,expected_b,tol", [
    ("llama3_2_3b", 3.2e9, 0.35),
    ("deepseek_coder_33b", 33e9, 0.25),
    ("granite_3_8b", 8e9, 0.35),
    ("deepseek_v2_236b", 236e9, 0.25),
    ("arctic_480b", 480e9, 0.25),
    ("rwkv6_7b", 7e9, 0.35),
])
def test_param_counts_match_nameplate(arch, expected_b, tol):
    total = param_counts(get_config(arch))["total"]
    assert abs(total - expected_b) / expected_b < tol, total / 1e9


def test_moe_active_far_below_total():
    c = param_counts(get_config("deepseek_v2_236b"))
    assert c["active"] < 0.2 * c["total"]  # ~21B active of 236B


def test_roofline_terms_and_dominant():
    res = dict(cost=dict(flops_loop_aware=197e12, bytes_out_loop_aware=0.0),
               collective_bytes_per_device=50e9, chips=256, model_flops=0.0)
    rl = derive_roofline(res)
    assert rl["compute_s"] == pytest.approx(1.0)
    assert rl["collective_s"] == pytest.approx(1.0)
    assert rl["dominant"] in ("compute", "collective")


@pytest.mark.parametrize("arch", ["llama3_2_3b", "rwkv6_7b", "musicgen_large",
                                  "llava_next_34b"])
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_no_allocation(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    for leaf in jax.tree_util.tree_leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    if shape.kind == "decode":
        assert "caches" in specs and "qpos" in specs
        win = window_for(cfg, shape)
        if win is not None and cfg.attn_type == "gqa":
            side = "client" if specs["caches"]["client"] else "server"
            k = specs["caches"][side]["seg0"]["k"]
            assert k.shape[2] == min(shape.seq_len, win)
