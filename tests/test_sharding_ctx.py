"""Sharding rules + activation context unit tests (no mesh needed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import transformer as tf
from repro.sharding import ctx, param_pspecs
from repro.sharding.specs import leaf_pspec

AXES = {"data": 16, "model": 16}


def test_column_row_rules():
    assert leaf_pspec(("attn", "wq"), (4096, 4096), AXES) == \
        P(None, "model")
    assert leaf_pspec(("attn", "wo"), (4096, 4096), AXES) == \
        P("model", None)
    assert leaf_pspec(("attn", "wq"), (4096, 4096), AXES, fsdp=True) == \
        P("data", "model")


def test_divisibility_fallback():
    # 73448 vocab is not divisible by 16 -> replicated
    assert leaf_pspec(("embed", "emb"), (73448, 2560), AXES) == P(None, None)
    assert leaf_pspec(("embed", "emb"), (128256, 3072), AXES) == \
        P("model", None)


def test_moe_expert_rule():
    spec = leaf_pspec(("ffn", "w_gate"), (160, 5120, 1536), AXES, fsdp=True)
    assert spec == P("model", "data", None)


def test_stacked_layers_get_leading_none():
    spec = leaf_pspec(("client", "seg0", "attn", "wq"), (14, 3072, 3072),
                      AXES, stacked=True)
    assert spec == P(None, None, "model")


def test_param_pspecs_cover_full_tree():
    cfg = get_config("zamba2_2_7b").reduced()
    params = jax.eval_shape(
        lambda: tf.init_params(jax.random.PRNGKey(0), cfg))
    specs = param_pspecs(params, AXES)
    assert jax.tree_util.tree_structure(specs, is_leaf=lambda x: isinstance(
        x, P)) == jax.tree_util.tree_structure(params)


def test_ctx_noop_without_install():
    ctx.clear()
    x = jnp.ones((4, 8, 16))
    assert ctx.constrain(x, "hidden") is x


def test_ctx_divisibility_drop():
    ctx.install(("data",), axes=AXES)
    try:
        # batch 1 does not divide 16 -> constraint silently dropped
        x = jnp.ones((1, 8, 16))
        y = ctx.constrain(x, "hidden")  # must not raise outside mesh
        assert y.shape == x.shape
    finally:
        ctx.clear()
