"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures (+ the paper's tinyllava): build
the REDUCED variant (2 layers, d_model <= 512, <= 4 experts), run one
forward and one full train step on CPU, assert output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.data.pipeline import make_pipeline
from repro.models import transformer as tf
from repro.optim import AdamWConfig
from repro.train.loop import init_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=16):
    return next(make_pipeline(cfg, b, s))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_constraints(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2
    assert cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = tf.init_params(KEY, cfg)
    batch = _batch(cfg)
    logits, aux = tf.forward(params, cfg, batch, rng=KEY)
    b = 2
    if cfg.modality == "audio":
        s = batch["codes"].shape[-1]
        assert logits.shape == (b, s, cfg.n_codebooks, cfg.vocab_size)
    elif cfg.modality == "vlm":
        s = cfg.n_image_tokens + batch["tokens"].shape[1]
        assert logits.shape == (b, s, cfg.vocab_size)
    else:
        s = batch["tokens"].shape[1]
        assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert np.isfinite(float(aux["commit"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_config(arch).reduced()
    opt = AdamWConfig(lr=1e-3)
    state = init_state(KEY, cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    batch = _batch(cfg)
    state, metrics = step(state, batch, KEY)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0.0
    assert int(state.step) == 1
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = tf.init_params(KEY, cfg)
    caches = tf.init_caches(cfg, 2, 32, jnp.float32)
    if cfg.modality == "audio":
        batch = dict(codes=jnp.zeros((2, cfg.n_codebooks, 1), jnp.int32))
    else:
        batch = dict(tokens=jnp.zeros((2, 1), jnp.int32))
    logits, new_caches = tf.decode_step(params, cfg, caches, batch,
                                        jnp.zeros((2,), jnp.int32))
    assert logits.shape[0] == 2 and logits.shape[1] == 1
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache structure preserved
    assert jax.tree_util.tree_structure(new_caches) == \
        jax.tree_util.tree_structure(caches)


def test_segments_respect_cut():
    for arch in ARCHS:
        cfg = get_config(arch)
        client, server = cfg.client_server_segments()
        n_client = sum(n for _, n in client)
        n_server = sum(n for _, n in server)
        assert n_client + n_server == cfg.n_layers
        assert n_client == cfg.split.resolve_cut(cfg.n_layers)


def test_zamba2_has_shared_attention():
    cfg = get_config("zamba2_2_7b")
    pattern = cfg.block_pattern()
    assert pattern.count("shared_attn") == 9  # every 6th of 54
    assert pattern.count("mamba2") == 45
