"""The stage/wire/scheduler decomposition and the many-client hub.

In-process tests cover the wire-link layer (per-link byte accounting,
grouping, cotangent quantization, per-client calibration) and the
mesh-free async scheduler; the SPMD lockstep hub (real collective
permutes, per-link HLO assertions, pipeline parity) runs in subprocesses
on an 8-fake-device mesh, like tests/test_mesh_subprocess.py.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.quantizers import QuantConfig
from repro.core import quantizers
from repro.core.split import (HubConfig, SplitConfig, WireLink,
                              calib_scale_error, group_links,
                              init_wire_calib, pipeline_links,
                              quantize_cotangent, update_wire_calib)
from repro.core.split_stage import chain_programs, hub_programs
from repro.launch import schedules

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=420)


# ---------------------------------------------------------------------------
# layer 1: stage programs
# ---------------------------------------------------------------------------

def test_stage_programs():
    cfg = get_config("llama3_2_3b").reduced()  # 2 layers
    chain = chain_programs(cfg, 2)
    assert [p.name for p in chain] == ["stage0/client", "stage1/server"]
    assert all(p.per_stage == 1 for p in chain)

    hub = hub_programs(cfg, 3)
    assert len(hub) == 4
    assert all(p.first and not p.last for p in hub[:3])
    assert hub[3].last and not hub[3].first
    assert hub[3].index == 3


# ---------------------------------------------------------------------------
# layer 2: wire links
# ---------------------------------------------------------------------------

def test_wirelink_bytes_and_heterogeneous_accounting():
    """Per-link bytes: each link counted once; the per-device tick load is
    the MAX over links (a device sources one cut per tick), not the old
    sum over distinct configs — the heterogeneous SPMD overcount."""
    cfg = get_config("llama3_2_3b").reduced()
    x = jax.ShapeDtypeStruct((4, 16, cfg.d_model), jnp.float32)
    q2 = QuantConfig(method="rdfsq", bits=2)
    q4 = QuantConfig(method="nf", bits=4)

    link = WireLink(src=0, dst=1, quant=q2)
    direct = jax.eval_shape(
        lambda: quantizers.encode(q2, jnp.zeros(x.shape, x.dtype)))
    assert link.fwd_wire_bytes(x) == direct.wire_bytes()
    # paper scope: uncompressed cotangent
    assert link.bwd_wire_bytes(x) == 4 * 16 * cfg.d_model * 4
    qlink = WireLink(src=0, dst=1, quant=q2, bwd_quant=q4)
    assert qlink.bwd_wire_bytes(x) < link.bwd_wire_bytes(x)

    split = SplitConfig(quant=q2, n_stages=4, stage_quants=(q2, q4, q2),
                        learnable_codec=False)
    wire = schedules.chain_wire_bytes(cfg, split, 4, 16)
    b2 = wire["links"][(0, 1)]["fwd"]
    b4 = wire["links"][(1, 2)]["fwd"]
    assert wire["links"][(2, 3)]["fwd"] == b2
    assert b4 > b2
    assert wire["fwd_tick"] == max(b2, b4)  # NOT b2 + b4 (the old sum)
    assert wire["fwd_total"] == 2 * b2 + b4


def test_pipeline_links_and_grouping():
    q2 = QuantConfig(method="rdfsq", bits=2)
    q4 = QuantConfig(method="nf", bits=4)
    split = SplitConfig(quant=q2, n_stages=4, stage_quants=(q2, q4, q2),
                        learnable_codec=False)
    links = pipeline_links(split)
    assert [(k.src, k.dst) for k in links] == [(0, 1), (1, 2), (2, 3)]
    groups = group_links(links)
    assert len(groups) == 2  # q2 cuts share one collective, q4 its own
    assert [(k.src, k.dst) for k in groups[0][2]] == [(0, 1), (2, 3)]

    hub = HubConfig(n_clients=3, quant=q2, client_quants=(q2, q4, q2))
    hlinks = hub.links()
    assert [(k.src, k.dst, k.client) for k in hlinks] == \
        [(0, 3, 0), (1, 3, 1), (2, 3, 2)]


def test_hub_config_validation():
    with pytest.raises(ValueError):
        HubConfig(n_clients=2, client_quants=(QuantConfig(),)).links()
    with pytest.raises(ValueError):
        HubConfig(n_clients=2, tick_rates=(1, 0)).resolve_tick_rates()
    with pytest.raises(ValueError):
        HubConfig(n_clients=2, tick_rates=(1,)).resolve_tick_rates()
    assert HubConfig(n_clients=3).resolve_tick_rates() == (1, 1, 1)


def test_quantize_cotangent():
    """Identity forward; the backward pushes the cotangent through the
    wire codec, exactly matching an explicit encode->decode roundtrip."""
    q = QuantConfig(method="rdfsq", bits=2)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32))
    y, vjp = jax.vjp(lambda v: quantize_cotangent(q, v), x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    g = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
    (got,) = vjp(g)
    ref = quantizers.decode(q, quantizers.encode(q, g))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-6)
    # identity config: cotangent passes through untouched
    (ident,) = jax.vjp(lambda v: quantize_cotangent(
        QuantConfig(method="identity"), v), x)[1](g)
    np.testing.assert_array_equal(np.asarray(ident), np.asarray(g))


def test_wire_calib_updates_and_isolation():
    k = jax.random.PRNGKey(0)
    narrow = 0.1 * jax.random.normal(k, (64,))
    wide = 5.0 * jax.random.normal(k, (64,))

    c0 = init_wire_calib()
    # first update adopts the batch stats outright
    c0 = update_wire_calib(c0, narrow)
    assert float(c0["count"]) == 1.0
    np.testing.assert_allclose(float(c0["std"]),
                               float(jnp.std(narrow)), rtol=1e-6)
    # later updates EMA-blend
    c0b = update_wire_calib(c0, 2.0 * narrow)
    assert float(c0["std"]) < float(c0b["std"]) < float(
        jnp.std(2.0 * narrow))

    c1 = update_wire_calib(init_wire_calib(), wide)
    err = float(calib_scale_error(c0, c1))
    assert err > 0.5, err  # 50x scale gap -> clearly different state
    same = float(calib_scale_error(c0, update_wire_calib(
        init_wire_calib(), narrow)))
    assert same < 1e-6, same


def test_arrival_mask():
    m = schedules.arrival_mask((1, 2, 3), 6)
    assert m.shape == (6, 3)
    np.testing.assert_array_equal(m[:, 0], [True] * 6)
    np.testing.assert_array_equal(m[:, 1],
                                  [True, False, True, False, True, False])
    np.testing.assert_array_equal(
        m[:, 2], [True, False, False, True, False, False])


# ---------------------------------------------------------------------------
# layer 3: the async scheduler (mesh-free, runs in-process)
# ---------------------------------------------------------------------------

def _async_setup(n_clients, client_scale=None, seed=0):
    from repro.optim import AdamWConfig

    cfg = get_config("llama3_2_3b").reduced()
    hub = HubConfig(n_clients=n_clients,
                    quant=QuantConfig(method="rdfsq", bits=2))
    opt = AdamWConfig(lr=0.0, weight_decay=0.0)  # observe, don't move
    state = schedules.init_hub_state(jax.random.PRNGKey(seed), cfg, hub,
                                     opt)
    if client_scale is not None:
        scale = jnp.asarray(client_scale)
        state["client_params"] = jax.tree_util.tree_map(
            lambda a: a * scale.reshape((n_clients,) + (1,) *
                                        (a.ndim - 1)).astype(a.dtype),
            state["client_params"])
    update = schedules.build_async_update(cfg, hub, opt, micro_batch=2,
                                          seq=16)
    tok = jax.random.randint(jax.random.PRNGKey(7),
                             (n_clients, 2, 16), 0, cfg.vocab_size)
    return cfg, hub, opt, state, update, tok


def _slice_client(state, c):
    """A solo (N=1) hub state holding exactly client c of ``state`` —
    same server, client c's params/opt/calib sliced out."""
    sliced = {k: jax.tree_util.tree_map(lambda a: a[c:c + 1], state[k])
              for k in ("client_params", "client_opt", "calib")}
    return dict(server=state["server"], **sliced)


def test_per_client_calibration_isolation():
    """Two clients with different activation scales produce different
    codec calibration state, and neither client's wire quantization
    error regresses vs training solo (satellite acceptance)."""
    cfg, _, opt, state0, update, tok = _async_setup(
        2, client_scale=(1.0, 3.0))
    mask = jnp.ones((2,))
    state = state0
    for _ in range(3):
        state, metrics = update(state, tok, tok, mask)
    calib = state["calib"]
    assert float(jnp.min(calib["count"])) == 3.0
    c0 = {k: v[0] for k, v in calib.items()}
    c1 = {k: v[1] for k, v in calib.items()}
    # 3x block-weight scale -> visibly different activation ranges
    assert float(calib_scale_error(c0, c1)) > 0.05
    hub_err = np.asarray(metrics["quant_rel_err"])

    # solo runs from the SAME initial weights (client c sliced out of the
    # hub state): client c alone must see the same quantization error it
    # saw inside the hub — no cross-client leakage through the codec
    solo_hub = HubConfig(n_clients=1, quant=QuantConfig(method="rdfsq",
                                                        bits=2))
    upd_solo = schedules.build_async_update(cfg, solo_hub, opt,
                                            micro_batch=2, seq=16)
    for c in (0, 1):
        s_solo = _slice_client(state0, c)
        for _ in range(3):
            s_solo, m_solo = upd_solo(s_solo, tok[c:c + 1], tok[c:c + 1],
                                      jnp.ones((1,)))
        solo_err = float(np.asarray(m_solo["quant_rel_err"])[0])
        np.testing.assert_allclose(hub_err[c], solo_err, rtol=1e-4)
        # and the solo codec state matches the hub's slice for client c
        solo_c = {k: v[0] for k, v in s_solo["calib"].items()}
        hub_c = {k: v[c] for k, v in calib.items()}
        assert float(calib_scale_error(hub_c, solo_c)) < 1e-5


def test_async_gating_freezes_non_arrivals():
    """A non-arriving client's params, moments, step count and calib are
    bit-identical before and after the tick (AdamW with a zero grad
    would still decay weights — the gate must select the old state)."""
    from repro.optim import AdamWConfig

    cfg = get_config("llama3_2_3b").reduced()
    hub = HubConfig(n_clients=2, quant=QuantConfig(method="rdfsq", bits=2))
    opt = AdamWConfig(lr=1e-2, weight_decay=0.1)
    state = schedules.init_hub_state(jax.random.PRNGKey(0), cfg, hub, opt)
    update = schedules.build_async_update(cfg, hub, opt, micro_batch=2,
                                          seq=16)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 2, 16), 0,
                             cfg.vocab_size)
    state2, _ = update(state, tok, tok, jnp.asarray([1.0, 0.0]))

    def leaves(tree, idx):
        return [np.asarray(a[idx]) for a in
                jax.tree_util.tree_leaves(tree)]

    for a, b in zip(leaves(state["client_params"], 1),
                    leaves(state2["client_params"], 1)):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(leaves(state["client_opt"], 1),
                    leaves(state2["client_opt"], 1)):
        np.testing.assert_array_equal(a, b)
    assert float(state2["calib"]["count"][1]) == 0.0
    # the arriving client did move
    changed = any(np.any(a != b) for a, b in
                  zip(leaves(state["client_params"], 0),
                      leaves(state2["client_params"], 0)))
    assert changed
    assert int(state2["client_opt"]["step"][0]) == 1
    # server stepped once for the arrival
    assert int(state2["server"].step) == 1


# ---------------------------------------------------------------------------
# SPMD lockstep hub: subprocess on the 8-fake-device mesh
# ---------------------------------------------------------------------------

def test_hub_parity_and_per_link_hlo():
    """hub(N=1) == 2-partition pipeline loss (3e-6 acceptance bound) and
    the 3-client heterogeneous hub's per-link static bytes match the
    lowered HLO collective-permute traffic within 1%."""
    r = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        from repro.launch import split_hub as sh
        p = sh.dryrun_parity()
        assert p["diff"] < 3e-6, p
        h = sh.dryrun_hub(n_clients=3)
        assert len(h["wire_links"]) == 3
        # heterogeneous: the nf-4bit link carries more than the rdfsq-2bit
        assert h["wire_links"]["1->3"] > h["wire_links"]["0->3"]
        print("HUB_OK")
    """)
    assert "HUB_OK" in r.stdout, r.stdout + r.stderr


def test_async_hub_trains():
    """Acceptance: async-mode train_hub shows monotone-ish loss decrease
    (windowed means) with heterogeneous quants AND tick rates."""
    r = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        from repro.launch import split_hub as sh
        res = sh.dryrun_train_async(n_ticks=18)
        assert res["tail_mean"] < res["head_mean"], res
        print("ASYNC_TRAIN_OK")
    """)
    assert "ASYNC_TRAIN_OK" in r.stdout, r.stdout + r.stderr


def test_pipeline_update_step_cache():
    """Repeated train_pipeline calls with the same configuration reuse
    one jitted update (satellite: retire the recompile overhead)."""
    r = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        from repro.launch import split_pipeline as sp
        sp.dryrun_train(n_steps=2, n_micro=2, micro_batch=4, seq=32)
        info1 = sp._cached_pipeline_update.cache_info()
        assert info1.misses == 1, info1
        sp.dryrun_train(n_steps=2, n_micro=2, micro_batch=4, seq=32)
        info2 = sp._cached_pipeline_update.cache_info()
        assert info2.misses == 1 and info2.hits >= 1, info2
        print("CACHE_OK")
    """)
    assert "CACHE_OK" in r.stdout, r.stdout + r.stderr
