"""Training loop, grad accumulation, checkpointing, serving consistency."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.configs import get_config
from repro.core import QuantConfig, SplitConfig
from repro.data.pipeline import make_pipeline
from repro.models import transformer as tf
from repro.optim import AdamWConfig
from repro.serve.decode import generate, prefill
from repro.train.loop import (init_state, make_train_step, train_loop)

KEY = jax.random.PRNGKey(0)


def test_loss_decreases_tiny_llama():
    cfg = get_config("llama3_2_3b").reduced()
    data = make_pipeline(cfg, batch_size=8, seq_len=32, seed=0)
    _, history = train_loop(cfg, AdamWConfig(lr=3e-3), data, n_steps=60,
                            log_every=59)
    first = history[0][1]["ce"]
    last = history[-1][1]["ce"]
    assert last < first * 0.8, (first, last)


def test_grad_accumulation_matches_single_batch():
    cfg = get_config("granite_3_8b").reduced()
    opt = AdamWConfig(lr=1e-3)
    state = init_state(KEY, cfg, opt)
    batch = next(make_pipeline(cfg, batch_size=8, seq_len=16))
    step1 = jax.jit(make_train_step(cfg, opt, grad_accum=1))
    step4 = jax.jit(make_train_step(cfg, opt, grad_accum=4))
    s1, m1 = step1(state, batch, KEY)
    s4, m4 = step4(state, batch, KEY)
    # same data, same params -> same mean loss & near-identical update
    assert abs(float(m1["ce"]) - float(m4["ce"])) < 2e-3
    p1 = jax.tree_util.tree_leaves(s1.params)
    p4 = jax.tree_util.tree_leaves(s4.params)
    for a, b in zip(p1, p4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("musicgen_large").reduced()
    opt = AdamWConfig()
    state = init_state(KEY, cfg, opt)
    path = os.path.join(tmp_path, "ckpt.npz")
    checkpoint.save(path, state)
    template = jax.tree_util.tree_map(jnp.zeros_like, state)
    restored = checkpoint.restore(path, template)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _no_split(cfg):
    return dataclasses.replace(
        cfg, split=SplitConfig(quant=QuantConfig(method="identity"),
                               learnable_codec=False, enabled=False))


@pytest.mark.parametrize("arch", ["llama3_2_3b", "rwkv6_7b", "zamba2_2_7b",
                                  "minicpm3_4b"])
def test_prefill_then_decode_matches_forward(arch):
    """serve path: prefill caches + 1-step decode == full forward."""
    cfg = _no_split(get_config(arch).reduced())
    params = tf.init_params(KEY, cfg)
    s = 12
    tokens = jax.random.randint(KEY, (2, s), 0, cfg.vocab_size)
    full_logits, _ = tf.forward(params, cfg,
                                dict(tokens=tokens))
    # prefill on first s-1 tokens, then decode token s-1
    _, caches = prefill(params, cfg, dict(tokens=tokens[:, :s - 1]),
                        cache_len=s)
    logits, _ = tf.decode_step(params, cfg, caches,
                               dict(tokens=tokens[:, s - 1:]),
                               jnp.full((2,), s - 1, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full_logits[:, -1]),
        atol=2e-2, rtol=2e-2)


def test_generate_runs():
    cfg = get_config("llama3_2_3b").reduced()
    params = tf.init_params(KEY, cfg)
    batch = dict(tokens=jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size))
    out = generate(params, cfg, batch, n_new=5, cache_len=32)
    assert out.shape == (2, 5)
    assert int(out.min()) >= 0 and int(out.max()) < cfg.vocab_size


def test_generate_rng_discipline():
    """Regression for the prefill/first-pick key reuse: sampling must be
    reproducible under the same rng and respond to a different rng, and
    greedy output must not depend on the rng at all."""
    cfg = get_config("llama3_2_3b").reduced()
    params = tf.init_params(KEY, cfg)
    batch = dict(tokens=jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size))
    kw = dict(n_new=8, cache_len=32, temperature=1.0)
    a = generate(params, cfg, batch, rng=jax.random.PRNGKey(1), **kw)
    b = generate(params, cfg, batch, rng=jax.random.PRNGKey(1), **kw)
    c = generate(params, cfg, batch, rng=jax.random.PRNGKey(2), **kw)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.any(np.asarray(a) != np.asarray(c))
    g1 = generate(params, cfg, batch, n_new=8, cache_len=32,
                  rng=jax.random.PRNGKey(1))
    g2 = generate(params, cfg, batch, n_new=8, cache_len=32,
                  rng=jax.random.PRNGKey(2))
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


def test_int8_kv_cache_decode_close_to_full():
    """Beyond-paper int8 KV cache: decode matches full forward to ~1%."""
    cfg = dataclasses.replace(_no_split(get_config("llama3_2_3b").reduced()),
                              kv_cache_bits=8)
    params = tf.init_params(KEY, cfg)
    s = 12
    tokens = jax.random.randint(KEY, (2, s), 0, cfg.vocab_size)
    full, _ = tf.forward(params, cfg, dict(tokens=tokens))
    _, caches = prefill(params, cfg, dict(tokens=tokens[:, :s - 1]),
                        cache_len=s)
    logits, new_caches = tf.decode_step(
        params, cfg, caches, dict(tokens=tokens[:, s - 1:]),
        jnp.full((2,), s - 1, jnp.int32))
    rel = float(jnp.max(jnp.abs(logits[:, 0] - full[:, -1]))) / \
        float(jnp.max(jnp.abs(full[:, -1])))
    assert rel < 0.05, rel
    # cache stays int8 on the wire
    leaf = new_caches["client"]["seg0"]["k"]
    assert leaf.dtype == jnp.int8
