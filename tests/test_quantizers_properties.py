"""Hypothesis property tests for the compression methods.

Kept separate from ``test_quantizers.py`` so a missing optional dependency
skips only these tests instead of aborting tier-1 collection.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import QuantConfig, roundtrip  # noqa: E402
from repro.core.packing import pack_bits, packed_size, unpack_bits  # noqa: E402


def _x(shape=(4, 64, 32), scale=2.0, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


def _rmse(a, b):
    return float(jnp.sqrt(jnp.mean((a - b) ** 2)))


@settings(max_examples=50, deadline=None)
@given(bits=st.sampled_from([1, 2, 3, 4, 8]),
       n=st.integers(min_value=1, max_value=300),
       seed=st.integers(min_value=0, max_value=2 ** 16))
def test_pack_roundtrip_exact(bits, n, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 2 ** bits, size=(n,)).astype(np.uint8)
    words = pack_bits(jnp.asarray(codes), bits)
    assert words.shape[0] == packed_size(n, bits)
    back = unpack_bits(words, bits, n)
    np.testing.assert_array_equal(np.asarray(back), codes)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), bits=st.sampled_from([2, 4]),
       method=st.sampled_from(["rdfsq", "nf"]))
def test_double_quantize_idempotent(seed, bits, method):
    """Re-quantizing a reconstruction reproduces (nearly) the same values."""
    cfg = QuantConfig(method=method, bits=bits)
    x = _x((2, 64), seed=seed)
    y1, _ = roundtrip(cfg, x)
    y2, _ = roundtrip(cfg, y1)
    assert _rmse(y1, y2) < 0.25 * _rmse(x, y1) + 1e-4


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_topk_preserves_largest(seed):
    cfg = QuantConfig(method="topk", bits=2, rand_frac=0.0)
    x = _x((2, 64), seed=seed)
    x_hat, _ = roundtrip(cfg, x, jax.random.PRNGKey(seed))
    flat = np.abs(np.asarray(x).reshape(2, -1))
    kept = np.asarray(x_hat).reshape(2, -1) != 0
    k = kept[0].sum()
    for b in range(2):
        top_idx = np.argsort(-flat[b])[:k]
        assert kept[b][top_idx].all()
