"""Split-learning boundary: equivalence, wire accounting, codec."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (QuantConfig, SplitConfig, analytic_bits_per_scalar,
                        compressor_roundtrip, init_codec_params, wire_payload)
from repro.models import transformer as tf

KEY = jax.random.PRNGKey(0)


def _cfg(method="identity", bits=2, learnable=False, enabled=True):
    base = get_config("llama3_2_3b").reduced()
    split = SplitConfig(cut_layer=1,
                        quant=QuantConfig(method=method, bits=bits),
                        learnable_codec=learnable, enabled=enabled)
    return dataclasses.replace(base, split=split)


def test_split_identity_equals_unsplit():
    """With the identity compressor and no codec, the cut is transparent
    (up to one bf16 round trip of the boundary activation)."""
    cfg_split = _cfg("identity")
    cfg_off = dataclasses.replace(
        cfg_split, split=dataclasses.replace(cfg_split.split, enabled=False))
    params = tf.init_params(KEY, cfg_split)
    batch = dict(tokens=jax.random.randint(KEY, (2, 16), 0,
                                           cfg_split.vocab_size))
    l1, _ = tf.forward(params, cfg_split, batch)
    l2, _ = tf.forward(params, cfg_off, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=5e-2,
                               rtol=5e-2)


@pytest.mark.parametrize("method", ["fsq", "rdfsq", "nf", "topk"])
def test_quantized_split_still_trains_signal(method):
    """Quantized cut degrades but does not destroy the logits."""
    cfg = _cfg(method, bits=2)
    params = tf.init_params(KEY, cfg)
    batch = dict(tokens=jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size))
    logits, aux = tf.forward(params, cfg, batch, rng=KEY)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_codec_near_identity_at_init():
    d = 64
    codec = init_codec_params(KEY, d)
    cfg = SplitConfig(quant=QuantConfig(method="identity"),
                      learnable_codec=True)
    x = jax.random.normal(KEY, (2, 8, d))
    y, _ = compressor_roundtrip(codec, cfg, x)
    assert float(jnp.mean(jnp.abs(y - x))) < 0.2


def test_wire_payload_bytes_scale_with_bits():
    d = 128
    x = jax.random.normal(KEY, (4, 16, d))
    sizes = {}
    for bits in (1, 2, 4):
        cfg = SplitConfig(quant=QuantConfig(method="rdfsq", bits=bits),
                          learnable_codec=False)
        sizes[bits] = wire_payload(cfg, None, x).wire_bytes()
    assert sizes[2] > sizes[1]
    # 2 bit ~ 87.5% smaller than 16 bit (paper abstract)
    cfg16 = SplitConfig(quant=QuantConfig(method="identity"),
                        learnable_codec=False)
    full = wire_payload(cfg16, None, x).wire_bytes()
    assert abs(1 - sizes[2] / (full * 0.125)) < 0.05


def test_analytic_bits_match_paper_table2():
    h = 1024
    assert analytic_bits_per_scalar(QuantConfig(method="fsq", bits=2), h) \
        == 2
    assert analytic_bits_per_scalar(QuantConfig(method="rdfsq", bits=3), h) \
        == 3
    assert analytic_bits_per_scalar(QuantConfig(method="identity"), h) == 16
    topk = analytic_bits_per_scalar(QuantConfig(method="topk", bits=2), h)
    assert abs(topk - 2.0) < 0.2  # 16K/H with K = bits*H/16


def test_commit_loss_reaches_client_params():
    """The commitment loss must backprop into client-side weights."""
    cfg = _cfg("rdfsq", bits=2, learnable=True)
    params = tf.init_params(KEY, cfg)
    batch = dict(tokens=jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size))

    def commit_only(params):
        _, aux = tf.forward(params, cfg, batch, rng=KEY)
        return aux["commit"]

    g = jax.grad(commit_only)(params)
    gnorm_client = sum(
        float(jnp.sum(jnp.abs(v))) for v in
        jax.tree_util.tree_leaves(g["client"]))
    gnorm_server = sum(
        float(jnp.sum(jnp.abs(v))) for v in
        jax.tree_util.tree_leaves(g["server"]))
    assert gnorm_client > 0.0
    assert gnorm_server == 0.0  # stop-gradient: server untouched by L_comm
