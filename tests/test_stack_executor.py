"""Unified stack executor + grad-safe barrier.

Covers the acceptance criteria of the backprop-restoration refactor:
(a) ``grad_safe_barrier`` gradients match a barrier-free reference,
(b) plain-scan vs sqrt-L-remat forward+grad equivalence,
(c) cache-collection path parity with the training path,
(d) the anti-hoisting protection survives: the lowered module still
    carries the barrier, and the compiled HLO contains no
    layer-count-stacked attention-mask buffer.
"""
import dataclasses
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.hlo_analysis import analyze
from repro.models import stack
from repro.models import transformer as tf
from repro.train.loop import init_state, make_train_step
from repro.utils import grad_safe_barrier

KEY = jax.random.PRNGKey(0)


def _cfg(n_layers=8, remat=False, remat_group=0):
    base = get_config("llama3_2_3b").reduced()
    return dataclasses.replace(base, n_layers=n_layers, remat=remat,
                               remat_group=remat_group)


def _batch(cfg, b=2, s=16):
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    return dict(tokens=tokens, labels=tokens)


# ---------------------------------------------------------------------------
# (a) grad_safe_barrier == barrier-free reference
# ---------------------------------------------------------------------------

def test_barrier_grads_match_reference():
    w = jax.random.normal(KEY, (8, 8))
    x0 = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    positions = jnp.arange(4)

    def run(x, use_barrier):
        def body(c, _):
            if use_barrier:
                c, _p = grad_safe_barrier((c, positions))
            return jnp.tanh(c @ w), None

        y, _ = jax.lax.scan(body, x, None, length=5)
        return jnp.sum(y * y)

    v_b, g_b = jax.value_and_grad(lambda x: run(x, True))(x0)
    v_r, g_r = jax.value_and_grad(lambda x: run(x, False))(x0)
    np.testing.assert_allclose(float(v_b), float(v_r), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g_b), np.asarray(g_r), atol=1e-6)


def test_barrier_identity_on_forward_and_int_leaves():
    x = jax.random.normal(KEY, (3, 5))
    ints = jnp.arange(5)
    y, i2 = grad_safe_barrier((x, ints))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(ints))
    # grads flow even when int leaves ride along (float0 cotangents)
    g = jax.grad(lambda x: grad_safe_barrier((x, ints))[0].sum())(x)
    np.testing.assert_allclose(np.asarray(g), 1.0)


# ---------------------------------------------------------------------------
# executor policies on a toy stack (no model in the loop)
# ---------------------------------------------------------------------------

def _toy_body(c, p):
    y = jnp.tanh(c @ p["w"]) + p["b"]
    return y, (dict(l2=jnp.sum(y * y)), None)


def _toy_stack(n=8, d=6):
    ks = jax.random.split(KEY, 2)
    return dict(w=jax.random.normal(ks[0], (n, d, d)) * 0.3,
                b=jax.random.normal(ks[1], (n, d)) * 0.01)


@pytest.mark.parametrize("remat,group", [(False, 0), (True, 0), (True, 3),
                                         (True, 4), (True, 8)])
def test_run_stack_policies_agree(remat, group):
    """Every executor policy computes the same carry, aux sum and grads."""
    stacked = _toy_stack()
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 6))

    def run(x, stacked):
        y, aux, _ = stack.run_stack(_toy_body, x, stacked, remat=remat,
                                    remat_group=group)
        return jnp.sum(y) + aux["l2"]

    def ref(x, stacked):
        y, (auxs, _) = jax.lax.scan(_toy_body, x, stacked)
        return jnp.sum(y) + jnp.sum(auxs["l2"])

    v, gx = jax.value_and_grad(run)(x, stacked)
    v_r, gx_r = jax.value_and_grad(ref)(x, stacked)
    np.testing.assert_allclose(float(v), float(v_r), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_r), atol=1e-6)
    gp = jax.grad(run, argnums=1)(x, stacked)
    gp_r = jax.grad(ref, argnums=1)(x, stacked)
    for a, b in zip(jax.tree_util.tree_leaves(gp),
                    jax.tree_util.tree_leaves(gp_r)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_run_stack_collect_matches_plain():
    stacked = _toy_stack()
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 6))

    def body(c, p):
        y = jnp.tanh(c @ p["w"]) + p["b"]
        return y, (dict(l2=jnp.sum(y * y)), dict(state=y))

    y1, aux1, caches = stack.run_stack(body, x, stacked, collect=True)
    y2, aux2, none = stack.run_stack(body, x, stacked, collect=False)
    assert none is None
    assert caches["state"].shape == (8, 4, 6)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))
    np.testing.assert_allclose(np.asarray(caches["state"][-1]),
                               np.asarray(y1))
    np.testing.assert_allclose(float(aux1["l2"]), float(aux2["l2"]),
                               rtol=1e-6)


def test_group_size_remainders():
    assert stack.group_size(2) == 1          # tiny stacks: no grouping
    assert stack.group_size(8, 4) == 4
    assert stack.group_size(31, 8) == 8      # prime length still groups
    assert stack.group_size(3, 8) == 1


def test_auto_group_size_bytes_aware():
    mib = 2 ** 20
    # fits the budget -> stay single-level
    assert stack.auto_group_size(64, mib, budget=64 * mib) == 1
    # over budget -> k ~ sqrt(n)
    assert stack.auto_group_size(64, 2 * mib, budget=64 * mib) == 8
    assert stack.auto_group_size(29, mib, budget=mib) == 5   # round(sqrt)
    # tiny stacks never group, whatever the bytes
    assert stack.auto_group_size(3, 2 ** 40, budget=1) == 1
    # env default budget is used when budget is omitted
    assert stack.auto_group_size(8, 1) == 1


def test_auto_remat_group_engages_and_preserves_numerics(monkeypatch):
    """With a zero byte budget every remat segment auto-groups; forward
    and grads must match the ungrouped model exactly."""
    cfg_plain = _cfg(n_layers=8, remat=False)
    params = tf.init_params(KEY, cfg_plain)
    batch = _batch(cfg_plain)
    l0, g0 = jax.value_and_grad(_loss_fn(cfg_plain))(params, batch)
    monkeypatch.setenv("REPRO_REMAT_BUDGET_BYTES", "0")
    cfg_auto = _cfg(n_layers=8, remat=True, remat_group=0)
    l1, g1 = jax.value_and_grad(_loss_fn(cfg_auto))(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# (b) plain vs sqrt-L remat on the real model
# ---------------------------------------------------------------------------

def _loss_fn(cfg):
    def loss(params, batch):
        logits, aux = tf.forward(params, cfg, batch, rng=KEY)
        return jnp.mean(logits.astype(jnp.float32) ** 2) + aux["commit"]

    return loss


def test_model_plain_vs_sqrt_remat_forward_and_grad():
    cfg0 = _cfg(n_layers=8, remat=False)
    cfg2 = _cfg(n_layers=8, remat=True, remat_group=4)
    params = tf.init_params(KEY, cfg0)
    batch = _batch(cfg0)
    l0, g0 = jax.value_and_grad(_loss_fn(cfg0))(params, batch)
    l2, g2 = jax.value_and_grad(_loss_fn(cfg2))(params, batch)
    np.testing.assert_allclose(float(l0), float(l2), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize("remat", [False, True])
def test_train_step_runs_both_remat_modes(remat):
    """Gradients flow through the stack with cfg.remat on AND off."""
    cfg = _cfg(n_layers=4, remat=remat, remat_group=2 if remat else 0)
    from repro.optim import AdamWConfig

    state = init_state(KEY, cfg, AdamWConfig(lr=1e-3))
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    state, metrics = step(state, dict(tokens=tokens, labels=tokens), KEY)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0.0


# ---------------------------------------------------------------------------
# (c) cache-collection path parity with the training path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("remat", [False, True])
def test_cache_collection_parity_with_training_path(remat):
    cfg = _cfg(n_layers=8, remat=remat)
    params = tf.init_params(KEY, cfg)
    batch = _batch(cfg, s=12)
    logits_train, aux_train = tf.forward(params, cfg, batch, rng=KEY)
    logits_cache, aux_cache, caches = tf.forward(params, cfg, batch,
                                                 rng=KEY, collect_cache=16)
    np.testing.assert_allclose(np.asarray(logits_train),
                               np.asarray(logits_cache), atol=1e-5)
    np.testing.assert_allclose(float(aux_train["commit"]),
                               float(aux_cache["commit"]), rtol=1e-5)
    # collected caches are layer-stacked like init_caches' layout
    ref = tf.init_caches(cfg, 2, 16, jnp.float32)
    assert jax.tree_util.tree_structure(caches) == \
        jax.tree_util.tree_structure(ref)
    for a, b in zip(jax.tree_util.tree_leaves(caches),
                    jax.tree_util.tree_leaves(ref)):
        assert a.shape == b.shape, (a.shape, b.shape)


# ---------------------------------------------------------------------------
# (d) hoisting protection preserved
# ---------------------------------------------------------------------------

def test_barrier_survives_in_lowered_module():
    """The lowered (pre-optimization) module must still pin (x, positions)
    once per stacked segment — removing grad_safe_barrier would zero it."""
    cfg = _cfg(n_layers=8)
    params = jax.eval_shape(lambda: tf.init_params(KEY, cfg))
    s = 32
    batch = dict(tokens=jax.ShapeDtypeStruct((2, s), jnp.int32),
                 positions=jax.ShapeDtypeStruct((s,), jnp.int32))
    txt = jax.jit(lambda p, b: tf.forward(p, cfg, b)[0]).lower(
        params, batch).as_text()
    assert txt.count("optimization_barrier") >= 2  # client + server segment


def test_no_layer_stacked_mask_buffer_in_hlo():
    """Compiled HLO for a stacked-layer forward must not contain an
    attention-mask buffer widened over the layer axis (the regression the
    barrier exists to prevent: a (layers, S, S)-shaped table)."""
    cfg = _cfg(n_layers=8)
    n_server = max(n for _, n in cfg.client_server_segments()[1])
    assert n_server >= 4  # the test needs a real stacked segment
    params = jax.eval_shape(lambda: tf.init_params(KEY, cfg))
    s = 64
    batch = dict(tokens=jax.ShapeDtypeStruct((2, s), jnp.int32),
                 positions=jax.ShapeDtypeStruct((s,), jnp.int32))
    hlo = jax.jit(lambda p, b: tf.forward(p, cfg, b)[0]).lower(
        params, batch).compile().as_text()
    # sanity: the analyzer walks the module (scan bodies present)
    res = analyze(hlo)
    assert res["n_computations"] > 1
    stacked_mask = re.compile(
        r"\[(?:%d|%d),(?:[0-9,]+,)?%d,%d\]" % (n_server, cfg.n_layers,
                                               s, s))
    hits = [m.group(0) for m in stacked_mask.finditer(hlo)]
    assert not hits, f"layer-stacked mask buffers in HLO: {hits[:5]}"
