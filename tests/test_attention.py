"""Flash attention (custom VJP) vs naive reference; decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers.attention import (decode_attention, flash_attention,
                                           gqa_decode, gqa_forward,
                                           init_attention_params,
                                           init_kv_cache)


def naive_attention(q, k, v, window=None):
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qf = q.astype(jnp.float32).reshape(b, sq, kh, g, d) * d ** -0.5
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    qp = jnp.arange(sq)
    kp = jnp.arange(k.shape[1])
    m = kp[None, :] <= qp[:, None]
    if window is not None:
        m &= qp[:, None] - kp[None, :] < window
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, v.shape[-1])


@pytest.mark.parametrize("sq,h,kh,d,dv,window,chunk", [
    (96, 4, 2, 16, 16, None, 32),
    (96, 4, 2, 16, 16, 48, 32),
    (100, 4, 4, 8, 12, None, 32),   # unaligned length, MLA-style dv != d
    (64, 8, 2, 32, 32, 16, 16),
])
def test_flash_forward_and_grad(sq, h, kh, d, dv, window, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, sq, h, d))
    k = jax.random.normal(ks[1], (2, sq, kh, d))
    v = jax.random.normal(ks[2], (2, sq, kh, dv))
    out = flash_attention(q, k, v, window=window, q_chunk=chunk,
                          kv_chunk=chunk)
    ref = naive_attention(q, k, v, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    f = lambda q, k, v: (flash_attention(
        q, k, v, window=window, q_chunk=chunk, kv_chunk=chunk) ** 2).sum()
    fr = lambda q, k, v: (naive_attention(q, k, v, window) ** 2).sum()
    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_flash_bf16():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (2, 64, 2, 16)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (2, 64, 2, 16)).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, q_chunk=32, kv_chunk=32)
    assert out.dtype == jnp.bfloat16
    ref = naive_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=5e-2)


@pytest.mark.parametrize("window", [None, 8])
def test_decode_matches_full_attention(window):
    """Step-by-step ring-buffer decode == full-sequence attention."""
    d_model, h, kh, hd, s = 32, 4, 2, 8, 12
    params = init_attention_params(jax.random.PRNGKey(0), d_model, h, kh, hd)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, d_model))
    positions = jnp.arange(s)
    full = gqa_forward(params, x, n_heads=h, n_kv_heads=kh, head_dim=hd,
                       rope_theta=1e4, positions=positions, window=window)

    cache_len = s if window is None else window
    cache = init_kv_cache(2, cache_len, kh, hd, jnp.float32)
    outs = []
    for t in range(s):
        qpos = jnp.full((2,), t, jnp.int32)
        y, cache = gqa_decode(params, x[:, t:t + 1], cache, n_heads=h,
                              n_kv_heads=kh, head_dim=hd, rope_theta=1e4,
                              qpos=qpos, window=window)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               atol=2e-4)


def test_kv_valid_len_masks_padding():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 8))
    k = jax.random.normal(ks[1], (1, 32, 2, 8))
    v = jax.random.normal(ks[2], (1, 32, 2, 8))
    out_full = flash_attention(q[:, :16], k[:, :16], v[:, :16],
                               q_chunk=16, kv_chunk=16)
    out_lim = flash_attention(q[:, :16], k, v, kv_valid_len=16,
                              q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(out_lim), np.asarray(out_full),
                               atol=1e-5)
