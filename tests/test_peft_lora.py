"""SplitLoRA: adapter init/apply/merge, checkpoints, optimizer sizing,
merged serving parity, and the SPMD adapter-grad wire (subprocess).

The structural site rule (``w*`` leaves, last two axes = (d_in, d_out),
leading axes batched) must hold across the arch zoo — dense GQA
(llama3), MLA factored projections (minicpm3), and MoE expert banks
(arctic) — without touching per-arch forward code; the merged weights
must be bit-identical to the effective weights the training forward
used; and the lockstep trainers must freeze the base bitwise while the
optimizer state shrinks to the adapter params.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_adapters, save_adapters
from repro.configs import get_config
from repro.core.split_stage import init_stage_params, run_blocks
from repro.models import transformer as tf
from repro.optim import AdamWConfig, param_bytes
from repro.peft import (adapter_bytes, adapter_param_count, apply_lora,
                        init_lora_params, lora_sites, merge_lora,
                        unmerge_lora)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KEY = jax.random.PRNGKey(0)

ZOO = ["llama3_2_3b", "minicpm3_4b", "arctic_480b"]  # GQA, MLA, MoE


def _run(code: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=420)


# ---------------------------------------------------------------------------
# sites + init
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ZOO)
def test_sites_cover_projections_only(arch):
    cfg = get_config(arch).reduced()
    params = tf.init_params(KEY, cfg)
    sites = lora_sites(params)
    assert sites, arch
    for path, leaf in sites:
        assert path[-1].startswith("w") and leaf.ndim >= 2
    names = {p[-1] for p, _ in sites}
    assert "router" not in names
    assert not any(n.startswith("ln") or n.endswith("norm")
                   for n in names)


@pytest.mark.parametrize("arch", ZOO)
def test_zero_init_is_identity_and_merge_changes_forward(arch):
    """B=0 adapters change nothing through the full arch forward; with a
    nonzero B the merged forward really moves — the structural site rule
    lands on weights each arch actually uses (GQA / MLA / MoE)."""
    cfg = get_config(arch).reduced()
    params = tf.init_params(KEY, cfg)
    batch = dict(tokens=jax.random.randint(jax.random.PRNGKey(1), (2, 8),
                                           0, cfg.vocab_size))

    base, _ = tf.forward(params, cfg, batch)
    ad0 = init_lora_params(jax.random.PRNGKey(2), params, rank=4)
    zero, _ = tf.forward(merge_lora(params, ad0), cfg, batch)
    np.testing.assert_array_equal(np.asarray(base, np.float32),
                                  np.asarray(zero, np.float32))

    ad = init_lora_params(jax.random.PRNGKey(2), params, rank=4,
                          b_scale=0.05)
    merged, _ = tf.forward(merge_lora(params, ad), cfg, batch)
    assert np.any(np.asarray(merged, np.float32)
                  != np.asarray(base, np.float32))


def test_scan_path_apply_matches_premerged_bitwise():
    """The stack executor's in-scan adapter path (slice (blocks,
    adapters) per layer, fold per slice) == pre-merged weights, bitwise
    — the invariant that makes merged serving token-exact."""
    cfg = get_config("llama3_2_3b").reduced()
    blocks = init_stage_params(KEY, cfg, 2)["blocks"]
    stage0 = jax.tree_util.tree_map(lambda a: a[0], blocks)
    ad = init_lora_params(jax.random.PRNGKey(2), stage0, rank=4,
                          b_scale=0.05)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (2, 8, cfg.d_model)).astype(tf.cdtype(cfg))
    pos = jnp.arange(8)
    eff = run_blocks(cfg, stage0, x, pos, adapters=ad)
    merged = run_blocks(cfg, merge_lora(stage0, ad), x, pos)
    np.testing.assert_array_equal(np.asarray(eff, np.float32),
                                  np.asarray(merged, np.float32))


@pytest.mark.parametrize("arch", ZOO)
def test_unmerge_recovers_base(arch):
    cfg = get_config(arch).reduced()
    params = tf.init_params(KEY, cfg)
    ad = init_lora_params(jax.random.PRNGKey(3), params, rank=8,
                          b_scale=0.05)
    back = unmerge_lora(merge_lora(params, ad), ad)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_adapter_checkpoint_small_and_bit_exact(tmp_path):
    cfg = get_config("llama3_2_3b").reduced()
    params = tf.init_params(KEY, cfg)
    ad = init_lora_params(jax.random.PRNGKey(4), params, rank=4,
                          b_scale=0.1)

    from repro.checkpoint import save
    full_path = tmp_path / "full.npz"
    ad_path = tmp_path / "adapters.npz"
    save(str(full_path), params)
    save_adapters(str(ad_path), ad)
    assert ad_path.stat().st_size < full_path.stat().st_size / 10

    template = jax.tree_util.tree_map(
        lambda a: jnp.zeros(a.shape, a.dtype), ad)
    back = load_adapters(str(ad_path), template)
    for a, b in zip(jax.tree_util.tree_leaves(ad),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a).view(np.uint16),
                                      np.asarray(b).view(np.uint16))

    with pytest.raises(ValueError, match="not an adapter tree"):
        save_adapters(str(tmp_path / "bad.npz"), params)


# ---------------------------------------------------------------------------
# adapter-only optimizer
# ---------------------------------------------------------------------------

def test_adapter_state_sized_by_adapters_and_base_frozen():
    from repro.train.loop import apply_adapter_gradients, init_adapter_state

    cfg = get_config("llama3_2_3b").reduced()
    params = init_stage_params(KEY, cfg, 2, lora_rank=4)
    opt_cfg = AdamWConfig(lr=1e-2, weight_decay=0.0)
    state = init_adapter_state(params, opt_cfg)

    assert param_bytes(state.opt["m"]) == adapter_bytes(params["adapters"])
    assert (param_bytes(state.opt["m"])
            < param_bytes(params) / 10)

    grads = jax.tree_util.tree_map(jnp.ones_like, params["adapters"])
    new_state, _ = apply_adapter_gradients(state, grads, opt_cfg)
    for k in params:
        if k == "adapters":
            continue
        for a, b in zip(jax.tree_util.tree_leaves(params[k]),
                        jax.tree_util.tree_leaves(new_state.params[k])):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
    moved = any(
        np.any(np.asarray(a, np.float32) != np.asarray(b, np.float32))
        for a, b in zip(
            jax.tree_util.tree_leaves(params["adapters"]),
            jax.tree_util.tree_leaves(new_state.params["adapters"])))
    assert moved

    with pytest.raises(ValueError, match="adapters"):
        init_adapter_state({"blocks": params["blocks"]}, opt_cfg)


def test_adapter_param_count_and_rank_scaling():
    cfg = get_config("llama3_2_3b").reduced()
    params = tf.init_params(KEY, cfg)
    n4 = adapter_param_count(init_lora_params(KEY, params, rank=4))
    n8 = adapter_param_count(init_lora_params(KEY, params, rank=8))
    assert n8 == 2 * n4 > 0


# ---------------------------------------------------------------------------
# merged serving parity
# ---------------------------------------------------------------------------

def test_engine_serves_merged_adapters_token_exact():
    """ServeEngine(lora_adapters=...) == generate on apply-path params."""
    import dataclasses

    from repro.core.quantizers import QuantConfig
    from repro.core.split import SplitConfig
    from repro.serve import decode as sd
    from repro.serve.engine import ServeEngine

    cfg = dataclasses.replace(
        get_config("llama3_2_3b").reduced(),
        split=SplitConfig(quant=QuantConfig(method="identity"),
                          learnable_codec=False, enabled=False))
    params = tf.init_params(KEY, cfg)
    ad = init_lora_params(jax.random.PRNGKey(5), params, rank=4,
                          b_scale=0.05)

    b, p, n_new, pg = 2, 8, 8, 4
    toks = np.random.default_rng(2).integers(
        1, cfg.vocab_size, size=(b, p)).astype(np.int32)
    ref = np.asarray(sd.generate(apply_lora(params, ad), cfg,
                                 dict(tokens=jnp.asarray(toks)),
                                 n_new=n_new, cache_len=16))
    eng = ServeEngine(params, cfg, n_slots=b, page_size=pg,
                      n_pages=1 + b * ((p + n_new) // pg),
                      lora_adapters=ad)
    rids = [eng.submit(list(toks[i]), max_new=n_new) for i in range(b)]
    res = eng.run()
    np.testing.assert_array_equal(np.stack([res[r] for r in rids]), ref)


# ---------------------------------------------------------------------------
# SPMD: the adapter-grad wire (subprocess, 8 fake devices)
# ---------------------------------------------------------------------------

def test_spmd_lora_pipeline_trains_base_frozen():
    """train_pipeline(lora_rank=4): loss down, base bit-frozen, moments
    sized by the adapters — the full dry-run assertion set."""
    r = _run("""
        from repro.launch.split_pipeline import dryrun_lora_train
        out = dryrun_lora_train(n_steps=4)
        assert out["loss_history"][-1] < out["loss_history"][0]
        print("PIPELINE_LORA_OK")
    """)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "PIPELINE_LORA_OK" in r.stdout


def test_spmd_hub_adapter_grad_wire_matches_hlo():
    """The hub's quantized gradient return shrinks to the adapter-grad
    payload, verified against the compiled HLO per link and direction."""
    r = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.core.quantizers import QuantConfig
        from repro.core.split import HubConfig
        from repro.launch.split_hub import (build_hub_grad_step, hub_mesh,
                                            hub_wire_bytes, init_hub_params)
        from repro.launch.split_pipeline import assert_links_match_hlo

        cfg = get_config("llama3_2_3b").reduced()
        n_clients, n_micro, mb, seq, rank = 3, 2, 4, 16, 4
        hub = HubConfig(
            n_clients=n_clients,
            quant=QuantConfig(method="rdfsq", bits=2),
            grad_quant=QuantConfig(method="rdfsq", bits=8,
                                   stats_axis="tensor"))
        mesh = hub_mesh(n_clients)
        params_sds = jax.eval_shape(
            lambda: init_hub_params(jax.random.PRNGKey(0), cfg, hub,
                                    lora_rank=rank))
        tok = jax.ShapeDtypeStruct((n_micro, n_clients, mb, seq),
                                   jnp.int32)
        step = build_hub_grad_step(cfg, mesh, hub, n_micro, mb, seq,
                                   lora_rank=rank)
        with mesh:
            hlo = jax.jit(step).lower(params_sds, tok,
                                      tok).compile().as_text()
        wire = hub_wire_bytes(cfg, hub, mb, seq,
                              data_shards=mesh.shape["data"],
                              lora_rank=rank)
        assert all(v["grad"] > 0 for v in wire["links"].values())
        assert_links_match_hlo("test hub lora", hlo, mesh, wire,
                               n_micro + 1, check_bwd=True,
                               check_grad=True)
        print("HUB_GRAD_WIRE_OK")
    """)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "HUB_GRAD_WIRE_OK" in r.stdout


def test_async_lora_hub_trains_in_process():
    """Mesh-free async LoRA hub: adapters move, base stays bit-frozen,
    losses finite, and the quantized grad roundtrip engages."""
    from repro.core.quantizers import QuantConfig
    from repro.core.split import HubConfig
    from repro.data.pipeline import make_pipeline
    from repro.launch.split_hub import train_hub

    cfg = get_config("llama3_2_3b").reduced()
    n, mb, seq = 2, 2, 16
    hub = HubConfig(n_clients=n,
                    quant=QuantConfig(method="rdfsq", bits=2),
                    grad_quant=QuantConfig(method="rdfsq", bits=8,
                                           stats_axis="tensor"))
    pipe = make_pipeline(cfg, n * mb, seq, seed=0)

    def batches():
        while True:
            b = next(pipe)
            yield (b["tokens"].reshape(n, mb, seq),
                   b["labels"].reshape(n, mb, seq))

    from repro.launch import schedules
    state0 = schedules.init_hub_state(jax.random.PRNGKey(0), cfg, hub,
                                      AdamWConfig(lr=1e-2,
                                                  weight_decay=0.0),
                                      lora_rank=2)
    client_base0 = jax.tree_util.tree_map(np.asarray,
                                          state0["client_params"])

    out = train_hub(cfg, hub, AdamWConfig(lr=1e-2, weight_decay=0.0),
                    batches(), micro_batch=mb, seq=seq, mode="async",
                    n_ticks=6, lora_rank=2)
    assert all(np.isfinite(v) for v in out["history"])
    state = out["state"]
    assert "client_adapters" in state
    for a, b in zip(jax.tree_util.tree_leaves(client_base0),
                    jax.tree_util.tree_leaves(state["client_params"])):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))
    moved = any(
        np.any(np.asarray(b, np.float32) != 0.0)
        for path, b in jax.tree_util.tree_leaves_with_path(
            state["client_adapters"])
        if "lora_b" in str(path[-1]))
    assert moved, "no adapter B factor moved after async LoRA ticks"
