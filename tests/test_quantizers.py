"""Unit tests for the paper's compression methods.

Hypothesis property tests live in ``test_quantizers_properties.py`` behind
``pytest.importorskip("hypothesis")`` so a missing optional dependency can't
abort collection of the whole tier-1 run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (QuantConfig, bits_per_scalar, decode, encode,
                        roundtrip)
from repro.core.packing import pack_bits, packed_size, storage_bits, \
    unpack_bits
from repro.core.quantizers.nf import nf_codebook

METHODS = ["fsq", "rdfsq", "nf", "topk"]


def _x(shape=(4, 64, 32), scale=2.0, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
@pytest.mark.parametrize("n", [1, 7, 64, 300])
def test_pack_roundtrip_exact(bits, n):
    rng = np.random.default_rng(bits * 1000 + n)
    codes = rng.integers(0, 2 ** bits, size=(n,)).astype(np.uint8)
    words = pack_bits(jnp.asarray(codes), bits)
    assert words.shape[0] == packed_size(n, bits)
    back = unpack_bits(words, bits, n)
    np.testing.assert_array_equal(np.asarray(back), codes)


def test_storage_bits():
    assert [storage_bits(b) for b in (1, 2, 3, 4, 5, 8)] == \
        [1, 2, 4, 4, 8, 8]


# ---------------------------------------------------------------------------
# wire form == in-graph form
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("bits", [1, 2, 4])
def test_roundtrip_matches_wire(method, bits):
    cfg = QuantConfig(method=method, bits=bits)
    x = _x()
    rng = jax.random.PRNGKey(1)
    p = encode(cfg, x, rng)
    x_wire = decode(cfg, p)
    x_rt, _ = roundtrip(cfg, x, rng)
    np.testing.assert_allclose(np.asarray(x_wire), np.asarray(x_rt),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("method,bits", [("fsq", 2), ("rdfsq", 2),
                                         ("nf", 2)])
def test_bits_per_scalar_near_nominal(method, bits):
    cfg = QuantConfig(method=method, bits=bits)
    x = _x((8, 64, 64))
    p = encode(cfg, x)
    bps = bits_per_scalar(p, x.size)
    # side-info overhead must be small (NF blockwise is the largest)
    assert bits <= bps < bits + 0.7


def test_identity_is_16bit():
    cfg = QuantConfig(method="identity")
    x = _x()
    p = encode(cfg, x)
    assert bits_per_scalar(p, x.size) == 16.0


# ---------------------------------------------------------------------------
# fidelity ordering (paper Section 3.2.2 claims)
# ---------------------------------------------------------------------------

def _rmse(a, b):
    return float(jnp.sqrt(jnp.mean((a - b) ** 2)))


@pytest.mark.parametrize("bits", [2, 4])
def test_rdfsq_beats_fsq(bits):
    """Linear scaling + exact range inversion must beat tanh saturation.

    (RMSE ordering holds for bits >= 2; at 1 bit RD-FSQ reconstructs to the
    clipped range endpoints, so its RMSE on Gaussian data is worse even
    though the paper's *task* metrics favor it — see Table 3.)"""
    x = _x((8, 32, 64), scale=3.0)
    e_fsq = _rmse(roundtrip(QuantConfig(method="fsq", bits=bits), x)[0], x)
    e_rd = _rmse(roundtrip(QuantConfig(method="rdfsq", bits=bits), x)[0], x)
    assert e_rd < e_fsq


def test_more_bits_less_error():
    x = _x()
    for method in ("rdfsq", "nf", "fsq"):
        errs = [_rmse(roundtrip(QuantConfig(method=method, bits=b), x)[0], x)
                for b in (1, 2, 4, 8)]
        assert errs == sorted(errs, reverse=True), (method, errs)


def test_rdfsq_error_bounded_by_bin():
    """Quantization error within the clipped range <= one bin width."""
    cfg = QuantConfig(method="rdfsq", bits=4, clip_sigma=100.0)  # no clip
    x = _x((4, 256))
    x_hat, _ = roundtrip(cfg, x)
    lo = x.min(axis=1, keepdims=True)
    hi = x.max(axis=1, keepdims=True)
    bin_w = (hi - lo) / (2 ** 4 - 1)
    assert float(jnp.max(jnp.abs(x_hat - x) / bin_w)) < 1.01


# ---------------------------------------------------------------------------
# STE + commitment loss
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", METHODS)
def test_ste_gradient_is_identity(method):
    cfg = QuantConfig(method=method, bits=2, commit_alpha=0.0)
    x = _x((2, 32))

    def f(x):
        y, _ = roundtrip(cfg, x, jax.random.PRNGKey(0))
        return jnp.sum(y * 3.0)

    g = jax.grad(f)(x)
    np.testing.assert_allclose(np.asarray(g), 3.0, atol=1e-5)


def test_commitment_loss_positive_and_differentiable():
    cfg = QuantConfig(method="rdfsq", bits=1)
    x = _x((4, 128))

    def f(x):
        _, commit = roundtrip(cfg, x)
        return commit

    val = f(x)
    assert 0.0 < float(val) < 2.0
    g = jax.grad(f)(x)
    assert float(jnp.max(jnp.abs(g))) > 0.0  # flows into the client


def test_commitment_smaller_at_higher_bits():
    x = _x((4, 256))
    c1 = float(roundtrip(QuantConfig(method="rdfsq", bits=1), x)[1])
    c4 = float(roundtrip(QuantConfig(method="rdfsq", bits=4), x)[1])
    assert c4 < c1


# ---------------------------------------------------------------------------
# NF codebook properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [1, 2, 3, 4])
def test_nf_codebook(bits):
    book = np.asarray(nf_codebook(bits))
    assert book.shape == (2 ** bits,)
    assert np.all(np.diff(book) > 0)  # strictly increasing
    assert 0.0 in book  # exact zero representable
    assert book.min() >= -1.0 and book.max() <= 1.0
    assert book.max() == 1.0


def test_nf4_matches_qlora_reference():
    """NF4 levels close to Dettmers et al. published NF4 values."""
    ref = np.array([-1.0, -0.6961928, -0.5250731, -0.39491748, -0.28444138,
                    -0.18477343, -0.09105003, 0.0, 0.07958029, 0.16093019,
                    0.24611232, 0.33791524, 0.44070983, 0.5626170,
                    0.72295684, 1.0])
    book = np.asarray(nf_codebook(4))
    np.testing.assert_allclose(book, ref, atol=2e-2)


# ---------------------------------------------------------------------------
# quantize(dequantize(quantize(x))) stability (fixed seeds; the hypothesis
# property versions live in test_quantizers_properties.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 17])
@pytest.mark.parametrize("bits,method", [(2, "rdfsq"), (4, "rdfsq"),
                                         (2, "nf"), (4, "nf")])
def test_double_quantize_idempotent(seed, bits, method):
    """Re-quantizing a reconstruction reproduces (nearly) the same values."""
    cfg = QuantConfig(method=method, bits=bits)
    x = _x((2, 64), seed=seed)
    y1, _ = roundtrip(cfg, x)
    y2, _ = roundtrip(cfg, y1)
    assert _rmse(y1, y2) < 0.25 * _rmse(x, y1) + 1e-4


@pytest.mark.parametrize("seed", [0, 3, 11])
def test_topk_preserves_largest(seed):
    cfg = QuantConfig(method="topk", bits=2, rand_frac=0.0)
    x = _x((2, 64), seed=seed)
    x_hat, _ = roundtrip(cfg, x, jax.random.PRNGKey(seed))
    flat = np.abs(np.asarray(x).reshape(2, -1))
    kept = np.asarray(x_hat).reshape(2, -1) != 0
    k = kept[0].sum()
    for b in range(2):
        top_idx = np.argsort(-flat[b])[:k]
        assert kept[b][top_idx].all()
