"""Weight-only serving quantization (repro.wq): packing errors, the
shared structural site rule, fused-kernel parity vs the jnp oracle,
GPTQ-vs-RTN held-out fidelity, bit-exact packed checkpoints, the
quantized ServeEngine, and the hub's quantized server stage."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import wq
from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.core import packing
from repro.core import split_stage as ss
from repro.data.pipeline import make_pipeline
from repro.kernels import ref, wq_kernel
from repro.models import transformer as tf
from repro.peft import lora_sites
from repro.serve.engine import ServeEngine
from repro.utils.tree import weight_sites


def _cfg():
    return get_config("tinyllava").reduced()


# ---------------------------------------------------------------------------
# satellite: core.packing ragged-tail hardening
# ---------------------------------------------------------------------------

def test_unpack_bits_exact_ragged_tail_roundtrip():
    for n, bits in ((13, 3), (100, 4), (7, 2), (8, 5)):
        codes = jnp.arange(n, dtype=jnp.uint8) % (1 << bits)
        flat = packing.pack_bits(codes, bits)
        assert flat.shape[0] == packing.packed_size(n, bits)
        out = packing.unpack_bits(flat, bits, n)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


def test_unpack_bits_rejects_short_stream():
    codes = jnp.arange(100, dtype=jnp.uint8) % 8
    flat = packing.pack_bits(codes, 3)
    with pytest.raises(ValueError, match="zero-fill"):
        packing.unpack_bits(flat[:-1], 3, 100)


def test_unpack_bits_rejects_oversized_stream():
    with pytest.raises(ValueError):
        packing.unpack_bits(jnp.zeros(1000, jnp.uint8), 3, 16)


# ---------------------------------------------------------------------------
# satellite: one structural site rule shared by peft and wq
# ---------------------------------------------------------------------------

def test_peft_and_wq_select_identical_sites():
    params = tf.init_params(jax.random.PRNGKey(0), _cfg())
    for sub in ("client", "server"):
        peft_paths = [p for p, _ in lora_sites(params[sub])]
        wq_paths = [p for p, _ in weight_sites(params[sub])]
        assert peft_paths == wq_paths and peft_paths


# ---------------------------------------------------------------------------
# kernel parity: fused Pallas dequant-matmul vs jnp oracle vs dense
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits,group,d_in,d_out",
                         [(4, 128, 256, 384), (3, 32, 256, 130),
                          (4, 32, 100, 128), (2, 32, 64, 96)])
def test_fused_matmul_matches_oracle_and_dense(bits, group, d_in, d_out):
    cfg = wq.WqConfig(bits=bits, group=group)
    w = jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out)) * 0.3
    packed = wq.rtn_quantize(w, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (9, d_in))
    y_jnp = wq.wq_matmul(x, packed, impl="jnp")
    y_pl = wq.wq_matmul(x, packed, impl="pallas")
    y_dense = x @ packed.dequantize().astype(x.dtype)
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_pl),
                               atol=1e-4, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_dense),
                               atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("bits", [4, 3])
def test_fused_matmul_act_order_parity(bits):
    d_in, d_out = 128, 96
    cfg = wq.WqConfig(bits=bits, group=32, act_order=True)
    w = jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out)) * 0.3
    X = jax.random.normal(jax.random.PRNGKey(1), (256, d_in))
    H = np.asarray(X.T @ X)
    packed = wq.gptq_quantize(w, H, cfg)
    assert packed.perm is not None
    x = jax.random.normal(jax.random.PRNGKey(2), (5, d_in))
    y_jnp = wq.wq_matmul(x, packed, impl="jnp")
    y_pl = wq.wq_matmul(x, packed, impl="pallas")
    y_dense = x @ packed.dequantize().astype(x.dtype)  # original order
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_pl),
                               atol=1e-4, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_dense),
                               atol=1e-4, rtol=1e-5)


def test_oracle_unpack_matches_core_packing():
    # the per-column bitstream IS core.packing's exact stream
    d_in, bits = 100, 3
    codes = jax.random.randint(jax.random.PRNGKey(0), (d_in, 5), 0,
                               1 << bits).astype(jnp.uint8)
    from repro.wq.packed import pack_weight_codes
    words = pack_weight_codes(codes, bits)
    col = packing.pack_bits(codes[:, 2], bits)
    np.testing.assert_array_equal(np.asarray(words[:, 2]), np.asarray(col))
    back = ref.wq_unpack_ref(words, bits, d_in)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


def test_matmul_rejects_stacked_and_mismatched():
    cfg = wq.WqConfig(bits=4, group=32)
    w = jax.random.normal(jax.random.PRNGKey(0), (2, 64, 32))
    stacked = wq.quantize_linear(w, cfg)
    with pytest.raises(ValueError, match="stacked"):
        wq.wq_matmul(jnp.zeros((3, 64)), stacked)
    flat = wq.quantize_linear(w[0], cfg)
    with pytest.raises(ValueError, match="feature dim"):
        wq.wq_matmul(jnp.zeros((3, 65)), flat)


# ---------------------------------------------------------------------------
# GPTQ error compensation: held-out improvement over RTN
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [4, 3])
def test_gptq_beats_rtn_on_heldout_reconstruction(bits):
    # correlated inputs (trained nets' anisotropic feature spectra) are
    # where Hessian compensation pays; held-out split guards against
    # calibration overfit
    d_in, d_out = 128, 96
    A = jax.random.normal(jax.random.PRNGKey(0), (d_in, d_in)) * 0.15
    Xc = jax.random.normal(jax.random.PRNGKey(1), (2048, d_in)) @ A
    Xh = jax.random.normal(jax.random.PRNGKey(2), (512, d_in)) @ A
    w = jax.random.normal(jax.random.PRNGKey(3), (d_in, d_out)) * 0.3
    cfg = wq.WqConfig(bits=bits, group=32)
    H = np.asarray(Xc.T @ Xc)

    def heldout_err(p):
        return float(jnp.linalg.norm(Xh @ (p.dequantize() - w)))

    e_rtn = heldout_err(wq.rtn_quantize(w, cfg))
    e_gptq = heldout_err(wq.gptq_quantize(w, H, cfg))
    assert e_gptq < 0.85 * e_rtn, (e_gptq, e_rtn)


def test_gptq_model_level_heldout_kl_beats_rtn():
    cfg = _cfg()
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    # power-law feature spectrum (random init is white, which makes GPTQ
    # degenerate to RTN by construction — the compensation term is zero
    # in expectation under an isotropic Hessian)
    d = cfg.d_model
    col = (1.0 / jnp.sqrt(1.0 + jnp.arange(d, dtype=jnp.float32))) * 3.0
    scale = lambda x: x * col if getattr(x, "ndim", 0) >= 1 and \
        x.shape[-1] == d else x  # noqa: E731
    for k in ("embed", "connector"):
        params[k] = jax.tree_util.tree_map(scale, params[k])

    calib = next(make_pipeline(cfg, 16, 64))
    held = next(make_pipeline(cfg, 4, 48, seed=123))
    hessians = wq.collect_hessians(params, cfg, calib)
    wcfg = wq.parse_weight_quant("int3", group=32)
    gq, _ = wq.quantize_params(params, wcfg, hessians=hessians)
    rt, _ = wq.quantize_params(params, wcfg)

    ld, _ = tf.forward(params, cfg, held)
    pd = jax.nn.log_softmax(ld.astype(jnp.float32))

    def kl(qp):
        lq, _ = tf.forward(qp, cfg, held)
        pq = jax.nn.log_softmax(lq.astype(jnp.float32))
        return float((jnp.exp(pd) * (pd - pq)).sum(-1).mean())

    k_gptq, k_rtn = kl(gq), kl(rt)
    assert k_gptq < k_rtn, (k_gptq, k_rtn)


# ---------------------------------------------------------------------------
# packed checkpoint roundtrip (bit-exact)
# ---------------------------------------------------------------------------

def test_packed_checkpoint_roundtrip_bit_exact(tmp_path):
    cfg = _cfg()
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    wcfg = wq.parse_weight_quant("int4", group=128, act_order=True)
    calib = next(make_pipeline(cfg, 2, 16))
    hs = wq.collect_hessians(params, cfg, calib)
    qp, _ = wq.quantize_params(params, wcfg, hessians=hs)

    path = str(tmp_path / "wq.npz")
    ckpt.save(path, qp)
    back = ckpt.restore(path, jax.tree_util.tree_map(jnp.zeros_like, qp))

    flat_a = jax.tree_util.tree_flatten_with_path(qp)[0]
    flat_b = jax.tree_util.tree_flatten_with_path(back)[0]
    assert len(flat_a) == len(flat_b)
    for (pa, a), (pb, b) in zip(flat_a, flat_b):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # structure survives too: packed stores are still PackedLinear
    stores = jax.tree_util.tree_leaves(
        back["server"], is_leaf=lambda x: isinstance(x, wq.PackedLinear))
    assert any(isinstance(s, wq.PackedLinear) for s in stores)
    site = back["server"]["seg0"]["attn"]["wq"]
    assert isinstance(site, wq.PackedLinear) and site.perm is not None


# ---------------------------------------------------------------------------
# quantized ServeEngine: token-level KL bound vs the dense engine
# ---------------------------------------------------------------------------

class _LogitTap(ServeEngine):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.picked = []

    def _pick(self, last_logits):
        self.picked.append(np.array(last_logits, np.float32))
        return super()._pick(last_logits)


def test_engine_int4_prefill_kl_bounded():
    cfg = _cfg()
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    b, p, n_new, pg = 8, 16, 2, 8
    n_pages = 1 + b * (-(-(cfg.n_image_tokens + p + n_new) // pg))
    calib = next(make_pipeline(cfg, 8, 32))
    # serve requests drawn from the calibration distribution (in-domain
    # prompts — what a real deployment quantizes for)
    req = next(make_pipeline(cfg, b, p, seed=9))
    toks = np.asarray(req["tokens"])
    imgs = np.asarray(req["image_embeds"], np.float32)

    def run(**kw):
        eng = _LogitTap(params, cfg, n_slots=b, page_size=pg,
                        n_pages=n_pages, **kw)
        for i in range(b):
            eng.submit(list(toks[i]), max_new=n_new, image_embeds=imgs[i])
        eng.run()
        return eng

    dense = run()
    quant = run(weight_quant="int4", wq_calib=calib)
    assert quant.stats["weight_bytes_packed"] * 3.7 <= \
        quant.stats["weight_bytes_dense"]
    # both engines admit all b requests in one prefill batch, so the
    # first _pick sees the same prompts — compare those token-level
    # distributions (decode ticks diverge once sampled tokens differ)
    ld, lq = dense.picked[0], quant.picked[0]
    assert ld.shape == lq.shape == (b, cfg.vocab_size)
    pd = jax.nn.log_softmax(jnp.asarray(ld))
    pq = jax.nn.log_softmax(jnp.asarray(lq))
    kl = float((jnp.exp(pd) * (pd - pq)).sum(-1).mean())
    # ~0.13 measured across seeds at int4/g128 on the random-init reduced
    # model (single next-token position, the sharpest comparison); dense
    # vs dense is exactly 0 and int3 lands several times higher
    assert 0.0 <= kl < 0.3, kl


# ---------------------------------------------------------------------------
# hub: quantized shared server stage for inference-only clients
# ---------------------------------------------------------------------------

def test_hub_quantized_server_stage_ce_close():
    cfg = _cfg()
    n_clients = 2
    sp = ss.init_stage_params(jax.random.PRNGKey(0), cfg, n_clients + 1,
                              per_stage=cfg.n_layers // 2)
    server = ss.hub_programs(cfg, n_clients)[-1]
    qblocks, report = ss.quantized_stage_blocks(sp, server, "int4",
                                                group=128)
    assert report and all(p < d for d, p in report.values())
    dense = jax.tree_util.tree_map(lambda v: v[server.index], sp["blocks"])

    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model)) * 0.4
    pos = jnp.arange(24, dtype=jnp.int32)
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 24), 0,
                                cfg.vocab_size)
    h_d = ss.run_blocks(cfg, dense, x, pos)
    h_q = ss.run_blocks(cfg, qblocks, x, pos)
    ce_d = float(ss.head_ce(cfg, sp, h_d, labels))
    ce_q = float(ss.head_ce(cfg, sp, h_q, labels))
    assert abs(ce_d - ce_q) < 0.1, (ce_d, ce_q)


# ---------------------------------------------------------------------------
# config parsing / validation
# ---------------------------------------------------------------------------

def test_parse_weight_quant_and_validation():
    c = wq.parse_weight_quant("int3", group=32, act_order=True)
    assert dataclasses.astuple(c) == (3, 32, True)
    with pytest.raises(ValueError):
        wq.parse_weight_quant("int9")
    with pytest.raises(ValueError):
        wq.WqConfig(bits=4, group=12)  # not a multiple of 8
