"""Wire codec coverage: pallas-vs-jnp dispatch (mirroring
test_attention_pallas.py), quantized_ship-vs-roundtrip parity for every
registered method, and pack/unpack properties for odd bit widths."""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import packing
from repro.core import quantizers as Q
from repro.core.quantizers import QuantConfig
from repro.core.split import SplitConfig, compressor_roundtrip, \
    quantized_ship, wire_payload

KEY = jax.random.PRNGKey(0)


def _x(shape, dtype=jnp.float32, seed=0, scale=3.0):
    return (jax.random.normal(jax.random.PRNGKey(seed), shape) * scale
            ).astype(dtype)


# ---------------------------------------------------------------------------
# packing: odd widths ride in their storage slots
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [1, 2, 3, 4, 5, 6, 7, 8])
@pytest.mark.parametrize("n", [1, 7, 64, 257])
def test_pack_unpack_roundtrip_all_widths(bits, n):
    codes = jax.random.randint(jax.random.PRNGKey(bits * 131 + n), (n,), 0,
                               2 ** bits).astype(jnp.uint8)
    words = packing.pack_bits(codes, bits)
    assert words.shape == (packing.packed_size(n, bits),)
    assert words.dtype == jnp.uint8
    out = packing.unpack_bits(words, bits, n)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


@pytest.mark.parametrize("bits,slot", [(3, 4), (5, 8), (6, 8), (7, 8)])
def test_odd_widths_pack_exactly(bits, slot):
    """Odd widths cost exactly ceil(n*b/8) on the wire — the pow2 slot
    only survives as the fused kernels' storage geometry."""
    assert packing.storage_bits(bits) == slot
    n = 123
    exact = -(-(n * bits) // 8)
    slotted = -(-n // (8 // slot))
    assert packing.packed_size(n, bits) == exact
    assert exact < slotted  # the bitstream strictly beats slot padding


@pytest.mark.parametrize("method", ["rdfsq", "nf", "fsq"])
@pytest.mark.parametrize("bits", [3, 5, 6, 7])
def test_quantizer_odd_widths_decode_encode(method, bits):
    """Odd widths flow through encode/decode/roundtrip end to end."""
    cfg = QuantConfig(method=method, bits=bits)
    x = _x((3, 129))
    x_hat = Q.decode(cfg, Q.encode(cfg, x))
    rt, _ = Q.roundtrip(cfg, x)
    np.testing.assert_allclose(np.asarray(x_hat), np.asarray(rt),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# pallas codec backend vs the jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 700), (8, 1024), (3, 257), (2, 16, 64)])
@pytest.mark.parametrize("bits", [2, 4])
def test_rdfsq_pallas_decode_matches_roundtrip(shape, bits):
    """decode(encode(x)) == roundtrip(x)[0] must hold per backend."""
    cfg = QuantConfig(method="rdfsq", bits=bits)
    x = _x(shape)
    payload = Q.encode(cfg, x, impl="pallas")
    assert payload.meta["impl"] == "pallas"
    x_hat = Q.decode(cfg, payload)
    rt, _ = Q.roundtrip(cfg, x)
    np.testing.assert_allclose(np.asarray(x_hat), np.asarray(rt),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("double_quant", [False, True])
def test_nf_pallas_decode_matches_roundtrip(bits, double_quant):
    cfg = QuantConfig(method="nf", bits=bits, double_quant=double_quant)
    x = _x((4, 700))
    payload = Q.encode(cfg, x, impl="pallas")
    assert payload.meta["impl"] == "pallas"
    x_hat = Q.decode(cfg, payload)
    rt, _ = Q.roundtrip(cfg, x)
    # the kernel emits fp16 block ranges before double-quant; same
    # tolerance class as test_kernels.test_nf_kernel_matches_core_quantizer
    np.testing.assert_allclose(np.asarray(x_hat), np.asarray(rt),
                               atol=0.1, rtol=5e-2)


def test_pallas_payload_bytes_match_jnp():
    """Same wire cost when rows pack cleanly (shape divisible)."""
    x = _x((4, 1024))
    for method, atol in (("rdfsq", 0), ("nf", 0)):
        cfg = QuantConfig(method=method, bits=2)
        bj = Q.encode(cfg, x, impl="jnp").wire_bytes()
        bp = Q.encode(cfg, x, impl="pallas").wire_bytes()
        assert bj == bp, (method, bj, bp)


def test_quant_env_dispatch(monkeypatch):
    """REPRO_QUANT_IMPL flips the backend with zero call-site churn."""
    cfg = QuantConfig(method="rdfsq", bits=2)
    x = _x((2, 256))
    monkeypatch.setenv("REPRO_QUANT_IMPL", "pallas")
    assert Q.resolve_impl(None) == "pallas"
    p = Q.encode(cfg, x)
    assert p.meta["impl"] == "pallas"
    # wire_payload (the Table-4 accounting entry point) picks it up too
    split = SplitConfig(quant=cfg, learnable_codec=False)
    assert wire_payload(split, None, x).meta["impl"] == "pallas"
    monkeypatch.setenv("REPRO_QUANT_IMPL", "jnp")
    assert Q.encode(cfg, x).meta["impl"] == "jnp"
    # a pallas payload still decodes with the pallas backend (the tag
    # travels with the payload, not the environment)
    x_hat = Q.decode(cfg, p)
    rt, _ = Q.roundtrip(cfg, x)
    np.testing.assert_allclose(np.asarray(x_hat), np.asarray(rt),
                               atol=1e-5, rtol=1e-5)
    monkeypatch.setenv("REPRO_QUANT_IMPL", "tpu-magic")
    with pytest.raises(ValueError):
        Q.resolve_impl(None)
    with pytest.raises(ValueError):
        Q.resolve_impl("cuda")


def test_stage_quants_length_validated():
    ok = SplitConfig(n_stages=4,
                     stage_quants=(QuantConfig(), QuantConfig(),
                                   QuantConfig(method="nf")))
    assert len(ok.resolve_stage_quants()) == 3
    assert SplitConfig(n_stages=3).resolve_stage_quants() == \
        (SplitConfig().quant,) * 2
    with pytest.raises(ValueError):
        SplitConfig(n_stages=4, stage_quants=(QuantConfig(),)
                    ).resolve_stage_quants()


def test_unsupported_configs_fall_back_to_jnp():
    x = _x((2, 64, 8))
    p = Q.encode(QuantConfig(method="rdfsq", bits=2, stats_axis="tensor"),
                 x, impl="pallas")
    assert p.meta["impl"] == "jnp"  # kernel stats are per sample row
    p = Q.encode(QuantConfig(method="nf", bits=2, block_size=3), x,
                 impl="pallas")
    assert p.meta["impl"] == "jnp"  # rows would straddle packed words


# ---------------------------------------------------------------------------
# the wire itself: quantized_ship == compressor_roundtrip numerics
# ---------------------------------------------------------------------------

def _ship_self(qcfg, x):
    """quantized_ship under the identity permutation on a 1-pod mesh."""
    mesh = jax.make_mesh((1,), ("pod",))

    @partial(shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
             check_rep=False)
    def ship(x):
        return quantized_ship(qcfg, x, "pod", ((0, 0),))

    with mesh:
        return jax.jit(ship)(x)


@pytest.mark.parametrize("method", sorted(Q.methods()))
def test_quantized_ship_matches_compressor_roundtrip(method):
    """The real wire (encode -> ppermute -> decode) reproduces the
    in-graph STE roundtrip for every registered method."""
    qcfg = QuantConfig(method=method, bits=2)
    split = SplitConfig(quant=qcfg, learnable_codec=False)
    x = _x((4, 8, 64))
    y_wire = _ship_self(qcfg, x)
    y_graph, _ = compressor_roundtrip(None, split, x)
    np.testing.assert_allclose(np.asarray(y_wire), np.asarray(y_graph),
                               atol=1e-6, rtol=1e-6)


def test_quantized_ship_pallas_backend(monkeypatch):
    """The ship picks the pallas codecs up through the env var."""
    monkeypatch.setenv("REPRO_QUANT_IMPL", "pallas")
    qcfg = QuantConfig(method="rdfsq", bits=2)
    x = _x((4, 8, 64))
    y_wire = _ship_self(qcfg, x)
    rt, _ = Q.roundtrip(qcfg, x)
    np.testing.assert_allclose(np.asarray(y_wire), np.asarray(rt),
                               atol=1e-5, rtol=1e-5)


def test_ship_wire_dtype_pinned():
    """The lowered ship must permute the packed uint8/uint16 words, not a
    widened float — XLA likes to reorder converts across collectives."""
    import re
    qcfg = QuantConfig(method="identity")
    mesh = jax.make_mesh((1,), ("pod",))

    @partial(shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
             check_rep=False)
    def ship(x):
        return quantized_ship(qcfg, x, "pod", ((0, 0),))

    x = _x((4, 64))  # f32 -> bf16 on the wire -> f32 back
    with mesh:
        hlo = jax.jit(ship).lower(x).compile().as_text()
    cps = re.findall(r"(\S+\[[0-9,]*\])\S*\s+collective-permute\(", hlo)
    assert cps, hlo
    for shape in cps:
        assert shape.startswith(("u16", "bf16")), cps