"""Pallas attention kernels (interpret mode) vs the jnp reference.

Covers the acceptance criteria of the flash-kernel tentpole: forward AND
``jax.grad`` parity across causal / sliding-window / GQA / MLA
(Dv != Dk) / ragged ``kv_valid_len`` shapes, bf16 operands, both decode
kernels (incl. ``decode_attention_q8`` vs a dequantize-then-attend
oracle), and the ``REPRO_ATTN_IMPL`` env-var dispatch end-to-end through
``gqa_decode``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import attention_ops
from repro.models.layers.attention import (decode_attention,
                                           decode_attention_q8,
                                           flash_attention, gqa_decode,
                                           gqa_forward,
                                           init_attention_params,
                                           init_kv_cache, quantize_kv_token)

KEY = jax.random.PRNGKey(0)


def _qkv(sq, h, kh, d, dv, skv=None, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    skv = sq if skv is None else skv
    q = jax.random.normal(ks[0], (2, sq, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (2, skv, kh, d)).astype(dtype)
    v = jax.random.normal(ks[2], (2, skv, kh, dv)).astype(dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# flash forward + grad parity (fp32-accumulation tolerance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq,h,kh,d,dv,window,chunk", [
    (96, 4, 2, 16, 16, None, 32),    # GQA causal
    (96, 4, 2, 16, 16, 48, 32),      # sliding window
    (100, 4, 4, 8, 12, None, 32),    # unaligned length, MLA-style dv != d
    (64, 8, 2, 32, 32, 16, 16),      # tight window, wide grouping
])
def test_flash_pallas_matches_reference(sq, h, kh, d, dv, window, chunk):
    q, k, v = _qkv(sq, h, kh, d, dv)
    out_ref = flash_attention(q, k, v, window=window, q_chunk=chunk,
                              kv_chunk=chunk, impl="jnp")
    out_pal = flash_attention(q, k, v, window=window, q_chunk=chunk,
                              kv_chunk=chunk, impl="pallas")
    np.testing.assert_allclose(np.asarray(out_pal), np.asarray(out_ref),
                               atol=2e-5)

    def loss(impl):
        return lambda q, k, v: (flash_attention(
            q, k, v, window=window, q_chunk=chunk, kv_chunk=chunk,
            impl=impl) ** 2).sum()

    g_pal = jax.grad(loss("pallas"), argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss("jnp"), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_pal, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_flash_pallas_bf16():
    q, k, v = _qkv(64, 4, 2, 16, 16, dtype=jnp.bfloat16, seed=1)
    out_pal = flash_attention(q, k, v, q_chunk=32, kv_chunk=32,
                              impl="pallas")
    assert out_pal.dtype == jnp.bfloat16
    out_ref = flash_attention(q, k, v, q_chunk=32, kv_chunk=32, impl="jnp")
    np.testing.assert_allclose(np.asarray(out_pal, np.float32),
                               np.asarray(out_ref, np.float32), atol=5e-2)


def test_flash_pallas_kv_valid_len_masks_padding():
    """Ragged KV: positions beyond kv_valid_len must be invisible."""
    q, k, v = _qkv(32, 2, 2, 8, 8, seed=2)
    out_full = flash_attention(q[:, :16], k[:, :16], v[:, :16], q_chunk=16,
                               kv_chunk=16, impl="pallas")
    out_lim = flash_attention(q[:, :16], k, v, kv_valid_len=16, q_chunk=16,
                              kv_chunk=16, impl="pallas")
    np.testing.assert_allclose(np.asarray(out_lim), np.asarray(out_full),
                               atol=1e-5)


def test_flash_pallas_inside_jit_and_runtime_positions():
    q, k, v = _qkv(64, 4, 2, 16, 16, seed=3)
    positions = jnp.arange(64, dtype=jnp.int32)

    @jax.jit
    def f(q, k, v, positions):
        return flash_attention(q, k, v, positions=positions, q_chunk=32,
                               kv_chunk=32, impl="pallas")

    out = f(q, k, v, positions)
    ref = flash_attention(q, k, v, positions=positions, q_chunk=32,
                          kv_chunk=32, impl="jnp")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ---------------------------------------------------------------------------
# decode kernels
# ---------------------------------------------------------------------------

def _ring_cache(b, length, kh, d, n_filled, seed=4):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    k_cache = jax.random.normal(ks[0], (b, length, kh, d))
    v_cache = jax.random.normal(ks[1], (b, length, kh, d))
    kpos = jnp.broadcast_to(jnp.arange(length, dtype=jnp.int32),
                            (b, length))
    kpos = jnp.where(kpos < n_filled, kpos, -1)  # unwritten slots
    return k_cache, v_cache, kpos


@pytest.mark.parametrize("b,length,kh,g,d,window", [
    (2, 24, 2, 2, 16, None),
    (2, 24, 2, 2, 16, 8),
    (1, 13, 1, 4, 8, None),   # odd ring length -> single-block fallback
    (3, 64, 2, 1, 32, 16),
])
def test_decode_pallas_matches_reference(b, length, kh, g, d, window):
    h = kh * g
    q = jax.random.normal(KEY, (b, 1, h, d))
    k_cache, v_cache, kpos = _ring_cache(b, length, kh, d, length - 3)
    qpos = jnp.full((b,), length - 4, jnp.int32)
    out_ref = decode_attention(q, k_cache, v_cache, kpos, qpos,
                               window=window, impl="jnp")
    out_pal = decode_attention(q, k_cache, v_cache, kpos, qpos,
                               window=window, impl="pallas")
    np.testing.assert_allclose(np.asarray(out_pal), np.asarray(out_ref),
                               atol=1e-5)


@pytest.mark.parametrize("window", [None, 8])
def test_decode_q8_pallas_vs_dequantize_then_attend(window):
    """Fused int8 decode == dequantize the cache, then bf16-path attend."""
    b, length, kh, g, d = 2, 32, 2, 2, 16
    h = kh * g
    q = jax.random.normal(KEY, (b, 1, h, d))
    k_cache, v_cache, kpos = _ring_cache(b, length, kh, d, length - 5)
    qpos = jnp.full((b,), length - 6, jnp.int32)
    k_codes, k_scale = quantize_kv_token(k_cache)
    v_codes, v_scale = quantize_kv_token(v_cache)

    out_pal = decode_attention_q8(q, k_codes, v_codes, k_scale, v_scale,
                                  kpos, qpos, window=window, impl="pallas")
    # oracle: materialize the dequantized cache, run the plain jnp path
    k_deq = k_codes.astype(jnp.float32) * \
        k_scale.astype(jnp.float32)[..., None]
    v_deq = v_codes.astype(jnp.float32) * \
        v_scale.astype(jnp.float32)[..., None]
    out_deq = decode_attention(q, k_deq, v_deq, kpos, qpos, window=window,
                               impl="jnp")
    np.testing.assert_allclose(np.asarray(out_pal), np.asarray(out_deq),
                               atol=1e-4)
    # and against the fused jnp reference (same wire math)
    out_ref = decode_attention_q8(q, k_codes, v_codes, k_scale, v_scale,
                                  kpos, qpos, window=window, impl="jnp")
    np.testing.assert_allclose(np.asarray(out_pal), np.asarray(out_ref),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# dispatch + end-to-end
# ---------------------------------------------------------------------------

def test_pick_block_vmem_safe():
    from repro.kernels.decode_kernel import MAX_BLOCK, pick_block
    assert pick_block(1024) == 512           # largest aligned divisor
    assert pick_block(24) == 24
    assert pick_block(13) == 13              # odd-but-small: one block
    assert pick_block(3000) == 200           # aligned beats tiny pow2
    assert pick_block(5 * 499) == 499        # no aligned divisor <= cap
    assert pick_block(100003) is None        # big prime: jnp fallback
    for n in (13, 24, 1024, 3000, 32768):
        blk = pick_block(n)
        assert blk is not None and blk <= MAX_BLOCK and n % blk == 0


def test_decode_prime_length_falls_back_to_reference():
    """Cache lengths with no VMEM-safe block must still work on the
    pallas path (silent jnp fallback inside attention_ops)."""
    b, length, kh, g, d = 1, 2053, 1, 2, 8  # 2053 is prime > MAX_BLOCK
    q = jax.random.normal(KEY, (b, 1, kh * g, d))
    k_cache, v_cache, kpos = _ring_cache(b, length, kh, d, 10)
    qpos = jnp.full((b,), 9, jnp.int32)
    out_pal = decode_attention(q, k_cache, v_cache, kpos, qpos,
                               impl="pallas")
    out_ref = decode_attention(q, k_cache, v_cache, kpos, qpos, impl="jnp")
    np.testing.assert_allclose(np.asarray(out_pal), np.asarray(out_ref),
                               atol=1e-5)


def test_serve_step_cache_keyed_by_attn_impl(monkeypatch):
    """Flipping REPRO_ATTN_IMPL between generate() calls must not reuse
    the other backend's compiled step."""
    from repro.serve import decode as sd
    monkeypatch.setenv("REPRO_ATTN_IMPL", "jnp")
    impl_a = attention_ops.resolve_impl(None)
    monkeypatch.setenv("REPRO_ATTN_IMPL", "pallas")
    impl_b = attention_ops.resolve_impl(None)
    from repro.configs import get_config
    cfg = get_config("llama3_2_3b").reduced()
    step_a = sd._compiled_serve_step(cfg, None, impl_a)
    step_b = sd._compiled_serve_step(cfg, None, impl_b)
    assert step_a is not step_b
    assert sd._compiled_serve_step(cfg, None, impl_a) is step_a


def test_resolve_impl_env_and_kwarg(monkeypatch):
    monkeypatch.delenv("REPRO_ATTN_IMPL", raising=False)
    default = attention_ops.resolve_impl(None)
    assert default == ("pallas" if jax.default_backend() == "tpu"
                       else "jnp")
    monkeypatch.setenv("REPRO_ATTN_IMPL", "pallas")
    assert attention_ops.resolve_impl(None) == "pallas"
    assert attention_ops.resolve_impl("jnp") == "jnp"  # kwarg wins
    monkeypatch.setenv("REPRO_ATTN_IMPL", "nope")
    with pytest.raises(ValueError):
        attention_ops.resolve_impl(None)


@pytest.mark.parametrize("bits", [16, 8])
def test_env_forced_pallas_decode_matches_full_attention(monkeypatch, bits):
    """Ring-buffer decode through the kernels == full-sequence attention
    (the exact zero-call-site-churn path gqa_decode/serve take)."""
    d_model, h, kh, hd, s = 32, 4, 2, 8, 12
    params = init_attention_params(jax.random.PRNGKey(0), d_model, h, kh, hd)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, d_model))
    positions = jnp.arange(s)
    full = gqa_forward(params, x, n_heads=h, n_kv_heads=kh, head_dim=hd,
                       rope_theta=1e4, positions=positions)

    monkeypatch.setenv("REPRO_ATTN_IMPL", "pallas")
    cache = init_kv_cache(2, s, kh, hd,
                          jnp.float32 if bits == 16 else jnp.bfloat16,
                          bits=bits)
    outs = []
    for t in range(s):
        qpos = jnp.full((2,), t, jnp.int32)
        y, cache = gqa_decode(params, x[:, t:t + 1], cache, n_heads=h,
                              n_kv_heads=kh, head_dim=hd, rope_theta=1e4,
                              qpos=qpos)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    atol = 2e-4 if bits == 16 else 0.15  # int8 cache is lossy
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               atol=atol)
