"""Sequence-mixer layers: chunked forms vs exact recurrences; MoE; MLA."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import mamba2, mla, rwkv6
from repro.models.layers.moe import init_moe_params, moe_forward


# ---------------------------------------------------------------------------
# Mamba2: chunked SSD == step-by-step recurrence
# ---------------------------------------------------------------------------

def test_mamba2_chunked_matches_decode_recurrence():
    d_model, expand, hd, ds = 32, 2, 16, 8
    key = jax.random.PRNGKey(0)
    p = mamba2.init_mamba2_params(key, d_model, expand=expand, headdim=hd,
                                  d_state=ds)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, d_model)) * 0.5
    full = mamba2.mamba2_forward(p, x, expand=expand, headdim=hd, d_state=ds,
                                 chunk=8)
    cache = mamba2.init_mamba2_cache(2, d_model, expand=expand, headdim=hd,
                                     d_state=ds)
    outs = []
    for t in range(24):
        y, cache = mamba2.mamba2_decode(p, x[:, t:t + 1], cache,
                                        expand=expand, headdim=hd,
                                        d_state=ds)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("chunk", [4, 8, 24])
def test_mamba2_chunk_size_invariant(chunk):
    d_model = 32
    p = mamba2.init_mamba2_params(jax.random.PRNGKey(0), d_model,
                                  expand=2, headdim=16, d_state=8)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, d_model)) * 0.5
    ref = mamba2.mamba2_forward(p, x, expand=2, headdim=16, d_state=8,
                                chunk=24)
    out = mamba2.mamba2_forward(p, x, expand=2, headdim=16, d_state=8,
                                chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


# ---------------------------------------------------------------------------
# RWKV6: chunked WKV == exact recurrence oracle
# ---------------------------------------------------------------------------

def test_wkv_chunked_matches_recurrent():
    b, s, h, dk = 2, 40, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dk))
    log_w = -jnp.exp(jax.random.normal(ks[3], (b, s, h, dk)) - 2.0)
    log_w = jnp.clip(log_w, -rwkv6.DECAY_CLAMP, 0.0)
    u = jax.random.normal(ks[4], (h, dk)) * 0.1
    y_c, s_c = rwkv6.wkv_chunked(r, k, v, log_w, u, chunk=16)
    y_r, s_r = rwkv6.wkv_recurrent(r, k, v, log_w, u)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r), atol=1e-3,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r), atol=1e-3,
                               rtol=1e-3)


def test_rwkv6_forward_matches_decode():
    d_model, hd = 64, 16
    p = rwkv6.init_rwkv6_params(jax.random.PRNGKey(0), d_model, hd)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, d_model)) * 0.5
    full = rwkv6.rwkv6_forward(p, x, head_dim=hd, chunk=4)
    cache = rwkv6.init_rwkv6_cache(2, d_model, hd)
    outs = []
    for t in range(12):
        y, cache = rwkv6.rwkv6_decode(p, x[:, t:t + 1], cache, head_dim=hd)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               atol=2e-3, rtol=1e-2)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def test_moe_routes_and_balances():
    d, e, f = 16, 4, 32
    p = init_moe_params(jax.random.PRNGKey(0), d, e, f)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d))
    y, aux = moe_forward(p, x, top_k=2, capacity_factor=2.0)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux["load_balance"]) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz
    assert float(aux["drop_fraction"]) <= 0.5


def test_moe_no_drops_at_high_capacity():
    d, e, f = 16, 4, 32
    p = init_moe_params(jax.random.PRNGKey(0), d, e, f)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d))
    _, aux = moe_forward(p, x, top_k=2, capacity_factor=8.0)
    assert float(aux["drop_fraction"]) == 0.0


def test_moe_matches_dense_mixture_at_full_capacity():
    """With no drops, sort-based dispatch == brute-force weighted experts."""
    d, e, f, k = 8, 4, 16, 2
    p = init_moe_params(jax.random.PRNGKey(0), d, e, f)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, d))
    y, _ = moe_forward(p, x, top_k=k, capacity_factor=8.0)

    xf = x.reshape(-1, d)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, k)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xf)
    for t in range(xf.shape[0]):
        acc = jnp.zeros((d,))
        for j in range(k):
            eidx = int(ei[t, j])
            gate = jax.nn.silu(xf[t] @ p["w_gate"][eidx])
            up = xf[t] @ p["w_up"][eidx]
            acc = acc + gv[t, j] * ((gate * up) @ p["w_down"][eidx])
        ref = ref.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(y.reshape(-1, d)),
                               np.asarray(ref), atol=1e-4, rtol=1e-3)


def test_moe_shared_and_dense_residual():
    d, e, f = 8, 4, 16
    p = init_moe_params(jax.random.PRNGKey(0), d, e, f, n_shared_experts=1,
                        dense_residual_d_ff=32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, d))
    y, _ = moe_forward(p, x, top_k=2)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))


# ---------------------------------------------------------------------------
# MLA: absorbed decode == materialized forward
# ---------------------------------------------------------------------------

def test_mla_decode_matches_forward():
    d_model, h = 32, 4
    kw = dict(kv_lora_rank=8, qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8)
    p = mla.init_mla_params(jax.random.PRNGKey(0), d_model, h,
                            q_lora_rank=16, **kw)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, d_model)) * 0.5
    positions = jnp.arange(10)
    full = mla.mla_forward(p, x, n_heads=h, rope_theta=1e4,
                           positions=positions, **kw)
    cache = mla.init_mla_cache(2, 10, kw["kv_lora_rank"], kw["qk_rope_dim"],
                               jnp.float32)
    outs = []
    for t in range(10):
        y, cache = mla.mla_decode(p, x[:, t:t + 1], cache, n_heads=h,
                                  rope_theta=1e4,
                                  qpos=jnp.full((2,), t, jnp.int32), **kw)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               atol=2e-4, rtol=1e-3)
