"""Adaptive grouped wire: grouped payloads, channel permutations, the
entropy allocator, calibration cold start, and the serve engine's
grouped/adaptive split wire."""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import entropy as entropy_mod
from repro.core import quantizers as Q
from repro.core.quantizers import QuantConfig
from repro.core.payload import GroupedPayload
from repro.core.split import (WireLink, calib_scale_error, init_wire_calib,
                              update_wire_calib)


def _x(shape, seed=0, scale=3.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


# ---------------------------------------------------------------------------
# grouped payloads
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["rdfsq", "fsq", "nf"])
def test_grouped_payload_matches_roundtrip(method):
    """Mixed-width grouped wire: decode(encode(x)) == roundtrip(x)[0]."""
    cfg = QuantConfig(method=method, bits=2, group_widths=(1, 2, 3, 8))
    x = _x((4, 6, 64))
    payload = Q.encode(cfg, x)
    assert isinstance(payload, GroupedPayload)
    assert payload.meta["widths"] == (1, 2, 3, 8)
    x_hat = Q.decode(cfg, payload)
    rt, _ = Q.roundtrip(cfg, x)
    np.testing.assert_allclose(np.asarray(x_hat), np.asarray(rt),
                               atol=1e-5, rtol=1e-5)


def test_grouped_fsq_3bit_is_3_16_of_bf16():
    """FSQ ships pure code bytes: a uniform 3-bit plan costs exactly
    3/16 of the bf16 activation."""
    cfg = QuantConfig(method="fsq", bits=2, group_widths=(3,) * 8)
    sds = jax.ShapeDtypeStruct((2, 16, 64), jnp.bfloat16)
    wire = jax.eval_shape(partial(Q.encode, cfg), sds).wire_bytes()
    assert wire == int(np.prod(sds.shape)) * 3 // 8
    assert wire / (int(np.prod(sds.shape)) * 2) == 3 / 16


def test_channel_perm_inverts_and_costs_nothing():
    """A permuted plan reconstructs channels in wire order (the decoder
    applies the inverse gather) and adds zero payload bytes."""
    d = 64
    perm = tuple(int(i) for i in
                 np.random.default_rng(7).permutation(d))
    base = QuantConfig(method="fsq", bits=2, group_widths=(8,) * 8)
    permed = dataclasses.replace(base, channel_perm=perm)
    x = jnp.tanh(_x((4, 5, d), seed=3, scale=1.0))  # in FSQ's sweet spot
    p0, p1 = Q.encode(base, x), Q.encode(permed, x)
    assert p0.wire_bytes() == p1.wire_bytes()
    assert p1.meta["permuted"] and not p0.meta["permuted"]
    x_hat = Q.decode(permed, p1)
    rt, _ = Q.roundtrip(permed, x)
    np.testing.assert_allclose(np.asarray(x_hat), np.asarray(rt),
                               atol=1e-5, rtol=1e-5)
    # at 8 bits the reconstruction is near-exact — a missing inverse
    # permutation would scramble the channel axis and blow this up
    np.testing.assert_allclose(np.asarray(x_hat), np.asarray(x), atol=0.05)


def test_channel_perm_length_validated():
    cfg = QuantConfig(method="fsq", bits=2, group_widths=(2, 2),
                      channel_perm=(1, 0, 2))
    with pytest.raises(ValueError):
        Q.encode(cfg, _x((2, 8)))


# ---------------------------------------------------------------------------
# the entropy allocator
# ---------------------------------------------------------------------------

def test_allocate_bits_uniform_on_homogeneous_signal():
    ent = np.full(64, 1.9)
    plan = entropy_mod.allocate_bits(ent, 2 * 64 * 100 / 8,
                                     group_size=8, scalars_per_channel=100)
    assert plan == (2,) * 8


def test_allocate_bits_floor_infeasible_raises():
    with pytest.raises(ValueError):
        entropy_mod.allocate_bits(np.full(64, 2.0), 10.0,
                                  group_size=8, scalars_per_channel=100)


def test_allocate_bits_stops_at_source_coding_bound():
    """Near-dead channels never get a second bit even under a huge
    budget, and no group exceeds MAX_WIRE_BITS."""
    ent = np.concatenate([np.full(32, 0.3), np.full(32, 20.0)])
    plan = entropy_mod.allocate_bits(ent, 1e9, group_size=8,
                                     scalars_per_channel=100)
    assert plan[:4] == (1,) * 4          # below 1 bit of entropy: floor
    assert plan[4:] == (8,) * 4          # clamped at the wire maximum
    assert max(plan) <= entropy_mod.MAX_WIRE_BITS


def test_plan_grouped_sorts_then_differentiates():
    """Sorted grouping exposes channel-level spread the contiguous group
    means would average away: the widths come out non-decreasing and
    actually different across groups."""
    rng = np.random.default_rng(0)
    ent = rng.permutation(np.linspace(0.2, 3.2, 64))
    perm, widths = entropy_mod.plan_grouped(
        ent, 2 * 64 * 100 / 8, group_size=8, scalars_per_channel=100)
    assert sorted(perm) == list(range(64))
    assert list(ent[list(perm)]) == sorted(ent)
    assert list(widths) == sorted(widths)  # ascending with entropy rank
    assert widths[0] < widths[-1]          # real differentiation
    # identical signal, identical plan: deterministic for the jit caches
    assert entropy_mod.plan_grouped(ent, 2 * 64 * 100 / 8, group_size=8,
                                    scalars_per_channel=100) == (perm, widths)


def test_optimal_bits_clamped_to_wire_range():
    assert entropy_mod.optimal_bits(25.0) == 8
    assert entropy_mod.optimal_bits(-3.0) == 1
    # the full estimator path: a wide-range sample at a tiny bin width
    # reads far past 8 bits of discretized entropy, but the
    # recommendation must stay shippable
    x = _x((4096,), seed=1, scale=1e4)
    bits, h = entropy_mod.estimate_optimal_bits(x, delta=1e-6)
    assert h > 8.0
    assert bits == 8


def test_entropy_ema_ranks_channels_and_cold_starts():
    """Wide channels read higher than near-constant ones, and the first
    update adopts the batch outright (decay-independent)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(np.stack([rng.normal(0, 1e-3, 512),
                              rng.normal(0, 1.0, 512)], axis=-1))
    a = entropy_mod.update_entropy_ema(entropy_mod.init_entropy_ema(2), x,
                                       decay=0.9)
    b = entropy_mod.update_entropy_ema(entropy_mod.init_entropy_ema(2), x,
                                       decay=0.1)
    np.testing.assert_array_equal(np.asarray(a["hist"]),
                                  np.asarray(b["hist"]))
    assert float(a["count"]) == 1.0
    ent = np.asarray(entropy_mod.entropy_ema_bits(a))
    assert ent[0] < ent[1]
    assert ent.min() >= 0.0


# ---------------------------------------------------------------------------
# wire calibration edge cases
# ---------------------------------------------------------------------------

def test_wire_calib_cold_start_adopts_batch():
    """count == 0 adopts the first batch's statistics exactly instead of
    blending them toward the zero init."""
    x = _x((8, 16), seed=2) + 5.0
    for decay in (0.9, 0.1):
        c = update_wire_calib(init_wire_calib(), x, decay=decay)
        assert float(c["mean"]) == pytest.approx(float(jnp.mean(x)))
        assert float(c["std"]) == pytest.approx(float(jnp.std(x)))
        assert float(c["lo"]) == pytest.approx(float(jnp.min(x)))
        assert float(c["hi"]) == pytest.approx(float(jnp.max(x)))
        assert float(c["count"]) == 1.0


def test_calib_scale_error_zero_span_finite():
    """Two constant (zero-span) calibrations agree at error 0, and a
    zero-span vs wide comparison saturates near 1 — never NaN/inf."""
    const = update_wire_calib(init_wire_calib(), jnp.full((4, 4), 3.0))
    wide = update_wire_calib(init_wire_calib(), _x((4, 4), seed=3))
    zero_zero = float(calib_scale_error(const, const))
    zero_wide = float(calib_scale_error(const, wide))
    assert zero_zero == 0.0
    assert np.isfinite(zero_wide) and zero_wide == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# serve engine: grouped + adaptive split wire
# ---------------------------------------------------------------------------

def _vlm_engine(split_wire=None, **kw):
    from repro.configs import get_config
    from repro.serve.engine import ServeEngine
    from repro.models import transformer as tf

    cfg = get_config("tinyllava").reduced()
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    b, p, pg, n_new = 2, 16, 8, 2
    n_img = cfg.n_image_tokens
    rng = np.random.default_rng(5)
    toks = rng.integers(1, cfg.vocab_size, size=(b, p)).astype(np.int32)
    imgs = rng.normal(size=(b, n_img, cfg.d_vision)).astype(np.float32)
    n_pages = 1 + b * (-(-(n_img + p + n_new) // pg))
    eng = ServeEngine(params, cfg, n_slots=b, page_size=pg,
                      n_pages=n_pages, split_wire=split_wire, **kw)
    for i in range(b):
        eng.submit(list(toks[i]), max_new=n_new, image_embeds=imgs[i])
    return eng, cfg, b, n_img


def test_engine_split_serve_grouped_wire_bytes():
    """A grouped split wire ships a GroupedPayload whose exact bytes
    match the WireLink static accounting."""
    from repro.models import transformer as tf

    wire = QuantConfig(method="rdfsq", bits=2, group_widths=(1, 2, 3, 8))
    eng, cfg, b, n_img = _vlm_engine(split_wire=wire)
    res = eng.run()
    assert all(len(v) == 2 for v in res.values())
    link = WireLink(src=0, dst=1, quant=wire)
    sds = jax.ShapeDtypeStruct((b, n_img, cfg.d_model), tf.cdtype(cfg))
    assert eng.stats["wire_bytes"] == link.fwd_wire_bytes(sds)


def test_engine_adaptive_split_serve_replans():
    """Budgeted mode re-plans the connector wire from the entropy EMA:
    the adopted plan (widths + sorted-channel permutation) lands on the
    engine's QuantConfig and the byte accounting follows it."""
    from repro.models import transformer as tf

    wire = QuantConfig(method="rdfsq", bits=2)
    eng, cfg, b, n_img = _vlm_engine(split_wire=wire,
                                     split_wire_budget_bits=2.0,
                                     split_plan_groups=8)
    res = eng.run()
    assert all(len(v) == 2 for v in res.values())
    plan = eng.stats["wire_plan"]
    assert plan == eng.split_wire.group_widths and len(plan) == 8
    assert all(1 <= w <= 8 for w in plan)
    assert sum(plan) / len(plan) <= 2.0  # within the bit budget
    assert sorted(eng.split_wire.channel_perm) == list(range(cfg.d_model))
    link = WireLink(src=0, dst=1, quant=eng.split_wire)
    sds = jax.ShapeDtypeStruct((b, n_img, cfg.d_model), tf.cdtype(cfg))
    assert eng.stats["wire_bytes"] == link.fwd_wire_bytes(sds)
