"""Sharded execution on a small fake-device mesh.

Device count locks at first jax init, so the mesh tests run in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 —
the same mechanism the production dry-run uses with 512.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=420)


def test_sharded_train_step_matches_single_device():
    """4x2 mesh train step == unsharded train step (same math)."""
    r = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.data.pipeline import make_pipeline
        from repro.optim import AdamWConfig
        from repro.sharding import mesh_axes, state_pspecs, batch_pspecs
        from repro.train.loop import init_state, make_train_step

        cfg = get_config("llama3_2_3b").reduced()
        opt = AdamWConfig(lr=1e-3)
        key = jax.random.PRNGKey(0)
        state = init_state(key, cfg, opt)
        batch = next(make_pipeline(cfg, 8, 16))
        step = make_train_step(cfg, opt)

        # single device reference
        s_ref, m_ref = jax.jit(step)(state, batch, key)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        axes = mesh_axes(mesh)
        st_specs = state_pspecs(state, axes, fsdp=True)
        b_specs = batch_pspecs(batch, ("data",), axes)
        named = lambda t: jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
        with mesh:
            f = jax.jit(step, in_shardings=(named(st_specs),
                                            named(b_specs),
                                            NamedSharding(mesh, P())))
            s_sh, m_sh = f(state, batch, key)
        assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 1e-3, \\
            (float(m_ref["loss"]), float(m_sh["loss"]))
        for a, b in zip(jax.tree_util.tree_leaves(s_ref.params),
                        jax.tree_util.tree_leaves(s_sh.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=5e-3)
        print("MESH_TRAIN_OK")
    """)
    assert "MESH_TRAIN_OK" in r.stdout, r.stdout + r.stderr


def test_quantized_ship_across_pod_axis():
    """quantized_ship moves bit-packed payloads over a pod axis inside
    shard_map, and the gradient returns on the reverse permutation."""
    r = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core import QuantConfig, quantized_ship, roundtrip

        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        qcfg = QuantConfig(method="rdfsq", bits=2)
        perm = [(0, 1), (1, 0)]

        # replicate over data so per-sample quantizer stats match the
        # single-device reference (RD-FSQ stats are per local sample)
        @partial(shard_map, mesh=mesh, in_specs=P("pod", None, None),
                 out_specs=P("pod", None, None))
        def ship(x):
            return quantized_ship(qcfg, x, "pod", tuple(perm))

        x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 64))
        y = jax.jit(ship)(x)
        # pod 1 receives pod 0's dequantized activation and vice versa
        ref0, _ = roundtrip(qcfg, x[:2])
        np.testing.assert_allclose(np.asarray(y[2:]), np.asarray(ref0),
                                   atol=1e-4)
        # gradient passes back through the reverse permutation
        g = jax.grad(lambda x: jnp.sum(jax.jit(ship)(x) * 2.0))(x)
        np.testing.assert_allclose(np.asarray(g), 2.0, atol=1e-5)
        print("SHIP_OK")
    """)
    assert "SHIP_OK" in r.stdout, r.stdout + r.stderr


def test_dryrun_one_small_arch():
    """End-to-end dryrun_one on the 512-device production mesh (1 combo)."""
    r = _run("""
        from repro.launch.dryrun import dryrun_one  # sets XLA_FLAGS first
        res = dryrun_one("musicgen_large", "long_500k", multi_pod=False,
                         save=False, verbose=False)
        assert res["chips"] == 256  # 16x16 single pod
        assert res["roofline"]["dominant"] in ("compute", "memory",
                                               "collective")
        print("DRYRUN_OK")
    """)
    assert "DRYRUN_OK" in r.stdout, r.stdout + r.stderr


def test_split_pipeline_loss_matches_monolithic():
    """Pipeline next-token CE == monolithic forward + CE, and the
    reported per-tick wire bytes are the static payload constant."""
    r = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core import quantizers as Q
        from repro.core.quantizers import QuantConfig
        from repro.launch import split_pipeline as sp
        from repro.models import transformer as tf
        from repro.models.layers import embedding as emb_mod
        from repro.models.layers.norms import rms_norm
        from repro.train.losses import IGNORE, cross_entropy

        cfg = sp._homogeneous_cfg("llama3_2_3b", reduced=True)
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        key = jax.random.PRNGKey(0)
        params = sp.init_pipeline_params(key, cfg)
        n_micro, mb, seq = 3, 4, 16
        tokens = jax.random.randint(key, (n_micro, mb, seq), 0,
                                    cfg.vocab_size)
        labels = jnp.concatenate(
            [tokens[:, :, 1:],
             jnp.full((n_micro, mb, 1), IGNORE, tokens.dtype)], axis=-1)

        def mono_loss(tok, lab, qcfg):
            x = emb_mod.embed(params["embed"], tok, jnp.float32)
            pos = jnp.arange(seq, dtype=jnp.int32)
            for stage in range(2):
                blocks = jax.tree_util.tree_map(lambda a: a[stage],
                                                params["blocks"])
                def body(h, p):
                    h, _, _ = tf.block_forward(cfg, "dense", p, h,
                                               positions=pos, window=None)
                    return h, None
                x, _ = jax.lax.scan(body, x, blocks)
                if stage == 0:  # the wire: quantize -> dequantize
                    x, _ = Q.roundtrip(qcfg, x)
            out = rms_norm(x, params["final_norm"], cfg.norm_eps)
            logits = emb_mod.head_logits(params["head"], out)
            return cross_entropy(logits, lab)

        for method in ("identity", "rdfsq"):
            qcfg = QuantConfig(method=method, bits=2)
            ref = np.mean([float(mono_loss(tokens[i], labels[i], qcfg))
                           for i in range(n_micro)])
            step = sp.build_pipeline_step(cfg, mesh, qcfg, n_micro, mb,
                                          seq)
            with mesh:
                loss, wire_b = jax.jit(step)(params, tokens, labels)
            assert abs(float(loss) - ref) < 2e-2, (method, float(loss),
                                                   ref)
            expected = sp.pipeline_wire_bytes(
                cfg, qcfg, mb, seq, data_shards=4)["fwd_tick"]
            assert float(wire_b) == expected > 0, (float(wire_b),
                                                   expected)
        print("PIPELINE_OK")
    """)
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr


def test_split_pipeline_grad_and_nstage():
    """Gradients cross the quantized wire into every stage (incl. the
    embed on stage 0), and a 4-stage topology runs fill/drain right."""
    r = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.quantizers import QuantConfig
        from repro.core.split import SplitConfig
        from repro.launch import split_pipeline as sp
        from repro.train.losses import IGNORE

        cfg = sp._homogeneous_cfg("llama3_2_3b", reduced=True)
        key = jax.random.PRNGKey(0)
        n_micro, mb, seq = 3, 4, 16
        tokens = jax.random.randint(key, (n_micro, mb, seq), 0,
                                    cfg.vocab_size)
        labels = jnp.concatenate(
            [tokens[:, :, 1:],
             jnp.full((n_micro, mb, 1), IGNORE, tokens.dtype)], axis=-1)

        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        params = sp.init_pipeline_params(key, cfg)
        qcfg = QuantConfig(method="rdfsq", bits=2)
        gstep = sp.build_pipeline_grad_step(cfg, mesh, qcfg,
                                            QuantConfig(method="rdfsq",
                                                        bits=2),
                                            n_micro, mb, seq)
        with mesh:
            loss, grads, wire_b = jax.jit(gstep)(params, tokens, labels)
        assert np.isfinite(float(loss)) and float(wire_b) > 0
        for s in range(2):
            g = sum(float(jnp.sum(jnp.abs(v[s]))) for v in
                    jax.tree_util.tree_leaves(grads["blocks"]))
            assert g > 0, (s, g)
        assert float(jnp.sum(jnp.abs(grads["embed"]["emb"]))) > 0

        # 4 stages x 1 layer with HETEROGENEOUS per-cut compression:
        # fill/drain over n_micro + 3 ticks, loss parity against the
        # monolithic forward applying each cut's roundtrip in place
        from repro.core import quantizers as Q
        from repro.models import transformer as tf
        from repro.models.layers import embedding as emb_mod
        from repro.models.layers.norms import rms_norm
        from repro.train.losses import cross_entropy

        cfg4 = dataclasses.replace(cfg, n_layers=4)
        mesh4 = jax.make_mesh((4, 2), ("pod", "data"))
        quants = (QuantConfig(method="rdfsq", bits=2),
                  QuantConfig(method="nf", bits=4),
                  QuantConfig(method="rdfsq", bits=2))
        split4 = SplitConfig(quant=qcfg, learnable_codec=False,
                             n_stages=4, stage_quants=quants)
        params4 = sp.init_pipeline_params(key, cfg4, 4)

        def mono_loss(tok, lab):
            x = emb_mod.embed(params4["embed"], tok, jnp.float32)
            pos = jnp.arange(seq, dtype=jnp.int32)
            for stage in range(4):
                p = jax.tree_util.tree_map(lambda a: a[stage, 0],
                                           params4["blocks"])
                x, _, _ = tf.block_forward(cfg4, "dense", p, x,
                                           positions=pos, window=None)
                if stage < 3:
                    x, _ = Q.roundtrip(quants[stage], x)
            out = rms_norm(x, params4["final_norm"], cfg4.norm_eps)
            return cross_entropy(
                emb_mod.head_logits(params4["head"], out), lab)

        ref = np.mean([float(mono_loss(tokens[i], labels[i]))
                       for i in range(n_micro)])
        step4 = sp.build_pipeline_step(cfg4, mesh4, split4, n_micro, mb,
                                       seq)
        with mesh4:
            loss4, wire4 = jax.jit(step4)(params4, tokens, labels)
        assert abs(float(loss4) - ref) < 2e-2, (float(loss4), ref)
        # two distinct cut configs -> wire bytes sum over both groups
        expected4 = sp.pipeline_wire_bytes(cfg4, split4, mb, seq,
                                           data_shards=2)["fwd_tick"]
        assert float(wire4) == expected4 > 0
        print("GRAD_NSTAGE_OK")
    """)
    assert "GRAD_NSTAGE_OK" in r.stdout, r.stdout + r.stderr


def test_split_pipeline_trains():
    """train_pipeline: AdamW over the 2-bit wire decreases the loss."""
    r = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        from repro.launch import split_pipeline as sp
        res = sp.dryrun_train(n_steps=4, n_micro=2, micro_batch=4,
                              seq=32, n_stages=2)
        hist = res["loss_history"]
        assert hist[-1] < hist[0], hist
        assert res["wire_bytes_per_tick"] > 0
        print("TRAIN_OK")
    """)
    assert "TRAIN_OK" in r.stdout, r.stdout + r.stderr
