"""Continuous-batching serving engine: paged pool invariants, paged
kernel parity, engine-vs-generate token parity, donation, early stop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.split import WireLink
from repro.kernels import attention_ops, attention_ref
from repro.models import transformer as tf
from repro.models.layers import attention as attn_mod
from repro.serve import decode as sd
from repro.serve.engine import ServeEngine
from repro.serve.pool import PagePool
from repro.serve.scheduler import Request, SlotScheduler


def _params(cfg, seed=0):
    return tf.init_params(jax.random.PRNGKey(seed), cfg)


# ---------------------------------------------------------------------------
# page pool invariants
# ---------------------------------------------------------------------------

def test_page_pool_random_admit_retire_trace():
    rng = np.random.default_rng(0)
    pool = PagePool(33)
    live = {}
    next_rid = 0
    for _ in range(300):
        if live and rng.random() < 0.4:
            rid = int(rng.choice(list(live)))
            n = pool.free_owner(rid)
            assert n == len(live.pop(rid))
        else:
            n = int(rng.integers(1, 5))
            if pool.can_alloc(n):
                pages = pool.alloc(n, next_rid)
                assert len(set(pages)) == n
                # no page aliased by two live requests, trash never out
                for p in pages:
                    assert p != 0
                    for other in live.values():
                        assert p not in other
                live[next_rid] = pages
                next_rid += 1
        pool.check_invariants()
    for rid in list(live):
        pool.free_owner(rid)
    pool.check_invariants()
    assert pool.n_free == 32 and pool.n_live == 0


def test_page_pool_retired_pages_reusable_and_double_free_raises():
    pool = PagePool(5)
    a = pool.alloc(4, 1)
    pool.free_owner(1)
    b = pool.alloc(4, 2)
    assert set(a) == set(b)  # the whole pool cycles through
    with pytest.raises(RuntimeError):
        pool.alloc(1, 3)
    pool.free(b)
    with pytest.raises(RuntimeError):
        pool.free(b)


def test_scheduler_head_of_line_blocks_until_pages_free():
    pool = PagePool(5)  # 4 usable pages
    sched = SlotScheduler(2, pool, page_size=4)
    sched.submit(Request(rid=0, tokens=[1] * 10, max_new=6))   # 4 pages
    sched.submit(Request(rid=1, tokens=[1] * 2, max_new=2))    # 1 page
    admitted = sched.admit()
    assert [r.rid for r in admitted] == [0]
    # a free slot exists but the FIFO head (nothing) — rid 1 must wait for
    # pages, not jump past a fuller pool
    assert sched.admit() == []
    sched.retire(admitted[0], "length")
    assert [r.rid for r in sched.admit()] == [1]


# ---------------------------------------------------------------------------
# paged decode kernels vs refs
# ---------------------------------------------------------------------------

def _paged_fixture():
    rng = np.random.default_rng(0)
    p, pg, kh, g, d = 7, 8, 2, 2, 16
    pt = jnp.array([[1, 2, -1], [3, 4, 5], [-1, -1, -1]], jnp.int32)
    qpos = jnp.array([12, 21, -1], jnp.int32)
    pos = np.full((p, pg), -1, np.int32)
    pos[1] = np.arange(pg)
    pos[2] = np.arange(pg, 2 * pg)
    pos[2, 5:] = -1  # slot 0 holds 13 tokens
    for j in range(3):
        pos[3 + j] = np.arange(j * pg, (j + 1) * pg)
    qf = jnp.asarray(rng.normal(size=(3, kh, g, d)), jnp.float32) / np.sqrt(d)
    return rng, p, pg, kh, d, pt, qpos, jnp.asarray(pos), qf


@pytest.mark.parametrize("window", [None, 6])
def test_decode_paged_pallas_matches_ref(window):
    rng, p, pg, kh, d, pt, qpos, pos, qf = _paged_fixture()
    k = jnp.asarray(rng.normal(size=(p, pg, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(p, pg, kh, d)), jnp.float32)
    ref = attention_ref.decode_attention_paged_ref(qf, k, v, pos, pt, qpos,
                                                   window=window)
    out = attention_ops.decode_paged_pallas(qf, k, v, pos, pt, qpos,
                                            window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # the inactive slot must be exact zero, not a softmax of garbage
    assert np.all(np.asarray(out)[2] == 0.0)


@pytest.mark.parametrize("window", [None, 6])
def test_decode_paged_q8_pallas_matches_ref(window):
    rng, p, pg, kh, d, pt, qpos, pos, qf = _paged_fixture()
    kc = jnp.asarray(rng.integers(-127, 128, (p, pg, kh, d)), jnp.int8)
    vc = jnp.asarray(rng.integers(-127, 128, (p, pg, kh, d)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.01, 0.1, (p, pg, kh)), jnp.float16)
    vs = jnp.asarray(rng.uniform(0.01, 0.1, (p, pg, kh)), jnp.float16)
    ref = attention_ref.decode_attention_paged_q8_ref(
        qf, kc, vc, ks, vs, pos, pt, qpos, window=window)
    out = attention_ops.decode_paged_q8_pallas(
        qf, kc, vc, ks, vs, pos, pt, qpos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert np.all(np.asarray(out)[2] == 0.0)


def test_paged_ref_equals_contiguous_ref_on_gathered_cache():
    rng, p, pg, kh, d, pt, qpos, pos, qf = _paged_fixture()
    k = jnp.asarray(rng.normal(size=(p, pg, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(p, pg, kh, d)), jnp.float32)
    kg = attention_ref.gather_pages(k, pt)
    vg = attention_ref.gather_pages(v, pt)
    kpos = attention_ref.paged_kpos(pos, pt)
    dense = attention_ref.decode_attention_ref(qf, kg, vg, kpos, qpos)
    paged = attention_ref.decode_attention_paged_ref(qf, k, v, pos, pt, qpos)
    act = np.asarray(qpos) >= 0
    np.testing.assert_array_equal(np.asarray(dense)[act],
                                  np.asarray(paged)[act])


@pytest.mark.parametrize("bits", [16, 8])
def test_gqa_decode_paged_matches_ring_cache(bits):
    rng = jax.random.PRNGKey(0)
    s, h, kh, d, dm, pg, npp = 2, 4, 2, 16, 32, 4, 4
    params = attn_mod.init_attention_params(rng, dm, h, kh, d,
                                            dtype=jnp.float32)
    ring = attn_mod.init_kv_cache(s, pg * npp, kh, d, dtype=jnp.float32,
                                  bits=bits)
    pool = attn_mod.init_paged_kv_pool(1 + s * npp, pg, kh, d,
                                       dtype=jnp.float32, bits=bits)
    pt = jnp.asarray(1 + np.arange(s * npp).reshape(s, npp), jnp.int32)
    for t in range(6):
        x = jax.random.normal(jax.random.fold_in(rng, t), (s, 1, dm),
                              jnp.float32)
        qpos = jnp.full((s,), t, jnp.int32)
        yr, ring = attn_mod.gqa_decode(params, x, ring, n_heads=h,
                                       n_kv_heads=kh, head_dim=d,
                                       rope_theta=1e4, qpos=qpos)
        yp, pool = attn_mod.gqa_decode_paged(params, x, pool, n_heads=h,
                                             n_kv_heads=kh, head_dim=d,
                                             rope_theta=1e4, qpos=qpos,
                                             page_table=pt)
        np.testing.assert_array_equal(np.asarray(yr), np.asarray(yp))


def test_gqa_decode_paged_inactive_writes_hit_trash_page():
    rng = jax.random.PRNGKey(0)
    h, kh, d, dm, pg, npp = 4, 2, 16, 32, 4, 2
    params = attn_mod.init_attention_params(rng, dm, h, kh, d,
                                            dtype=jnp.float32)
    pool = attn_mod.init_paged_kv_pool(1 + npp, pg, kh, d,
                                       dtype=jnp.float32)
    pt = jnp.asarray(np.vstack([1 + np.arange(npp), -np.ones(npp)]),
                     jnp.int32)
    x = jax.random.normal(rng, (2, 1, dm), jnp.float32)
    _, pool = attn_mod.gqa_decode_paged(
        params, x, pool, n_heads=h, n_kv_heads=kh, head_dim=d,
        rope_theta=1e4, qpos=jnp.array([0, -1], jnp.int32), page_table=pt)
    assert np.all(np.asarray(pool["pos"])[0] == -1)  # trash stays empty
    assert np.asarray(pool["pos"])[1, 0] == 0        # active write landed


# ---------------------------------------------------------------------------
# engine vs generate
# ---------------------------------------------------------------------------

def _lockstep_case(cfg):
    params = _params(cfg)
    b, p, n_new, pg = 4, 8, 8, 4
    toks = np.random.default_rng(1).integers(
        1, cfg.vocab_size, size=(b, p)).astype(np.int32)
    ref = np.asarray(sd.generate(params, cfg, dict(tokens=jnp.asarray(toks)),
                                 n_new=n_new, cache_len=16))
    eng = ServeEngine(params, cfg, n_slots=b, page_size=pg,
                      n_pages=1 + b * ((p + n_new) // pg))
    rids = [eng.submit(list(toks[i]), max_new=n_new) for i in range(b)]
    res = eng.run()
    np.testing.assert_array_equal(np.stack([res[r] for r in rids]), ref)
    assert eng.page_pool.n_live == 0


def test_engine_lockstep_token_exact_vs_generate():
    _lockstep_case(get_config("llama3_2_3b").reduced())


def test_engine_lockstep_token_exact_vs_generate_int8_cache():
    _lockstep_case(dataclasses.replace(get_config("llama3_2_3b").reduced(),
                                       kv_cache_bits=8))


def test_engine_churn_mixed_lengths_invariants():
    cfg = get_config("llama3_2_3b").reduced()
    eng = ServeEngine(_params(cfg), cfg, n_slots=2, page_size=4,
                      n_pages=1 + 10)
    rng = np.random.default_rng(7)
    rids = [eng.submit(list(rng.integers(1, cfg.vocab_size,
                                         int(rng.integers(3, 12)))),
                       max_new=int(rng.integers(1, 9)))
            for _ in range(6)]
    steps = 0
    while not eng.idle:
        eng.step()
        eng.page_pool.check_invariants()
        steps += 1
        assert steps < 500
    for rid in rids:
        r = eng.request(rid)
        assert r.state == "done" and len(r.out) == r.max_new
    assert eng.page_pool.n_live == 0
    assert eng.stats["prefill_batches"] >= 2  # mid-flight admissions ran


def test_engine_eos_retires_midflight_and_backfills_slot():
    cfg = get_config("llama3_2_3b").reduced()
    params = _params(cfg)
    toks = np.random.default_rng(3).integers(
        1, cfg.vocab_size, size=(2, 4)).astype(np.int32)
    # discover a token row 0 will emit mid-stream, then replay with it as EOS
    probe = ServeEngine(params, cfg, n_slots=1, page_size=4, n_pages=1 + 4)
    rid = probe.submit(list(toks[0]), max_new=6)
    stream = probe.run()[rid]
    eos = stream[2]
    eng = ServeEngine(params, cfg, n_slots=1, page_size=4, n_pages=1 + 4,
                      eos_id=eos)
    r0 = eng.submit(list(toks[0]), max_new=6)
    r1 = eng.submit(list(toks[1]), max_new=2)  # waits for the only slot
    while not eng.idle:
        eng.step()
        eng.page_pool.check_invariants()
    req0, req1 = eng.request(r0), eng.request(r1)
    assert req0.finish_reason == "eos"
    assert req0.out == stream[:3]          # eos emitted, then retired
    assert len(req0.out) < 6               # early, not max_new
    assert req1.state == "done" and len(req1.out) == 2  # backfilled slot


def test_engine_vlm_lockstep_and_split_serve_wire_bytes():
    cfg = get_config("tinyllava").reduced()
    params = _params(cfg)
    b, p, n_new, pg = 2, 16, 4, 8
    n_img = cfg.n_image_tokens
    rng = np.random.default_rng(5)
    toks = rng.integers(1, cfg.vocab_size, size=(b, p)).astype(np.int32)
    imgs = rng.normal(size=(b, n_img, cfg.d_vision)).astype(np.float32)
    ref = np.asarray(sd.generate(
        params, cfg, dict(tokens=jnp.asarray(toks),
                          image_embeds=jnp.asarray(imgs)),
        n_new=n_new, cache_len=64))
    n_pages = 1 + b * (-(-(n_img + p + n_new) // pg))
    eng = ServeEngine(params, cfg, n_slots=b, page_size=pg, n_pages=n_pages)
    rids = [eng.submit(list(toks[i]), max_new=n_new, image_embeds=imgs[i])
            for i in range(b)]
    res = eng.run()
    np.testing.assert_array_equal(np.stack([res[r] for r in rids]), ref)
    assert eng.stats["wire_bytes"] == 0  # co-located mode ships nothing

    eng = ServeEngine(params, cfg, n_slots=b, page_size=pg, n_pages=n_pages,
                      split_wire=cfg.split.quant)
    rids = [eng.submit(list(toks[i]), max_new=n_new, image_embeds=imgs[i])
            for i in range(b)]
    res = eng.run()
    assert all(len(res[r]) == n_new for r in rids)
    # byte accounting matches the WireLink static contract for the shipped
    # connector activations (B, n_img, d_model in the compute dtype)
    link = WireLink(src=0, dst=1, quant=cfg.split.quant)
    sds = jax.ShapeDtypeStruct((b, n_img, cfg.d_model), tf.cdtype(cfg))
    assert eng.stats["wire_bytes"] == link.fwd_wire_bytes(sds)


# ---------------------------------------------------------------------------
# donation + generate early stop
# ---------------------------------------------------------------------------

def test_serve_step_donates_caches_no_copy():
    cfg = get_config("llama3_2_3b").reduced()
    params = _params(cfg)
    caches = tf.init_caches(cfg, 2, 16, dtype=tf.cdtype(cfg))
    step = sd.compiled_serve_step(cfg)
    low = step.lower(params, caches, dict(tokens=jnp.zeros((2, 1),
                                                           jnp.int32)),
                     jnp.zeros((2,), jnp.int32))
    assert "tf.aliasing_output" in low.as_text()
    assert "input_output_alias" in low.compile().as_text()


def test_paged_step_donates_pools():
    from repro.serve import paged
    cfg = get_config("llama3_2_3b").reduced()
    params = _params(cfg)
    pools = paged.init_pools(cfg, 5, 4)
    step = paged.compiled_paged_step(cfg)
    low = step.lower(params, pools, dict(tokens=jnp.zeros((2, 1),
                                                          jnp.int32)),
                     jnp.zeros((2,), jnp.int32),
                     jnp.full((2, 2), -1, jnp.int32))
    assert "tf.aliasing_output" in low.as_text()
    assert "input_output_alias" in low.compile().as_text()


def test_generate_eos_freezes_finished_rows():
    cfg = get_config("llama3_2_3b").reduced()
    params = _params(cfg)
    toks = np.random.default_rng(0).integers(
        1, cfg.vocab_size, (2, 8)).astype(np.int32)
    batch = dict(tokens=jnp.asarray(toks))
    base = np.asarray(sd.generate(params, cfg, batch, n_new=8,
                                  cache_len=16))
    eos = int(base[0][2])
    out = np.asarray(sd.generate(params, cfg, batch, n_new=8, cache_len=16,
                                 eos_id=eos, pad_id=0))
    i0 = list(base[0]).index(eos)
    # regression: the finished row's tokens are unchanged by continued
    # stepping — eos kept, everything after is pad
    np.testing.assert_array_equal(out[0][:i0 + 1], base[0][:i0 + 1])
    assert np.all(out[0][i0 + 1:] == 0)
    if eos not in base[1]:
        np.testing.assert_array_equal(out[1], base[1])
