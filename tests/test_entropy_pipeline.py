"""Entropy estimator (paper Section 3.3 / Appendix A) + data pipeline."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.entropy import (differential_entropy_bits,
                                discretized_entropy_bits,
                                estimate_optimal_bits, optimal_bits,
                                scott_bandwidth)
from repro.data.pipeline import make_pipeline
from repro.train.losses import IGNORE


def test_gaussian_entropy():
    """H(N(0,1)) = 0.5 log2(2 pi e) ~ 2.047 bits."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8192,))
    ent, _ = differential_entropy_bits(x)
    assert abs(ent - 2.047) < 0.15


def test_uniform_entropy():
    """H(U[0, 4]) = log2(4) = 2 bits."""
    x = jax.random.uniform(jax.random.PRNGKey(1), (8192,)) * 4.0
    ent, _ = differential_entropy_bits(x)
    assert abs(ent - 2.0) < 0.25


def test_scaled_gaussian_shifts_entropy():
    """H(aX) = H(X) + log2 a."""
    x = jax.random.normal(jax.random.PRNGKey(2), (8192,))
    e1, _ = differential_entropy_bits(x)
    e2, _ = differential_entropy_bits(4.0 * x)
    assert abs((e2 - e1) - 2.0) < 0.2


def test_optimal_bits_ceiling():
    assert optimal_bits(1.8) == 2  # the paper's Table-1 conclusion
    assert optimal_bits(2.3) == 3
    assert optimal_bits(0.2) == 1


def test_scott_rule():
    assert abs(scott_bandwidth(1000, 1.0) -
               (4 / 3) ** 0.2 * 1000 ** -0.2) < 1e-9


def test_estimate_optimal_bits_scale_invariant():
    """H(aX) = H(X) + log2|a| must NOT leak into the bit choice: the
    quantizers normalize by the data range, so a client rescaling its
    activations cannot change the optimal wire width."""
    x = jax.random.normal(jax.random.PRNGKey(3), (8192,))
    b1, e1 = estimate_optimal_bits(x)
    for a in (1e-3, 0.125, 7.0, 512.0):
        b2, e2 = estimate_optimal_bits(a * x)
        assert b2 == b1, (a, b1, b2)
        assert abs(e2 - e1) < 0.1, (a, e1, e2)
    # raw differential entropy (the paper protocol) is NOT invariant —
    # the regression guards exactly this discrepancy
    raw1, _ = differential_entropy_bits(x)
    raw2, _ = differential_entropy_bits(512.0 * x)
    assert abs(raw2 - raw1) > 8.0


def test_estimate_matches_paper_table1_conclusion():
    """Compactly supported activations sit at the paper's ~1.8 bits ->
    2-bit optimal, now at EVERY scale: h(U/sigma) = log2(sqrt(12)) ~ 1.79
    regardless of the range the client picked."""
    u = jax.random.uniform(jax.random.PRNGKey(4), (8192,))
    for scale in (1.0, 100.0):
        bits, ent = estimate_optimal_bits(scale * u)
        assert bits == 2, (scale, bits, ent)
        # theoretical log2(sqrt(12)) ~ 1.79 plus the KDE's boundary
        # smoothing bias (~0.16 on a hard-edged density)
        assert math.log2(math.sqrt(12.0)) - 0.1 < ent < 2.0, ent


def test_discretized_entropy_bin_width():
    """H_disc ~ h(X) - log2(delta): halving the bin adds one bit."""
    x = jax.random.normal(jax.random.PRNGKey(5), (8192,))
    e1, _ = discretized_entropy_bits(x, 0.5)
    e2, _ = discretized_entropy_bits(x, 0.25)
    assert abs((e2 - e1) - 1.0) < 1e-9


def test_estimate_stable_across_batches():
    """Paper Table 1: estimates agree across batches."""
    ents = []
    for seed in range(4):
        x = jax.random.normal(jax.random.PRNGKey(seed), (4096,)) * 0.8
        b, e = estimate_optimal_bits(x)
        ents.append(e)
    assert max(ents) - min(ents) < 0.2


# ---------------------------------------------------------------------------
# pipeline
# ---------------------------------------------------------------------------

def test_text_pipeline_learnable_structure():
    cfg = get_config("llama3_2_3b").reduced()
    batch = next(make_pipeline(cfg, 4, 32))
    t, l = batch["tokens"], batch["labels"]
    assert t.shape == (4, 32) and l.shape == (4, 32)
    # label at i is token at i+1 (teacher forcing)
    np.testing.assert_array_equal(l[:, :-1], t[:, 1:])
    assert (l[:, -1] == IGNORE).all()


def test_vqa_pipeline_answers_encode_class():
    cfg = get_config("tinyllava").reduced()
    batch = next(make_pipeline(cfg, 4, 64))
    assert batch["image_embeds"].shape[1] == cfg.n_image_tokens
    labels = batch["labels"]
    n_ans = (labels != IGNORE).sum(axis=1)
    assert (n_ans == 4).all()  # answer_len positions supervised


def test_audio_pipeline_shapes():
    cfg = get_config("musicgen_large").reduced()
    batch = next(make_pipeline(cfg, 2, 16))
    assert batch["codes"].shape == (2, cfg.n_codebooks, 16)
    assert batch["labels_codes"].shape == (2, cfg.n_codebooks, 16)
    np.testing.assert_array_equal(batch["labels_codes"][:, :, :-1],
                                  batch["codes"][:, :, 1:])


def test_pipeline_deterministic():
    cfg = get_config("granite_3_8b").reduced()
    b1 = next(make_pipeline(cfg, 2, 16, seed=7))
    b2 = next(make_pipeline(cfg, 2, 16, seed=7))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
