"""AdamW from scratch (no optax dependency).

Production niceties: global-norm gradient clipping, decoupled weight decay
(skipped for norms/biases/1-D params), and configurable moment dtype —
bf16 moments shard the optimizer state of the 236B/480B MoE configs inside
per-device HBM (see EXPERIMENTS.md SSPerf).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 2e-5
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    moment_dtype: str = "float32"  # float32 | bfloat16


def init_opt_state(params, cfg: AdamWConfig) -> Dict[str, Any]:
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return dict(
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def param_bytes(tree) -> int:
    """Static byte size of a parameter pytree (arrays or SDS)."""
    import math

    return sum(math.prod(p.shape) * jnp.dtype(p.dtype).itemsize
               for p in jax.tree_util.tree_leaves(tree))


def opt_state_bytes(state: Dict[str, Any]) -> int:
    """Static byte size of an AdamW state (m + v moments + step).

    The SplitLoRA trainers assert this equals the moment bytes over the
    *adapter* tree alone — the optimizer state must be sized by the
    trainable (adapter) params, not the frozen base weights.
    """
    return (param_bytes(state["m"]) + param_bytes(state["v"])
            + param_bytes(state["step"]))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def _decay_mask(params):
    """Weight decay only on >=2-D weights (not norms, biases, scalars)."""
    return jax.tree_util.tree_map(lambda p: p.ndim >= 2, params)


def adamw_update(params, grads, state: Dict, cfg: AdamWConfig,
                 lr_scale: jnp.ndarray | float = 1.0
                 ) -> Tuple[Any, Dict, Dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v, decay):
        gf = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        m_hat = m_new / b1c
        v_hat = v_new / b2c
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        if decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_mask = treedef.flatten_up_to(_decay_mask(params))
    out = [upd(p, g, m, v, dk) for p, g, m, v, dk in
           zip(flat_p, flat_g, flat_m, flat_v, flat_mask)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = dict(grad_norm=gnorm, lr=jnp.asarray(lr, jnp.float32))
    return new_p, dict(m=new_m, v=new_v, step=step), metrics
