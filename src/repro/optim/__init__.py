from repro.optim.adamw import (AdamWConfig, adamw_update, global_norm,
                               init_opt_state, opt_state_bytes, param_bytes)
from repro.optim.schedule import constant, warmup_cosine

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "global_norm",
           "opt_state_bytes", "param_bytes", "warmup_cosine", "constant"]
