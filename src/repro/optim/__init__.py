from repro.optim.adamw import (AdamWConfig, adamw_update, global_norm,
                               init_opt_state)
from repro.optim.schedule import constant, warmup_cosine

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "global_norm",
           "warmup_cosine", "constant"]
