"""Training step builder + host-side loop.

``make_train_step(cfg, opt)`` returns a pure jit-able function
``(state, batch, rng) -> (state, metrics)`` implementing the paper's
composite objective.  The same function is what the multi-pod dry-run
lowers for the train_4k shape.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.optim.schedule import warmup_cosine
from repro.train.losses import composite_loss
from repro.sharding import ctx as shard_ctx


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Dict[str, Any]
    step: jnp.ndarray


def init_state(key, cfg: ArchConfig, opt_cfg: AdamWConfig) -> TrainState:
    params = tf.init_params(key, cfg)
    return TrainState(params=params, opt=init_opt_state(params, opt_cfg),
                      step=jnp.zeros((), jnp.int32))


def apply_gradients(state: TrainState, grads, opt_cfg: AdamWConfig, *,
                    warmup_steps: int = 0,
                    total_steps: int = 0) -> Tuple[TrainState, Dict]:
    """Warmup-cosine scheduled AdamW update of a TrainState.

    The one place the schedule meets the optimizer — shared by the
    monolithic train step below and the split-pipeline trainer
    (``launch/split_pipeline.train_pipeline``).  ``total_steps == 0``
    disables the schedule (constant lr).
    """
    lr_scale = warmup_cosine(state.step, warmup_steps=warmup_steps,
                             total_steps=total_steps) \
        if total_steps else 1.0
    new_params, new_opt, opt_metrics = adamw_update(
        state.params, grads, state.opt, opt_cfg, lr_scale)
    return TrainState(params=new_params, opt=new_opt,
                      step=state.step + 1), opt_metrics


def init_adapter_state(params, opt_cfg: AdamWConfig) -> TrainState:
    """SplitLoRA TrainState: optimizer moments over adapters ONLY.

    ``params`` must carry an ``"adapters"`` entry (see
    ``core.split_stage.init_stage_params(lora_rank=...)``).  The AdamW
    state is built from the adapter subtree alone, so its byte size —
    the thing SplitLoRA shrinks — is proportional to the adapter params,
    not the frozen base weights (asserted by the LoRA dry-runs via
    ``optim.opt_state_bytes``).
    """
    if "adapters" not in params:
        raise ValueError("init_adapter_state needs params['adapters']")
    return TrainState(params=params,
                      opt=init_opt_state(params["adapters"], opt_cfg),
                      step=jnp.zeros((), jnp.int32))


def apply_adapter_gradients(state: TrainState, adapter_grads,
                            opt_cfg: AdamWConfig, *,
                            warmup_steps: int = 0,
                            total_steps: int = 0) -> Tuple[TrainState, Dict]:
    """Adapter-only AdamW: steps ``params['adapters']``, base frozen.

    The counterpart of :func:`apply_gradients` for SplitLoRA runs:
    ``adapter_grads`` mirrors ``state.params['adapters']`` (and nothing
    else), the moments in ``state.opt`` were built over the adapter
    subtree, and every non-adapter leaf of ``state.params`` is returned
    untouched (bit-frozen base weights).
    """
    ad = state.params["adapters"]
    assert (jax.tree_util.tree_structure(state.opt["m"])
            == jax.tree_util.tree_structure(ad)), \
        "optimizer state is not sized by the adapter params"
    lr_scale = warmup_cosine(state.step, warmup_steps=warmup_steps,
                             total_steps=total_steps) \
        if total_steps else 1.0
    new_ad, new_opt, opt_metrics = adamw_update(
        ad, adapter_grads, state.opt, opt_cfg, lr_scale)
    new_params = dict(state.params, adapters=new_ad)
    return TrainState(params=new_params, opt=new_opt,
                      step=state.step + 1), opt_metrics


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, *,
                    window: Optional[int] = None,
                    total_steps: int = 10000,
                    warmup_steps: int = 100,
                    grad_accum: int = 1,
                    accum_dtype: str = "float32",
                    remat: Optional[bool] = None,
                    remat_group: Optional[int] = None) -> Callable:
    """Build the jit-able train step.

    ``remat`` / ``remat_group`` override the config's stack-executor
    policy (``repro.models.stack``): ``remat=True`` checkpoints each
    layer body, ``remat_group=k>1`` additionally enables two-level
    (sqrt-L) checkpointing.  The backward pass through the stack relies
    on ``repro.utils.grad_safe_barrier`` keeping the anti-hoisting
    barrier differentiable — gradients flow across the split cut for
    every config and both remat modes.

    ``grad_accum`` > 1 splits the global batch into microbatches processed
    by a lax.scan with gradient accumulation — the standard lever for
    fitting per-step activation memory (EXPERIMENTS.md §Perf: 256x4k tokens
    do not fit at once even with remat + flash-vjp attention).

    ``accum_dtype="bfloat16"`` accumulates in bf16: XLA sinks an fp32
    accumulator's convert into the backward scan and materializes fp32
    copies of every saved layer input (~2x residual memory, measured
    +9 GiB/dev on deepseek-v2; EXPERIMENTS.md SSPerf A6).  bf16
    accumulation of <=16 microbatches costs ~0.4% relative gradient error
    before the fp32 Adam update.
    """
    if remat is not None or remat_group is not None:
        cfg = dataclasses.replace(
            cfg,
            remat=cfg.remat if remat is None else remat,
            remat_group=cfg.remat_group if remat_group is None
            else remat_group)
    alpha = cfg.split.quant.commit_alpha

    def loss_fn(params, batch, rng):
        logits, aux = tf.forward(params, cfg, batch, rng=rng, window=window)
        return composite_loss(logits, batch, aux, alpha)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch, rng):
        if grad_accum <= 1:
            (_, metrics), grads = grad_fn(params, batch, rng)
            return grads, metrics

        def to_micro(x):
            return x.reshape((grad_accum, x.shape[0] // grad_accum)
                             + x.shape[1:])

        # positions are per-sequence, not per-sample: broadcast, don't split
        positions = batch.get("positions")
        micro = jax.tree_util.tree_map(
            to_micro, {k: v for k, v in batch.items() if k != "positions"})

        def body(carry, mb):
            grads_acc, metrics_acc, rng = carry
            rng, sub = jax.random.split(rng)
            mb = shard_ctx.constrain_batch_tree(mb)
            if positions is not None:
                mb = dict(mb, positions=positions)
            (_, metrics), grads = grad_fn(params, mb, sub)
            # pin per-microbatch grads + the fp32 accumulator to the param
            # (FSDP) sharding: reduce-scatter instead of all-reduce, and a
            # 16x smaller accumulator (EXPERIMENTS.md SSPerf A3)
            grads = shard_ctx.constrain_like_params(grads)
            acc_dt = jnp.bfloat16 if accum_dtype == "bfloat16" \
                else jnp.float32
            grads_acc = shard_ctx.constrain_like_params(
                jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(acc_dt), grads_acc, grads))
            metrics_acc = jax.tree_util.tree_map(
                lambda a, m: a + m / grad_accum, metrics_acc, metrics)
            return (grads_acc, metrics_acc, rng), None

        acc_dt0 = jnp.bfloat16 if accum_dtype == "bfloat16" \
            else jnp.float32
        zeros_like_f32 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, acc_dt0), params)
        metrics0 = dict(loss=0.0, ce=0.0, commit=0.0, load_balance=0.0,
                        drop_fraction=0.0)
        metrics0 = {k: jnp.zeros((), jnp.float32) for k in metrics0}
        (grads, metrics, _), _ = jax.lax.scan(
            body, (zeros_like_f32, metrics0, rng), micro)
        grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
        return grads, metrics

    def train_step(state: TrainState, batch: Dict,
                   rng: jax.Array) -> Tuple[TrainState, Dict]:
        grads, metrics = compute_grads(state.params, batch, rng)
        state, opt_metrics = apply_gradients(state, grads, opt_cfg,
                                             warmup_steps=warmup_steps,
                                             total_steps=total_steps)
        metrics.update(opt_metrics)
        return state, metrics

    return train_step


def train_loop(cfg: ArchConfig, opt_cfg: AdamWConfig, data_iter, *,
               n_steps: int, seed: int = 0, log_every: int = 10,
               window: Optional[int] = None,
               callback: Optional[Callable[[int, Dict], None]] = None
               ) -> Tuple[TrainState, list]:
    """Single-host training loop (examples / Table-3 benchmarks)."""
    key = jax.random.PRNGKey(seed)
    state = init_state(key, cfg, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, window=window,
                                      total_steps=n_steps))
    history = []
    for i in range(n_steps):
        batch = next(data_iter)
        key, sub = jax.random.split(key)
        state, metrics = step_fn(state, batch, sub)
        if i % log_every == 0 or i == n_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append((i, m))
            if callback:
                callback(i, m)
    return state, history
