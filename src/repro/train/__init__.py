from repro.train.loop import (TrainState, init_state, make_train_step,
                              train_loop)
from repro.train.losses import composite_loss, cross_entropy

__all__ = ["TrainState", "init_state", "make_train_step", "train_loop",
           "composite_loss", "cross_entropy"]
