"""Loss functions: masked CE + the paper's composite split-learning loss.

L(Y, Y_hat) = CrossEntropy(Y, Y_hat) + alpha * L_comm      (Section 3.2.2)

plus standard MoE auxiliaries (load-balance, router-z) for the MoE
architectures.  Labels == IGNORE (-100) are masked (image positions in VLM
sequences, padding).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

IGNORE = -100
MOE_LB_COEF = 0.01
MOE_Z_COEF = 1e-3


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Masked mean CE.  logits (..., V); labels (...,) int with IGNORE."""
    mask = (labels != IGNORE).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)


def composite_loss(logits: jnp.ndarray, batch: Dict, aux: Dict,
                   commit_alpha: float) -> Tuple[jnp.ndarray, Dict]:
    """Paper loss + MoE auxiliaries.  Handles text/vlm/audio label layouts."""
    if "labels_codes" in batch:  # audio: logits (B,S,K,V), labels (B,K,S)
        labels = batch["labels_codes"].transpose(0, 2, 1)  # (B,S,K)
        ce = cross_entropy(logits, labels)
    else:
        ce = cross_entropy(logits, batch["labels"])
    loss = ce + commit_alpha * aux["commit"]
    loss = loss + MOE_LB_COEF * aux["load_balance"] + \
        MOE_Z_COEF * aux["router_z"]
    metrics = dict(loss=loss, ce=ce, commit=aux["commit"],
                   load_balance=aux["load_balance"],
                   drop_fraction=aux["drop_fraction"])
    return loss, metrics
