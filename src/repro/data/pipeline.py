"""Synthetic, shardable data pipelines.

No LLaVA-1.5 data is available offline (DESIGN.md SS3), so each modality
gets a *learnable* synthetic task whose difficulty is sensitive to boundary
-activation fidelity — which is exactly what the Table-3 benchmark needs to
rank compression methods:

* text: affine-Markov next-token stream  t_{i+1} = (a t_i + b) mod V with
  occasional resets — learnable by a tiny LM, requires propagating state.
* vlm (synthetic VQA): images are class prototypes + noise in vision space;
  the answer tokens deterministically encode the class id.  Getting the
  answer right requires the class to survive the compressed cut — the
  quantization bottleneck is on the information path, as in real VQA.
* audio: per-codebook cyclic progressions with codebook-coupled phase.

Batches are numpy dicts; callers ``jax.device_put`` them with the mesh
sharding (see launch/train.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.train.losses import IGNORE


@dataclasses.dataclass
class PipelineConfig:
    batch_size: int = 8
    seq_len: int = 64
    seed: int = 0
    n_classes: int = 16  # vlm task
    answer_len: int = 4


class SyntheticPipeline:
    def __init__(self, arch: ArchConfig, pcfg: PipelineConfig):
        self.arch = arch
        self.pcfg = pcfg
        self.rng = np.random.default_rng(pcfg.seed)
        if arch.modality == "vlm":
            self.prototypes = self.rng.normal(
                size=(pcfg.n_classes, arch.d_vision)).astype(np.float32)

    # ------------------------------------------------------------------
    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> Dict[str, np.ndarray]:
        a = self.arch
        if a.modality == "vlm":
            return self._vqa_batch()
        if a.modality == "audio":
            return self._audio_batch()
        return self._text_batch()

    # ------------------------------------------------------------------
    def _text_batch(self) -> Dict[str, np.ndarray]:
        p, a = self.pcfg, self.arch
        v = a.vocab_size
        # fixed affine map for the whole stream: next-token is a learnable
        # (memorizable) function of the current token
        mult, add = 5, 17
        t0 = self.rng.integers(0, v, size=(p.batch_size, 1))
        toks = [t0]
        for _ in range(p.seq_len - 1):
            toks.append((toks[-1] * mult + add) % v)
        tokens = np.concatenate(toks, axis=1).astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((p.batch_size, 1), IGNORE)],
            axis=1).astype(np.int32)
        return dict(tokens=tokens, labels=labels,
                    positions=np.arange(p.seq_len, dtype=np.int32))

    def _vqa_batch(self) -> Dict[str, np.ndarray]:
        p, a = self.pcfg, self.arch
        b = p.batch_size
        cls = self.rng.integers(0, p.n_classes, size=(b,))
        img = (self.prototypes[cls][:, None, :] +
               0.3 * self.rng.normal(size=(b, a.n_image_tokens, a.d_vision))
               ).astype(np.float32)
        text_len = p.seq_len
        tokens = self.rng.integers(0, a.vocab_size,
                                   size=(b, text_len)).astype(np.int32)
        # answer: last `answer_len` positions encode the class id
        ans = np.stack([(cls + j) % min(a.vocab_size, 256)
                        for j in range(p.answer_len)], axis=1)
        tokens[:, -p.answer_len:] = ans
        full_len = a.n_image_tokens + text_len
        labels = np.full((b, full_len), IGNORE, np.int64)
        # predict answer tokens (teacher forcing: label at pos i-1 is tok i)
        start = full_len - p.answer_len
        labels[:, start - 1:full_len - 1] = ans
        return dict(image_embeds=img, tokens=tokens,
                    labels=labels.astype(np.int32),
                    positions=np.arange(full_len, dtype=np.int32))

    def _audio_batch(self) -> Dict[str, np.ndarray]:
        p, a = self.pcfg, self.arch
        b, k, v = p.batch_size, a.n_codebooks, a.vocab_size
        phase = self.rng.integers(0, v, size=(b, k, 1))
        step = np.arange(p.seq_len)[None, None, :]
        stride = np.arange(1, k + 1)[None, :, None]
        codes = ((phase + stride * step) % v).astype(np.int32)
        labels = np.concatenate(
            [codes[:, :, 1:], np.full((b, k, 1), IGNORE)],
            axis=2).astype(np.int32)
        return dict(codes=codes, labels_codes=labels,
                    positions=np.arange(p.seq_len, dtype=np.int32))


def make_pipeline(arch: ArchConfig, batch_size: int, seq_len: int,
                  seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    if arch.modality == "vlm":
        seq_len = max(8, seq_len - arch.n_image_tokens)
    return iter(SyntheticPipeline(
        arch, PipelineConfig(batch_size=batch_size, seq_len=seq_len,
                             seed=seed)))
