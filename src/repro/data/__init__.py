from repro.data.pipeline import (PipelineConfig, SyntheticPipeline,
                                 make_pipeline)

__all__ = ["PipelineConfig", "SyntheticPipeline", "make_pipeline"]
