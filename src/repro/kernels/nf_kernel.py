"""Pallas TPU kernel: blockwise NF-b (QLoRA) quantize / dequantize.

One grid step processes a (BLOCKS_PER_TILE x G) tile of activation blocks:
per-block (min, range) reduction, normalize onto [-1, 1], nearest-neighbor
lookup against the <=16-entry NF codebook held in VMEM (broadcast compare
over a tiny trailing axis — VPU-friendly, no gather), then shift-or pack
to uint8 words.  Outputs per tile: packed codes + per-block fp16 (min,
range) side-info (the "auxiliary information" whose wire cost the paper
discusses for QLoRA).

VMEM: 128 x 64 fp32 tile (32 KiB) + codebook (64 B) + outputs — tiny; the
kernel is bandwidth-bound by design (quantization is a streaming op).
Double quantization of the ranges happens outside the kernel (it touches
only NB/G scalars, 1/64th of the data).

Validated on CPU with interpret=True against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.packing import storage_bits

BLOCKS_PER_TILE = 128
_EPS = 1e-8


def _quant_kernel(x_ref, book_ref, codes_ref, m_ref, r_ref, *, bits: int,
                  g: int):
    x = x_ref[...].astype(jnp.float32)  # (BT, G)
    m = x.min(axis=1, keepdims=True)
    mx = x.max(axis=1, keepdims=True)
    rng = mx - m
    norm = 2.0 * (x - m) / (rng + _EPS) - 1.0
    book = book_ref[...].astype(jnp.float32)  # (1, n_levels)
    dist = jnp.abs(norm[..., None] - book[0][None, None, :])
    codes = jnp.argmin(dist, axis=-1).astype(jnp.uint8)  # (BT, G)
    sb = storage_bits(bits)
    per = 8 // sb
    grouped = codes.reshape(BLOCKS_PER_TILE, g // per, per)
    shifts = (jnp.arange(per, dtype=jnp.uint8) * sb)[None, None, :]
    codes_ref[...] = (grouped << shifts).sum(axis=-1).astype(jnp.uint8)
    m_ref[...] = m.astype(jnp.float16)
    r_ref[...] = rng.astype(jnp.float16)


def _dequant_kernel(w_ref, m_ref, r_ref, book_ref, out_ref, *, bits: int,
                    g: int):
    words = w_ref[...]
    m = m_ref[...].astype(jnp.float32)
    rng = r_ref[...].astype(jnp.float32)
    book = book_ref[...].astype(jnp.float32)[0]  # (n_levels,)
    sb = storage_bits(bits)
    per = 8 // sb
    shifts = (jnp.arange(per, dtype=jnp.uint8) * sb)[None, None, :]
    mask = jnp.uint8((1 << sb) - 1)
    codes = ((words[..., None] >> shifts) & mask).reshape(
        BLOCKS_PER_TILE, g)
    # gather-free lookup: one-hot contraction over the tiny codebook axis
    onehot = (codes[..., None] ==
              jnp.arange(book.shape[0], dtype=jnp.uint8)).astype(jnp.float32)
    norm = (onehot * book[None, None, :]).sum(-1)
    out_ref[...] = ((norm + 1.0) / 2.0 * rng + m).astype(out_ref.dtype)


def quantize_pallas(blocks: jnp.ndarray, book: jnp.ndarray, bits: int, *,
                    interpret: bool):
    """blocks: (NB, G) with NB % BLOCKS_PER_TILE == 0."""
    nb, g = blocks.shape
    per = 8 // storage_bits(bits)
    grid = (nb // BLOCKS_PER_TILE,)
    book2d = book.reshape(1, -1)
    return pl.pallas_call(
        functools.partial(_quant_kernel, bits=bits, g=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCKS_PER_TILE, g), lambda i: (i, 0)),
            pl.BlockSpec((1, book2d.shape[1]), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCKS_PER_TILE, g // per), lambda i: (i, 0)),
            pl.BlockSpec((BLOCKS_PER_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((BLOCKS_PER_TILE, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, g // per), jnp.uint8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float16),
            jax.ShapeDtypeStruct((nb, 1), jnp.float16),
        ],
        interpret=interpret,
    )(blocks, book2d)


def dequantize_pallas(words: jnp.ndarray, m: jnp.ndarray, rng: jnp.ndarray,
                      book: jnp.ndarray, bits: int, g: int, *,
                      out_dtype=jnp.float32, interpret: bool):
    nb = words.shape[0]
    per = 8 // storage_bits(bits)
    grid = (nb // BLOCKS_PER_TILE,)
    book2d = book.reshape(1, -1)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, bits=bits, g=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCKS_PER_TILE, g // per), lambda i: (i, 0)),
            pl.BlockSpec((BLOCKS_PER_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((BLOCKS_PER_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, book2d.shape[1]), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCKS_PER_TILE, g), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, g), out_dtype),
        interpret=interpret,
    )(words, m, rng, book2d)
