"""jit'd public wrappers for the compressor kernels.

Handles layout (flatten to 2-D, pad to tile multiples, slice back),
backend dispatch (interpret=True on CPU — the kernels target TPU), and
the cheap outside-the-kernel pieces (RD-FSQ statistics pass, NF double
quantization of block ranges).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.packing import storage_bits
from repro.core.quantizers.nf import nf_codebook
from repro.kernels import nf_kernel, rdfsq_kernel
from repro.kernels.ref import rdfsq_stats

_EPS = 1e-8


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# RD-FSQ
# ---------------------------------------------------------------------------

def _pad_to(x, mult, axis, value=0.0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@partial(jax.jit, static_argnames=("bits", "clip_sigma"))
def rdfsq_quantize(x: jnp.ndarray, bits: int, clip_sigma: float = 3.0
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused quantize+pack.  x: (B, ...) -> (packed (B, C*b/8), stats (B,2)).

    Statistics (one reduction pass) run in jnp; the streaming
    clip/scale/round/pack runs in the Pallas kernel.
    """
    b = x.shape[0]
    x2d = x.reshape(b, -1)
    c = x2d.shape[1]
    lo, hi = rdfsq_stats(x2d, clip_sigma)
    stats = jnp.concatenate([lo, hi], axis=1).astype(jnp.float32)
    xp = _pad_to(x2d.astype(jnp.float32), rdfsq_kernel.COLS, 1)
    # pad rows so the row grid divides; padded rows reuse row-0 stats
    xp = _pad_to(xp, rdfsq_kernel.ROWS, 0)
    statsp = _pad_to(stats, rdfsq_kernel.ROWS, 0, value=1.0)
    words = rdfsq_kernel.quantize_pallas(xp, statsp, bits,
                                         interpret=_interpret())
    per = 8 // storage_bits(bits)
    cw = -(-c // per)  # ceil after packing of the unpadded columns
    return words[:b, :cw], stats.astype(jnp.float16)


@partial(jax.jit, static_argnames=("bits", "n_cols", "out_dtype"))
def rdfsq_dequantize(words: jnp.ndarray, stats: jnp.ndarray, bits: int,
                     n_cols: int, out_dtype=jnp.float32) -> jnp.ndarray:
    b = words.shape[0]
    per = 8 // storage_bits(bits)
    wp = _pad_to(words, rdfsq_kernel.COLS // per, 1)
    wp = _pad_to(wp, rdfsq_kernel.ROWS, 0)
    statsp = _pad_to(stats.astype(jnp.float32), rdfsq_kernel.ROWS, 0,
                     value=1.0)
    x = rdfsq_kernel.dequantize_pallas(wp, statsp, bits,
                                       out_dtype=out_dtype,
                                       interpret=_interpret())
    return x[:b, :n_cols]


# ---------------------------------------------------------------------------
# NF-b (QLoRA)
# ---------------------------------------------------------------------------

def _double_quant(rng: jnp.ndarray, dq_group: int):
    nb = rng.shape[0]
    pad = (-nb) % dq_group
    groups = jnp.pad(rng, ((0, pad), (0, 0))).reshape(-1, dq_group)
    gscale = jnp.max(jnp.abs(groups), axis=-1, keepdims=True)
    codes = jnp.round(groups / (gscale + _EPS) * 255.0).astype(jnp.uint8)
    return codes.reshape(-1, 1)[:nb + pad], gscale[:, 0].astype(jnp.float16)


@partial(jax.jit, static_argnames=("bits", "block", "double_quant",
                                   "dq_group"))
def nf_quantize(x: jnp.ndarray, bits: int, block: int = 64,
                double_quant: bool = True, dq_group: int = 256):
    """Blockwise NF-b quantize+pack.

    Returns (packed codes (NB, G*b/8), scales, aux dict); the caller keeps
    ``x.size`` for dequantization.
    """
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, block)
    nb = blocks.shape[0]
    bpad = (-nb) % nf_kernel.BLOCKS_PER_TILE
    blocks = jnp.pad(blocks, ((0, bpad), (0, 0)))
    book = jnp.asarray(nf_codebook(bits), jnp.float32)
    words, m, rng = nf_kernel.quantize_pallas(blocks, book, bits,
                                              interpret=_interpret())
    words, m, rng = words[:nb], m[:nb], rng[:nb]
    aux = dict(block_min=m)
    if double_quant:
        codes, gscale = _double_quant(rng.astype(jnp.float32), dq_group)
        scales = codes[:nb]
        aux["dq_scale"] = gscale
    else:
        scales = rng
    return words, scales, aux


@partial(jax.jit, static_argnames=("bits", "block", "double_quant",
                                   "dq_group", "n", "out_dtype"))
def nf_dequantize(words: jnp.ndarray, scales: jnp.ndarray, aux: dict,
                  bits: int, n: int, block: int = 64,
                  double_quant: bool = True, dq_group: int = 256,
                  out_dtype=jnp.float32):
    nb = words.shape[0]
    m = aux["block_min"]
    if double_quant:
        gscale = aux["dq_scale"].astype(jnp.float32)
        pad = (-nb) % dq_group
        codes = jnp.pad(scales, ((0, pad), (0, 0))).reshape(-1, dq_group)
        rng = (codes.astype(jnp.float32) / 255.0 * gscale[:, None]
               ).reshape(-1, 1)[:nb].astype(jnp.float16)
    else:
        rng = scales
    bpad = (-nb) % nf_kernel.BLOCKS_PER_TILE
    wp = jnp.pad(words, ((0, bpad), (0, 0)))
    mp = jnp.pad(m, ((0, bpad), (0, 0)))
    rp = jnp.pad(rng, ((0, bpad), (0, 0)))
    book = jnp.asarray(nf_codebook(bits), jnp.float32)
    x = nf_kernel.dequantize_pallas(wp, mp, rp, book, bits, block,
                                    out_dtype=out_dtype,
                                    interpret=_interpret())
    return x[:nb].reshape(-1)[:n]
