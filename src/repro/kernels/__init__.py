# Custom-kernel layer: each hot spot ships as <name>_kernel.py (Pallas
# TPU kernels) + a pure-jnp oracle (ref.py / attention_ref.py) + a
# dispatch/layout wrapper (ops.py / attention_ops.py).  Current members:
#   rdfsq_kernel / nf_kernel   — the paper's wire compressor (ops.py)
#   flash_kernel               — flash attention fwd + bwd (attention_ops)
#   decode_kernel              — fused bf16/int8 single-token decode
# Kernels run compiled on TPU and interpret=True elsewhere; attention
# backend selection is REPRO_ATTN_IMPL=pallas|jnp (see attention_ops).
