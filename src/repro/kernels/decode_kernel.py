"""Pallas TPU fused single-token decode attention kernels.

The decode roofline is dominated by streaming the KV cache once per
token; the jnp path additionally round-trips the (B, H, L) fp32 score and
probability tensors through HBM — for a 32k cache those are the same
order of magnitude as the cache itself — and the int8 path materializes
a dequantized copy of every block.  These kernels stream the cache
through VMEM once, keep the online-softmax state (m, l, acc) in scratch
across the L sweep, and for the int8 cache fold the per-(token, head)
absmax scales directly into the two dots, so no dequantized K/V tile
ever exists outside VMEM.

Grid: (B, KH, nL) with the cache-length axis innermost.  Caches keep the
repo's native (B, L, KH, D) ring-buffer layout — blocks are strided
(1, bL, 1, D) DMAs, squeezed to (bL, D) in VMEM.  Masking (empty slots,
causality, sliding window) uses the runtime (kpos, qpos) vectors, and
fully-masked blocks (outside the window / not yet written) are skipped
with ``pl.when`` — the ring-buffer sweep degrades to O(window) work for
long-context serving.

Validated on CPU with interpret=True against attention_ref.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30
_TRANS_B = (((1,), (1,)), ((), ()))
_PLAIN = (((1,), (0,)), ((), ()))


# Largest cache-length block the kernels will accept: a (bL, D=256) fp32
# K tile at 2048 rows is 2 MiB — comfortably inside VMEM with V, scales
# and scratch.  Lengths with no divisor <= MAX_BLOCK (e.g. large primes)
# are rejected by pick_block and fall back to the jnp reference.
MAX_BLOCK = 2048


def pick_block(length: int, target: int = 512) -> Optional[int]:
    """VMEM-safe cache-length block: the largest divisor of ``length``
    <= min(target, MAX_BLOCK), preferring sublane-aligned (multiple-of-8)
    blocks.  Returns ``None`` when no reasonable block divides (e.g.
    prime lengths beyond MAX_BLOCK) — callers fall back to the jnp
    reference."""
    cap = min(target, MAX_BLOCK, length)
    for cand in range(cap - cap % 8, 7, -8):  # aligned, largest first
        if length % cand == 0:
            return cand
    if length <= cap:
        return length  # odd-but-small ring buffers: one block
    for cand in range(cap, 7, -1):  # unaligned beats falling back
        if length % cand == 0:
            return cand
    return None


def _valid(kp, qp, window):
    """(1, bL) mask: slot written, causal, in-window."""
    v = jnp.logical_and(kp >= 0, kp <= qp)
    if window is not None:
        v = jnp.logical_and(v, qp - kp < window)
    return v


def _online_update(s, v_blk, m_s, l_s, acc_s, p_scale=None):
    """One online-softmax step: s (G, bL) masked scores, v_blk (bL, Dv);
    ``p_scale`` (1, bL) folds the int8 V absmax scales into p before the
    dot (the l normalizer keeps the unscaled p, matching the reference
    softmax-then-scale order)."""
    m_prev = m_s[...]  # (G, 1)
    m_next = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_next)
    corr = jnp.exp(m_prev - m_next)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    m_s[...] = m_next
    pv = p if p_scale is None else p * p_scale
    pv = jax.lax.dot_general(pv.astype(v_blk.dtype), v_blk, _PLAIN,
                             preferred_element_type=jnp.float32)
    acc_s[...] = acc_s[...] * corr + pv


def _decode_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_s, l_s, acc_s, *, window, nl):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    qp = qpos_ref[...]  # (1, 1) int32
    kp = kpos_ref[...]  # (1, bL) int32
    valid = _valid(kp, qp, window)

    @pl.when(jnp.any(valid))
    def _compute():
        q = q_ref[0, 0]          # (G, D), pre-scaled
        k = k_ref[0, :, 0, :]    # (bL, D)
        s = jax.lax.dot_general(q, k, _TRANS_B,
                                preferred_element_type=jnp.float32)
        s = jnp.where(valid, s, _NEG_INF)
        _online_update(s, v_ref[0, :, 0, :], m_s, l_s, acc_s)

    @pl.when(j == nl - 1)
    def _finalize():
        o_ref[0, 0] = acc_s[...] / jnp.maximum(l_s[...], 1e-30)


def decode(qf, k_cache, v_cache, kpos, qpos, *, window, block, interpret):
    """qf: (B, KH, G, D) pre-scaled; caches (B, L, KH, D/Dv); kpos (B, L);
    qpos (B, 1) int32.  Returns (B, KH, G, Dv) fp32."""
    b, kh, g, d = qf.shape
    length = k_cache.shape[1]
    dv = v_cache.shape[-1]
    nl = length // block
    kernel = functools.partial(_decode_kernel, window=window, nl=nl)
    cache_map = lambda b_, kh_, j: (b_, j, kh_, 0)
    return pl.pallas_call(
        kernel,
        grid=(b, kh, nl),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, kh_, j: (b_, 0)),
            pl.BlockSpec((1, block), lambda b_, kh_, j: (b_, j)),
            pl.BlockSpec((1, 1, g, d), lambda b_, kh_, j: (b_, kh_, 0, 0)),
            pl.BlockSpec((1, block, 1, d), cache_map),
            pl.BlockSpec((1, block, 1, dv), cache_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv),
                               lambda b_, kh_, j: (b_, kh_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, dv), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dv), jnp.float32),
        ],
        interpret=interpret,
    )(qpos, kpos, qf, k_cache, v_cache)


def _decode_q8_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, ks_ref,
                      vs_ref, o_ref, m_s, l_s, acc_s, *, window, nl):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    qp = qpos_ref[...]
    kp = kpos_ref[...]
    valid = _valid(kp, qp, window)

    @pl.when(jnp.any(valid))
    def _compute():
        q = q_ref[0, 0]                        # (G, D)
        k = k_ref[0, :, 0, :].astype(q.dtype)  # (bL, D) int8 codes
        s = jax.lax.dot_general(q, k, _TRANS_B,
                                preferred_element_type=jnp.float32)
        s = s * ks_ref[0]                      # fold K absmax scales
        s = jnp.where(valid, s, _NEG_INF)
        _online_update(s, v_ref[0, :, 0, :].astype(q.dtype), m_s, l_s,
                       acc_s, p_scale=vs_ref[0])  # fold V absmax scales

    @pl.when(j == nl - 1)
    def _finalize():
        o_ref[0, 0] = acc_s[...] / jnp.maximum(l_s[...], 1e-30)


def decode_q8(qf, k_codes, v_codes, k_scale, v_scale, kpos, qpos, *,
              window, block, interpret):
    """Int8-cache decode.  qf (B, KH, G, D) pre-scaled; codes
    (B, L, KH, D) int8; scales (B, KH, L) fp32 (pre-transposed by the
    caller — they are D-times smaller than the codes).  Returns
    (B, KH, G, D) fp32."""
    b, kh, g, d = qf.shape
    length = k_codes.shape[1]
    nl = length // block
    kernel = functools.partial(_decode_q8_kernel, window=window, nl=nl)
    cache_map = lambda b_, kh_, j: (b_, j, kh_, 0)
    scale_map = lambda b_, kh_, j: (b_, kh_, j)
    return pl.pallas_call(
        kernel,
        grid=(b, kh, nl),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, kh_, j: (b_, 0)),
            pl.BlockSpec((1, block), lambda b_, kh_, j: (b_, j)),
            pl.BlockSpec((1, 1, g, d), lambda b_, kh_, j: (b_, kh_, 0, 0)),
            pl.BlockSpec((1, block, 1, d), cache_map),
            pl.BlockSpec((1, block, 1, d), cache_map),
            pl.BlockSpec((1, 1, block), scale_map),
            pl.BlockSpec((1, 1, block), scale_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b_, kh_, j: (b_, kh_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qpos, kpos, qf, k_codes, v_codes, k_scale, v_scale)


# ---------------------------------------------------------------------------
# paged lookup path (serving engine: KV pool + per-request page tables)
# ---------------------------------------------------------------------------
#
# The continuous-batching engine stores the KV cache as fixed-size pages
# in a shared pool; each slot owns a page table mapping logical page j to
# a physical pool page.  The page table rides in as a *scalar-prefetch*
# operand (PrefetchScalarGridSpec), so the BlockSpec index maps read it to
# DMA each slot's pages straight out of the pool — no gathered contiguous
# copy of the cache ever exists.  Unallocated entries (-1) are clamped to
# physical page 0 (the engine's reserved null page) for the DMA and masked
# out in-kernel via the prefetched table, so whatever page 0 holds never
# contributes.  Grid: (S, KH, npp), page axis innermost — the same
# online-softmax scratch sweep as the contiguous kernels above.

def _pt_phys(pt_ref, s, j):
    """Clamped physical page for (slot s, logical page j)."""
    return jnp.maximum(pt_ref[s, j], 0)


def _decode_paged_kernel(pt_ref, qpos_ref, q_ref, k_ref, v_ref, pos_ref,
                         o_ref, m_s, l_s, acc_s, *, window, npp):
    s_idx = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    qp = qpos_ref[...]  # (1, 1) int32
    kp = pos_ref[...]   # (1, pg) int32
    valid = _valid(kp, qp, window) & (pt_ref[s_idx, j] >= 0)

    @pl.when(jnp.any(valid))
    def _compute():
        q = q_ref[0, 0]          # (G, D), pre-scaled
        k = k_ref[0, :, 0, :]    # (pg, D)
        s = jax.lax.dot_general(q, k, _TRANS_B,
                                preferred_element_type=jnp.float32)
        s = jnp.where(valid, s, _NEG_INF)
        _online_update(s, v_ref[0, :, 0, :], m_s, l_s, acc_s)

    @pl.when(j == npp - 1)
    def _finalize():
        o_ref[0, 0] = acc_s[...] / jnp.maximum(l_s[...], 1e-30)


def decode_paged(qf, k_pool, v_pool, pos_pool, page_table, qpos, *,
                 window, interpret):
    """Paged-pool decode.  qf: (S, KH, G, D) pre-scaled; pools
    (P, pg, KH, D/Dv); pos_pool (P, pg) int32; page_table (S, npp) int32
    (-1 = unallocated); qpos (S, 1) int32.  Returns (S, KH, G, Dv) fp32."""
    s, kh, g, d = qf.shape
    pg = k_pool.shape[1]
    dv = v_pool.shape[-1]
    npp = page_table.shape[1]
    kernel = functools.partial(_decode_paged_kernel, window=window, npp=npp)
    pool_map = lambda s_, kh_, j, pt: (_pt_phys(pt, s_, j), 0, kh_, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(s, kh, npp),
        in_specs=[
            pl.BlockSpec((1, 1), lambda s_, kh_, j, pt: (s_, 0)),
            pl.BlockSpec((1, 1, g, d), lambda s_, kh_, j, pt: (s_, kh_, 0, 0)),
            pl.BlockSpec((1, pg, 1, d), pool_map),
            pl.BlockSpec((1, pg, 1, dv), pool_map),
            pl.BlockSpec((1, pg),
                         lambda s_, kh_, j, pt: (_pt_phys(pt, s_, j), 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv),
                               lambda s_, kh_, j, pt: (s_, kh_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, kh, g, dv), jnp.float32),
        interpret=interpret,
    )(page_table, qpos, qf, k_pool, v_pool, pos_pool)


def _decode_paged_q8_kernel(pt_ref, qpos_ref, q_ref, k_ref, v_ref, ks_ref,
                            vs_ref, pos_ref, o_ref, m_s, l_s, acc_s, *,
                            window, npp):
    s_idx = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    qp = qpos_ref[...]
    kp = pos_ref[...]
    valid = _valid(kp, qp, window) & (pt_ref[s_idx, j] >= 0)

    @pl.when(jnp.any(valid))
    def _compute():
        q = q_ref[0, 0]                        # (G, D)
        k = k_ref[0, :, 0, :].astype(q.dtype)  # (pg, D) int8 codes
        s = jax.lax.dot_general(q, k, _TRANS_B,
                                preferred_element_type=jnp.float32)
        s = s * ks_ref[0]                      # fold K absmax scales
        s = jnp.where(valid, s, _NEG_INF)
        _online_update(s, v_ref[0, :, 0, :].astype(q.dtype), m_s, l_s,
                       acc_s, p_scale=vs_ref[0])  # fold V absmax scales

    @pl.when(j == npp - 1)
    def _finalize():
        o_ref[0, 0] = acc_s[...] / jnp.maximum(l_s[...], 1e-30)


def decode_paged_q8(qf, k_pool, v_pool, k_scale, v_scale, pos_pool,
                    page_table, qpos, *, window, interpret):
    """Paged int8-pool decode.  Codes (P, pg, KH, D) int8; scales
    (P, KH, pg) fp32 (pre-transposed by the caller); otherwise as
    :func:`decode_paged`.  Returns (S, KH, G, D) fp32."""
    s, kh, g, d = qf.shape
    pg = k_pool.shape[1]
    npp = page_table.shape[1]
    kernel = functools.partial(_decode_paged_q8_kernel, window=window,
                               npp=npp)
    pool_map = lambda s_, kh_, j, pt: (_pt_phys(pt, s_, j), 0, kh_, 0)
    scale_map = lambda s_, kh_, j, pt: (_pt_phys(pt, s_, j), kh_, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(s, kh, npp),
        in_specs=[
            pl.BlockSpec((1, 1), lambda s_, kh_, j, pt: (s_, 0)),
            pl.BlockSpec((1, 1, g, d), lambda s_, kh_, j, pt: (s_, kh_, 0, 0)),
            pl.BlockSpec((1, pg, 1, d), pool_map),
            pl.BlockSpec((1, pg, 1, d), pool_map),
            pl.BlockSpec((1, 1, pg), scale_map),
            pl.BlockSpec((1, 1, pg), scale_map),
            pl.BlockSpec((1, pg),
                         lambda s_, kh_, j, pt: (_pt_phys(pt, s_, j), 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda s_, kh_, j, pt: (s_, kh_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, kh, g, d), jnp.float32),
        interpret=interpret,
    )(page_table, qpos, qf, k_pool, v_pool, k_scale, v_scale, pos_pool)
