"""Pallas TPU flash-attention kernels (forward + backward).

Attention is the dominant FLOP and HBM-traffic path in train, prefill,
the split pipeline and decode; the jnp reference
(``kernels/attention_ref.py``) pays scan-carry materialization, per-chunk
``lax.cond`` dispatch and fp32 accumulator round-trips through HBM that a
fused kernel keeps in VMEM.  Three kernels:

* ``forward``  — online softmax over (q-block, kv-block) grid cells with
  the kv axis innermost; the fp32 (m, l, acc) state lives in VMEM scratch
  across the kv sweep and only the normalized output + per-row (m, l)
  ever reach HBM.  Returns ``(out fp32, m, l)``.
* ``backward_dq`` — same sweep; recomputes per-block probabilities from
  the saved (m, l) exactly like the jnp VJP, so no (Sq x Skv) tensor is
  ever materialized.
* ``backward_dkv`` — kv-major sweep with the (GQA group, q-block) axes
  innermost, accumulating dK/dV for each KV head in VMEM scratch.

Masking uses RUNTIME position vectors (qpos along sublanes, kpos along
lanes) rather than trace-time iota — the same contract as the reference:
the sentinels (+/-2^30) encode padding and ``kv_valid_len``, and arbitrary
position ids keep working.  Fully-masked grid cells are skipped with
``pl.when`` on block min/max positions (splash-attention style), which
preserves the causal ~2x and sliding-window O(S*W) savings.

Row state (m, l, delta) is carried at lane-width 1 — (bq, 1) fp32 tiles —
instead of the 128-wide replicated idiom: the HBM-level residuals stay
(B, H, Sq, 1) so the train-memory story of the custom VJP is unchanged.

Validated on CPU with interpret=True against attention_ref (see
tests/test_attention_pallas.py); layout is (B, H, S, D) inside the
kernels, transposed at the ``attention_ops`` boundary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30
_TRANS_B = (((1,), (1,)), ((), ()))   # (a, b) -> a @ b.T
_TRANS_A = (((0,), (0,)), ((), ()))   # (a, b) -> a.T @ b
_PLAIN = (((1,), (0,)), ((), ()))     # (a, b) -> a @ b


def _visible(qp, kp, window):
    """Block-level skip predicate from runtime position extrema."""
    vis = jnp.min(kp) <= jnp.max(qp)
    if window is not None:
        vis = jnp.logical_and(vis, jnp.max(kp) > jnp.min(qp) - window)
    return vis


def _mask(qp, kp, window):
    """(bq, bkv) mask from qp (bq, 1) / kp (1, bkv) runtime positions."""
    m = kp <= qp
    if window is not None:
        m = jnp.logical_and(m, qp - kp < window)
    return m


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref,
                o_ref, m_ref, l_ref, m_s, l_s, acc_s, *, window, nkv):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    qp = qpos_ref[...]  # (bq, 1) int32
    kp = kpos_ref[...]  # (1, bkv) int32

    @pl.when(_visible(qp, kp, window))
    def _compute():
        q = q_ref[0, 0]  # (bq, D), pre-scaled
        k = k_ref[0, 0]  # (bkv, D)
        s = jax.lax.dot_general(q, k, _TRANS_B,
                                preferred_element_type=jnp.float32)
        s = jnp.where(_mask(qp, kp, window), s, _NEG_INF)
        m_prev = m_s[...]  # (bq, 1)
        l_prev = l_s[...]
        m_next = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_next)
        corr = jnp.exp(m_prev - m_next)
        l_s[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        m_s[...] = m_next
        v = v_ref[0, 0]  # (bkv, Dv)
        pv = jax.lax.dot_general(p.astype(v.dtype), v, _PLAIN,
                                 preferred_element_type=jnp.float32)
        acc_s[...] = acc_s[...] * corr + pv

    @pl.when(j == nkv - 1)
    def _finalize():
        l_fin = l_s[...]
        o_ref[0, 0] = acc_s[...] / jnp.maximum(l_fin, 1e-30)
        m_ref[0, 0] = m_s[...]
        l_ref[0, 0] = l_fin


def forward(q, k, v, qpos, kpos, *, window, block, interpret):
    """q: (B, H, Sq, D) pre-scaled; k/v: (B, KH, Skv, D/Dv); qpos (Sq, 1),
    kpos (1, Skv) int32 with sentinel padding; Sq/Skv multiples of
    ``block``.  Returns (out fp32 (B, H, Sq, Dv), m, l (B, H, Sq, 1))."""
    b, h, sq, d = q.shape
    kh, skv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // kh
    nq, nkv = sq // block, skv // block
    grid = (b, h, nq, nkv)
    kernel = functools.partial(_fwd_kernel, window=window, nkv=nkv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, 1), lambda b_, h_, i, j: (i, 0)),
            pl.BlockSpec((1, block), lambda b_, h_, i, j: (0, j)),
            pl.BlockSpec((1, 1, block, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block, d),
                         lambda b_, h_, i, j: (b_, h_ // g, j, 0)),
            pl.BlockSpec((1, 1, block, dv),
                         lambda b_, h_, i, j: (b_, h_ // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block, dv),
                         lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block, 1), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block, 1), lambda b_, h_, i, j: (b_, h_, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, sq, dv), jnp.float32),
            jax.ShapeDtypeStruct((b, h, sq, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, h, sq, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block, 1), jnp.float32),
            pltpu.VMEM((block, 1), jnp.float32),
            pltpu.VMEM((block, dv), jnp.float32),
        ],
        interpret=interpret,
    )(qpos, kpos, q, k, v)


# ---------------------------------------------------------------------------
# backward: dQ (q-major sweep, kv innermost)
# ---------------------------------------------------------------------------

def _dq_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, go_ref, m_ref, l_ref,
               di_ref, dq_ref, dq_s, *, window, nkv):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        dq_s[...] = jnp.zeros_like(dq_s)

    qp = qpos_ref[...]
    kp = kpos_ref[...]

    @pl.when(_visible(qp, kp, window))
    def _compute():
        q = q_ref[0, 0]
        k = k_ref[0, 0]
        s = jax.lax.dot_general(q, k, _TRANS_B,
                                preferred_element_type=jnp.float32)
        s = jnp.where(_mask(qp, kp, window), s, _NEG_INF)
        linv = 1.0 / jnp.maximum(l_ref[0, 0], 1e-30)  # (bq, 1)
        p = jnp.exp(s - m_ref[0, 0]) * linv
        go = go_ref[0, 0]  # (bq, Dv)
        v = v_ref[0, 0]    # (bkv, Dv)
        dp = jax.lax.dot_general(go, v, _TRANS_B,
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - di_ref[0, 0])
        dq_s[...] += jax.lax.dot_general(ds.astype(k.dtype), k, _PLAIN,
                                         preferred_element_type=jnp.float32)

    @pl.when(j == nkv - 1)
    def _finalize():
        dq_ref[0, 0] = dq_s[...]


def backward_dq(q, k, v, go, m, l, di, qpos, kpos, *, window, block,
                interpret):
    """Inputs in (B, H/KH, S, ...) layout (see ``forward``); go
    (B, H, Sq, Dv); m/l/di (B, H, Sq, 1) fp32.  Returns dq fp32
    (B, H, Sq, D) w.r.t. the pre-scaled query."""
    b, h, sq, d = q.shape
    kh, skv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // kh
    nq, nkv = sq // block, skv // block
    kernel = functools.partial(_dq_kernel, window=window, nkv=nkv)
    qo_map = lambda b_, h_, i, j: (b_, h_, i, 0)
    kv_map = lambda b_, h_, i, j: (b_, h_ // g, j, 0)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nkv),
        in_specs=[
            pl.BlockSpec((block, 1), lambda b_, h_, i, j: (i, 0)),
            pl.BlockSpec((1, block), lambda b_, h_, i, j: (0, j)),
            pl.BlockSpec((1, 1, block, d), qo_map),
            pl.BlockSpec((1, 1, block, d), kv_map),
            pl.BlockSpec((1, 1, block, dv), kv_map),
            pl.BlockSpec((1, 1, block, dv), qo_map),
            pl.BlockSpec((1, 1, block, 1), qo_map),
            pl.BlockSpec((1, 1, block, 1), qo_map),
            pl.BlockSpec((1, 1, block, 1), qo_map),
        ],
        out_specs=pl.BlockSpec((1, 1, block, d), qo_map),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block, d), jnp.float32)],
        interpret=interpret,
    )(qpos, kpos, q, k, v, go, m, l, di)


# ---------------------------------------------------------------------------
# backward: dK/dV (kv-major sweep, (group, q) innermost)
# ---------------------------------------------------------------------------

def _dkv_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, go_ref, m_ref,
                l_ref, di_ref, dk_ref, dv_ref, dk_s, dv_s, *, window,
                ng, nq):
    g_idx = pl.program_id(3)
    i = pl.program_id(4)

    @pl.when(jnp.logical_and(g_idx == 0, i == 0))
    def _init():
        dk_s[...] = jnp.zeros_like(dk_s)
        dv_s[...] = jnp.zeros_like(dv_s)

    qp = qpos_ref[...]
    kp = kpos_ref[...]

    @pl.when(_visible(qp, kp, window))
    def _compute():
        q = q_ref[0, 0]
        k = k_ref[0, 0]
        s = jax.lax.dot_general(q, k, _TRANS_B,
                                preferred_element_type=jnp.float32)
        s = jnp.where(_mask(qp, kp, window), s, _NEG_INF)
        linv = 1.0 / jnp.maximum(l_ref[0, 0], 1e-30)
        p = jnp.exp(s - m_ref[0, 0]) * linv  # (bq, bkv)
        go = go_ref[0, 0]
        v = v_ref[0, 0]
        dv_s[...] += jax.lax.dot_general(p.astype(go.dtype), go, _TRANS_A,
                                         preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(go, v, _TRANS_B,
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - di_ref[0, 0])
        dk_s[...] += jax.lax.dot_general(ds.astype(q.dtype), q, _TRANS_A,
                                         preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(g_idx == ng - 1, i == nq - 1))
    def _finalize():
        dk_ref[0, 0] = dk_s[...]
        dv_ref[0, 0] = dv_s[...]


def backward_dkv(q, k, v, go, m, l, di, qpos, kpos, *, window, block,
                 interpret):
    """Returns (dk, dv) fp32 in (B, KH, Skv, D/Dv) layout; the GQA group
    sum happens in VMEM scratch across the (group, q-block) grid axes."""
    b, h, sq, d = q.shape
    kh, skv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // kh
    nq, nkv = sq // block, skv // block
    kernel = functools.partial(_dkv_kernel, window=window, ng=g, nq=nq)
    qo_map = lambda b_, kh_, j, g_, i: (b_, kh_ * g + g_, i, 0)
    kv_map = lambda b_, kh_, j, g_, i: (b_, kh_, j, 0)
    return pl.pallas_call(
        kernel,
        grid=(b, kh, nkv, g, nq),
        in_specs=[
            pl.BlockSpec((block, 1), lambda b_, kh_, j, g_, i: (i, 0)),
            pl.BlockSpec((1, block), lambda b_, kh_, j, g_, i: (0, j)),
            pl.BlockSpec((1, 1, block, d), qo_map),
            pl.BlockSpec((1, 1, block, d), kv_map),
            pl.BlockSpec((1, 1, block, dv), kv_map),
            pl.BlockSpec((1, 1, block, dv), qo_map),
            pl.BlockSpec((1, 1, block, 1), qo_map),
            pl.BlockSpec((1, 1, block, 1), qo_map),
            pl.BlockSpec((1, 1, block, 1), qo_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block, d), kv_map),
            pl.BlockSpec((1, 1, block, dv), kv_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kh, skv, d), jnp.float32),
            jax.ShapeDtypeStruct((b, kh, skv, dv), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block, d), jnp.float32),
            pltpu.VMEM((block, dv), jnp.float32),
        ],
        interpret=interpret,
    )(qpos, kpos, q, k, v, go, m, l, di)
