"""Backend dispatch for the attention kernels.

Mirrors the ``kernels/ops.py`` idiom for the compressor: layout handling
(transposes to the kernels' (B, H, S, D) form), ``interpret=True`` off
TPU, and implementation selection.

Selection order (``resolve_impl``):
  1. explicit ``impl=`` keyword threaded through the public APIs in
     ``models/layers/attention.py`` (used by parity tests / benchmarks);
  2. the ``REPRO_ATTN_IMPL`` environment variable (``pallas`` | ``jnp``)
     for zero-code A/B flips;
  3. default: Pallas on TPU backends, the jnp reference elsewhere (the
     interpreter is correct but slow, so CPU CI stays on jnp unless a
     test opts in).

``flash_pallas`` is the Pallas twin of ``attention_ref.flash_reference``
— same operand contract (pre-scaled q, sentinel positions, chunk-aligned
padding), same custom-VJP residuals (out, m, l), so ``flash_attention``
can swap them 1:1 with zero call-site churn.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import attention_ref, decode_kernel, flash_kernel
from repro.utils.dispatch import resolve_backend_impl

_VALID_IMPLS = ("pallas", "jnp")


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def compiled_shape_ok(block: int) -> bool:
    """Gate for the COMPILED (real-TPU) kernels: sub-8 / unaligned
    sequence blocks produce sublane tiles Mosaic handles poorly (or not
    at all), so hostile shapes fall back to the jnp reference on TPU.
    Interpret mode has no such constraint — CPU parity tests exercise
    the kernels at any block size."""
    return _interpret() or (block >= 8 and block % 8 == 0)


def resolve_impl(impl: Optional[str] = None) -> str:
    """Resolve the attention backend (see module docstring for order)."""
    return resolve_backend_impl(impl, "REPRO_ATTN_IMPL", "attention",
                                _VALID_IMPLS)


# ---------------------------------------------------------------------------
# train / prefill flash attention
# ---------------------------------------------------------------------------

def _to_bhsd(x):
    return x.transpose(0, 2, 1, 3)


@partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def flash_pallas(q, k, v, qpos, kpos, window, chunk):
    """Pallas flash attention on pre-scaled, chunk-padded operands.

    Same contract as ``attention_ref.flash_reference``: q (B, Sq, H, D)
    pre-multiplied by 1/sqrt(D), k/v (B, Skv, KH, D/Dv), qpos/kpos with
    +/-2^30 sentinels.  Returns (B, Sq, H, Dv) in q.dtype.
    """
    out, _, _ = _flash_pallas_fwd_impl(q, k, v, qpos, kpos, window, chunk)
    return out


def _flash_pallas_fwd_impl(q, k, v, qpos, kpos, window, chunk):
    outs, m, l = flash_kernel.forward(
        _to_bhsd(q), _to_bhsd(k), _to_bhsd(v),
        qpos.reshape(-1, 1), kpos.reshape(1, -1),
        window=window, block=chunk, interpret=_interpret())
    return _to_bhsd(outs).astype(q.dtype), m, l


def _flash_pallas_vjp_fwd(q, k, v, qpos, kpos, window, chunk):
    out, m, l = _flash_pallas_fwd_impl(q, k, v, qpos, kpos, window, chunk)
    return out, (q, k, v, qpos, kpos, out, m, l)


def _flash_pallas_vjp_bwd(window, chunk, res, gout):
    q, k, v, qpos, kpos, out, m, l = res
    # delta = rowsum(dO * O) — the only O(S) recomputation input the
    # backward kernels need beyond (m, l).
    di = jnp.einsum("bshd,bshd->bsh", gout.astype(jnp.float32),
                    out.astype(jnp.float32)).transpose(0, 2, 1)[..., None]
    qt, kt, vt = _to_bhsd(q), _to_bhsd(k), _to_bhsd(v)
    got = _to_bhsd(gout)
    qp2, kp2 = qpos.reshape(-1, 1), kpos.reshape(1, -1)
    common = dict(window=window, block=chunk, interpret=_interpret())
    dq = flash_kernel.backward_dq(qt, kt, vt, got, m, l, di, qp2, kp2,
                                  **common)
    dk, dvv = flash_kernel.backward_dkv(qt, kt, vt, got, m, l, di, qp2, kp2,
                                        **common)
    return (_to_bhsd(dq).astype(q.dtype), _to_bhsd(dk).astype(k.dtype),
            _to_bhsd(dvv).astype(v.dtype), jnp.zeros_like(qpos),
            jnp.zeros_like(kpos))


flash_pallas.defvjp(_flash_pallas_vjp_fwd, _flash_pallas_vjp_bwd)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def decode_pallas(qf, k_cache, v_cache, kpos, qpos, *, window=None):
    """Fused single-token decode.  qf (B, KH, G, D) pre-scaled; caches in
    the native (B, L, KH, D/Dv) ring-buffer layout.  Returns
    (B, KH, G, Dv) fp32."""
    block = decode_kernel.pick_block(k_cache.shape[1])
    if block is None or not compiled_shape_ok(block):
        # no VMEM-safe (or, compiled, sublane-aligned) block divides
        return attention_ref.decode_attention_ref(
            qf, k_cache, v_cache, kpos, qpos, window=window)
    return decode_kernel.decode(
        qf, k_cache, v_cache, kpos, qpos.reshape(-1, 1).astype(jnp.int32),
        window=window, block=block, interpret=_interpret())


def decode_q8_pallas(qf, k_codes, v_codes, k_scale, v_scale, kpos, qpos, *,
                     window=None):
    """Fused int8-cache decode; folds the absmax scales into the dots
    inside the kernel.  The (B, L, KH) fp16 scales are cast/transposed to
    (B, KH, L) fp32 here — they are D-times smaller than the codes."""
    block = decode_kernel.pick_block(k_codes.shape[1])
    if block is None or not compiled_shape_ok(block):
        return attention_ref.decode_attention_q8_ref(
            qf, k_codes, v_codes, k_scale, v_scale, kpos, qpos,
            window=window)
    ks = k_scale.astype(jnp.float32).transpose(0, 2, 1)
    vs = v_scale.astype(jnp.float32).transpose(0, 2, 1)
    return decode_kernel.decode_q8(
        qf, k_codes, v_codes, ks, vs, kpos,
        qpos.reshape(-1, 1).astype(jnp.int32),
        window=window, block=block, interpret=_interpret())


def decode_paged_pallas(qf, k_pool, v_pool, pos_pool, page_table, qpos, *,
                        window=None):
    """Paged-pool decode (serving engine): the page table rides in as a
    scalar-prefetch operand so pool pages are DMA'd straight from their
    physical location — no gathered contiguous cache copy.  qf
    (S, KH, G, D) pre-scaled; pools (P, pg, KH, D/Dv); page_table (S, npp)
    with -1 for unallocated; qpos (S,).  Returns (S, KH, G, Dv) fp32."""
    pg = k_pool.shape[1]
    if not compiled_shape_ok(pg):
        return attention_ref.decode_attention_paged_ref(
            qf, k_pool, v_pool, pos_pool, page_table, qpos, window=window)
    return decode_kernel.decode_paged(
        qf, k_pool, v_pool, pos_pool, page_table.astype(jnp.int32),
        qpos.reshape(-1, 1).astype(jnp.int32),
        window=window, interpret=_interpret())


def decode_paged_q8_pallas(qf, k_pool, v_pool, k_scale_pool, v_scale_pool,
                           pos_pool, page_table, qpos, *, window=None):
    """Paged int8-pool decode; absmax scales fold into the dots in-kernel.
    Scale pools arrive in the engine's native (P, pg, KH) fp16 layout and
    are cast/transposed to (P, KH, pg) fp32 here (D-times smaller than the
    codes)."""
    pg = k_pool.shape[1]
    if not compiled_shape_ok(pg):
        return attention_ref.decode_attention_paged_q8_ref(
            qf, k_pool, v_pool, k_scale_pool, v_scale_pool, pos_pool,
            page_table, qpos, window=window)
    ks = k_scale_pool.astype(jnp.float32).transpose(0, 2, 1)
    vs = v_scale_pool.astype(jnp.float32).transpose(0, 2, 1)
    return decode_kernel.decode_paged_q8(
        qf, k_pool, v_pool, ks, vs, pos_pool, page_table.astype(jnp.int32),
        qpos.reshape(-1, 1).astype(jnp.int32),
        window=window, interpret=_interpret())
