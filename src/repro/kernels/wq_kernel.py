"""Pallas TPU kernel: fused packed-int4/int3 dequant + matmul (repro.wq).

The serve-time decode path is HBM-bandwidth bound on *weights*: every
tick streams the whole server stack out of HBM at full width.  With the
weights stored as ``core.packing`` bitstreams (0.5 B/element at int4
instead of 2 B bf16), the matmul must unpack + dequantize on the fly —
done here inside the MXU pipeline so the codes never exist at 8 bits in
HBM: each grid step reads a ``(bk * bits / 8, bn)`` uint8 tile and the
``(bk / group, bn)`` fp16 scale/min tiles into VMEM, rebuilds the codes
with uint32 word arithmetic (8 consecutive codes of a column span
exactly ``bits`` whole bytes, so a ``(nb, bits, bn)`` reshape + byte
shifts yields one 32-bit word per code octet — ``bits <= 4`` fits), maps
``code * scale + min``, and contracts the dequantized ``(bk, bn)`` tile
against the activation tile in the activation dtype with an fp32 VMEM
accumulator.

HBM traffic per output tile: ``bits/16`` of the bf16 weight bytes plus
the fp16 side info (``2 * 16 / (group * bits)`` relative) — the ~3.76x
serve-time weight-bandwidth cut measured by ``benchmarks/wq_bench.py``.

Grid: ``(M / bm, N / bn, K / bk)`` with K innermost; the wrapper pads M
to ``bm``, N to ``bn = 128`` (lane width) and K to ``bk`` (a multiple of
``group`` and >= 128) — padded K rows decode against zero-padded
activations, so they contribute exactly 0.  Validated on CPU with
``interpret=True`` against ``kernels/ref.py::wq_matmul_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import packed_size

BM = 16   # sublane multiple for both fp32 (8) and bf16 (16) tiles
BN = 128  # lane width


def _matmul_kernel(x_ref, w_ref, s_ref, m_ref, o_ref, acc_ref, *,
                   bits: int, group: int, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    words = w_ref[...]                       # (bk * bits // 8, bn) uint8
    nb = words.shape[0] // bits              # 8-code octets in this K tile
    bn = words.shape[1]
    w32 = words.reshape(nb, bits, bn).astype(jnp.uint32)
    byte_shifts = (jnp.arange(bits, dtype=jnp.uint32) * 8)[None, :, None]
    word32 = (w32 << byte_shifts).sum(axis=1)          # (nb, bn)
    code_shifts = (jnp.arange(8, dtype=jnp.uint32) * bits)[None, :, None]
    mask = jnp.uint32(2 ** bits - 1)
    codes = (word32[:, None, :] >> code_shifts) & mask  # (nb, 8, bn)
    codes = codes.reshape(nb * 8, bn).astype(jnp.float32)

    gpb = (nb * 8) // group                  # groups per K tile (>= 1)
    scale = s_ref[...].astype(jnp.float32)[:, None, :]  # (gpb, 1, bn)
    mn = m_ref[...].astype(jnp.float32)[:, None, :]
    w = (codes.reshape(gpb, group, bn) * scale + mn).reshape(nb * 8, bn)

    x = x_ref[...]                           # (bm, bk) activation dtype
    acc_ref[...] += jax.lax.dot(x, w.astype(x.dtype),
                                preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(a: jnp.ndarray, axis: int, size: int) -> jnp.ndarray:
    pad = size - a.shape[axis]
    if pad <= 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("bits", "group", "d_in",
                                             "interpret"))
def matmul_pallas(x2d: jnp.ndarray, words: jnp.ndarray,
                  scales: jnp.ndarray, mins: jnp.ndarray, *, bits: int,
                  group: int, d_in: int, interpret: bool) -> jnp.ndarray:
    """(M, d_in) @ packed (d_in, d_out) -> (M, d_out) fp32.

    ``words``: (packed_size(d_in, bits), d_out) per-column bitstreams in
    STORAGE channel order (any act-order gather happened on ``x``
    upstream); ``scales``/``mins``: (ceil(d_in / group), d_out) fp16.
    """
    if bits not in (2, 3, 4):
        raise ValueError(f"fused wq kernel supports bits in (2, 3, 4); "
                         f"got {bits}")
    m, k_in = x2d.shape
    assert k_in == d_in, (k_in, d_in)
    d_out = words.shape[1]
    assert words.shape[0] == packed_size(d_in, bits), words.shape
    n_groups = -(-d_in // group)
    assert scales.shape == (n_groups, d_out), scales.shape

    bk = group * max(1, -(-BN // group))     # multiple of group, >= 128
    m_pad = -(-m // BM) * BM
    n_pad = -(-d_out // BN) * BN
    k_pad = -(-d_in // bk) * bk
    n_k = k_pad // bk

    x_p = _pad_to(_pad_to(x2d, 1, k_pad), 0, m_pad)
    w_p = _pad_to(_pad_to(words, 0, k_pad * bits // 8), 1, n_pad)
    s_p = _pad_to(_pad_to(scales, 0, k_pad // group), 1, n_pad)
    mn_p = _pad_to(_pad_to(mins, 0, k_pad // group), 1, n_pad)

    gpb = bk // group
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, bits=bits, group=group, n_k=n_k),
        grid=(m_pad // BM, n_pad // BN, n_k),
        in_specs=[
            pl.BlockSpec((BM, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk * bits // 8, BN), lambda i, j, k: (k, j)),
            pl.BlockSpec((gpb, BN), lambda i, j, k: (k, j)),
            pl.BlockSpec((gpb, BN), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        interpret=interpret,
    )(x_p, w_p, s_p, mn_p)
    return out[:m, :d_out]
