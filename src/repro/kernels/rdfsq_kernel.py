"""Pallas TPU kernel: fused RD-FSQ quantize+pack / unpack+dequantize.

The compressor sits serially on the split-learning wire (it runs on every
microbatch before the cross-pod transfer), so its latency adds directly to
the communication-critical path.  The fused kernel makes it a single
streaming VMEM pass: read a (ROWS x COLS) tile of boundary activations,
clip -> linear-scale -> round -> shift-or-pack 2/4-bit codes into uint8
words, write the packed tile.  HBM traffic is 1 read of x + 1 write of
x * bits/16 — the naive jnp path materializes the intermediate codes at
8 bits plus separate pack ops.

TPU notes: COLS=1024 keeps the lane dim a multiple of 128 both before
(1024) and after packing (1024 * bits / 8 >= 128 for bits >= 1); the
(ROWS x COLS) fp32 tile + packed output is ~36 KiB, far under the ~16 MiB
VMEM budget, leaving room for double buffering.  The MXU is not involved —
this is a VPU kernel; the per-(row)-scalar (lo, hi) side inputs ride along
as a (ROWS, 2) VMEM tile.

Validated on CPU with interpret=True against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.packing import storage_bits

ROWS = 8
COLS = 1024
_EPS = 1e-6


def _quantize_kernel(x_ref, stats_ref, out_ref, *, bits: int):
    x = x_ref[...].astype(jnp.float32)  # (ROWS, COLS)
    lo = stats_ref[:, 0:1]
    hi = stats_ref[:, 1:2]
    d = 2 ** bits
    half = (d - 1) / 2.0
    xc = jnp.clip(x, lo, hi)
    e = 2.0 * (xc - lo) / (hi - lo + _EPS) - 1.0
    if d % 2 == 1:
        z = jnp.round(half * e)
    else:
        z = jnp.round(half * e - 0.5) + 0.5
    z = jnp.clip(z, -half, half)
    idx = (z + half).astype(jnp.uint8)
    # shift-or pack: per = codes per uint8 word
    sb = storage_bits(bits)
    per = 8 // sb
    grouped = idx.reshape(ROWS, COLS // per, per)
    shifts = (jnp.arange(per, dtype=jnp.uint8) * sb)[None, None, :]
    words = (grouped << shifts).sum(axis=-1).astype(jnp.uint8)
    out_ref[...] = words


def _dequantize_kernel(w_ref, stats_ref, out_ref, *, bits: int):
    words = w_ref[...]  # (ROWS, COLS//per) uint8
    lo = stats_ref[:, 0:1]
    hi = stats_ref[:, 1:2]
    d = 2 ** bits
    half = (d - 1) / 2.0
    sb = storage_bits(bits)
    per = 8 // sb
    shifts = (jnp.arange(per, dtype=jnp.uint8) * sb)[None, None, :]
    mask = jnp.uint8((1 << sb) - 1)
    codes = ((words[..., None] >> shifts) & mask).reshape(ROWS, COLS)
    c = (codes.astype(jnp.float32) - half) / half
    out_ref[...] = ((c + 1.0) / 2.0 * (hi - lo) + lo).astype(out_ref.dtype)


def quantize_pallas(x2d: jnp.ndarray, stats: jnp.ndarray, bits: int, *,
                    interpret: bool) -> jnp.ndarray:
    """x2d: (R, C) with R % ROWS == 0, C % COLS == 0; stats: (R, 2)."""
    r, c = x2d.shape
    per = 8 // storage_bits(bits)
    grid = (r // ROWS, c // COLS)
    return pl.pallas_call(
        functools.partial(_quantize_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS, COLS), lambda i, j: (i, j)),
            pl.BlockSpec((ROWS, 2), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, COLS // per), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c // per), jnp.uint8),
        interpret=interpret,
    )(x2d, stats)


def dequantize_pallas(words: jnp.ndarray, stats: jnp.ndarray, bits: int, *,
                      out_dtype=jnp.float32, interpret: bool) -> jnp.ndarray:
    r, cw = words.shape
    per = 8 // storage_bits(bits)
    c = cw * per
    grid = (r // ROWS, c // COLS)
    return pl.pallas_call(
        functools.partial(_dequantize_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS, COLS // per), lambda i, j: (i, j)),
            pl.BlockSpec((ROWS, 2), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, COLS), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), out_dtype),
        interpret=interpret,
    )(words, stats)
