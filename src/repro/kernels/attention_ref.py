"""Pure-jnp reference attention (oracle for the Pallas kernels).

This is the memory-safe chunked (flash-style) implementation that shipped
before the Pallas kernels existed, kept verbatim as (a) the off-TPU
fallback behind ``REPRO_ATTN_IMPL=jnp`` and (b) the oracle every kernel
parity test asserts against — the same role ``kernels/ref.py`` plays for
the compressor kernels.

``flash_reference`` carries a **custom VJP**: the forward saves only
(out, row-max, row-sum); the backward recomputes per-(q-chunk, kv-chunk)
probabilities instead of storing them — without this, the lax.scan
backward would checkpoint an (Sq x Skv) probability tensor per layer and
the train_4k shapes could never fit HBM (measured: 255 GiB/dev ->
12 GiB/dev on llama3.2-3b; EXPERIMENTS.md §Perf).

Operands stay in model dtype (bf16); every dot accumulates in fp32 via
``preferred_element_type``.  Chunk-level causal/window skipping avoids
issuing fully-masked blocks (splash-attention style).

Supports: causal masking, sliding windows, GQA head grouping, Dv != Dk
(MLA), decode against ring-buffer KV caches (bf16 and int8-quantized).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

_NEG_INF = -1e30
_FAR = jnp.int32(2 ** 30)


def _block_mask(qpos, kpos, window):
    """(cq, ckv) causal/window mask from RUNTIME position vectors.

    Positions must be runtime data (not trace-time iota): if XLA can
    constant-fold the masks it widens them into (nq x nkv x ...) stacked
    buffers inside the scan loops — measured 26 GiB/device on train_4k
    before this fix (EXPERIMENTS.md SSPerf).
    """
    m = kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= qpos[:, None] - kpos[None, :] < window
    return m


# ---------------------------------------------------------------------------
# forward implementation (shared by primal and VJP fwd)
# ---------------------------------------------------------------------------

def _flash_fwd_impl(qs, k, v, qpos, kpos, *, window, chunk):
    """qs is the pre-scaled query; qpos/kpos are runtime position vectors
    (padded with +/-2^30 sentinels).  Returns (out fp32, m, l) chunked:
    out (nq, B, KH, G, cq, Dv); m, l (nq, B, KH, G, cq)."""
    b, sq, h, d = qs.shape
    skv, kh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // kh
    nq = sq // chunk
    nkv = skv // chunk

    qc_all = qs.reshape(b, nq, chunk, kh, g, d).transpose(1, 0, 3, 4, 2, 5)
    ks = k.reshape(b, nkv, chunk, kh, d).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nkv, chunk, kh, dv).transpose(1, 0, 2, 3, 4)
    qp_all = qpos.reshape(nq, chunk)
    kp_all = kpos.reshape(nkv, chunk)

    def q_body(qc, qp):  # qc: (B, KH, G, cq, D); qp: (cq,)
        def kv_body(carry, inp):
            m_run, l_run, acc = carry
            kc, vc, kp = inp

            def compute(c):
                m_run, l_run, acc = c
                s = jnp.einsum("bkgqd,bskd->bkgqs", qc, kc,
                               preferred_element_type=jnp.float32)
                mask = _block_mask(qp, kp, window)
                s = jnp.where(mask[None, None, None], s, _NEG_INF)
                m_new = jnp.maximum(m_run, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m_run - m_new)
                l_new = l_run * corr + p.sum(axis=-1)
                pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vc.dtype), vc,
                                preferred_element_type=jnp.float32)
                return m_new, l_new, acc * corr[..., None] + pv

            visible = kp.min() <= qp.max()
            if window is not None:
                visible &= kp.max() > qp.min() - window
            carry = jax.lax.cond(visible, compute, lambda c: c,
                                 (m_run, l_run, acc))
            return carry, None

        m0 = jnp.full((b, kh, g, chunk), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, chunk), jnp.float32)
        a0 = jnp.zeros((b, kh, g, chunk, dv), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0),
                                          (ks, vs, kp_all))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return out, m_f, l_f

    def q_scan(_, inp):
        qc, qp = inp
        return 0, q_body(qc, qp)

    _, (outs, ms, ls) = jax.lax.scan(q_scan, 0, (qc_all, qp_all))
    return outs, ms, ls


def _unchunk_out(outs, b, sq, h, dv, dtype):
    nq = outs.shape[0]
    q_chunk = outs.shape[4]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * q_chunk, h, dv)
    return out[:, :sq].astype(dtype)


# ---------------------------------------------------------------------------
# custom-VJP flash attention
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def flash_reference(q, k, v, qpos, kpos, window, chunk):
    """Chunked online-softmax attention on pre-scaled, pre-padded operands.

    q: (B, Sq, H, D) pre-multiplied by 1/sqrt(D); Sq and Skv are multiples
    of ``chunk``; qpos/kpos carry +/-2^30 sentinels for padding and
    kv_valid_len.  Returns (B, Sq, H, Dv) in q.dtype.
    """
    outs, _, _ = _flash_fwd_impl(q, k, v, qpos, kpos, window=window,
                                 chunk=chunk)
    b, sq, h, _ = q.shape
    return _unchunk_out(outs, b, sq, h, v.shape[-1], q.dtype)


def _flash_vjp_fwd(q, k, v, qpos, kpos, window, chunk):
    outs, ms, ls = _flash_fwd_impl(q, k, v, qpos, kpos, window=window,
                                   chunk=chunk)
    b, sq, h, _ = q.shape
    out = _unchunk_out(outs, b, sq, h, v.shape[-1], q.dtype)
    return out, (q, k, v, qpos, kpos, out, ms, ls)


def _flash_vjp_bwd(window, chunk, res, gout):
    """Flash backward: recompute per-block probabilities from saved (m, l);
    never stores an (Sq x Skv) tensor."""
    q, k, v, qpos, kpos, out, ms, ls = res
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // kh
    nq = sq // chunk
    nkv = skv // chunk

    delta_all = jnp.einsum("bshd,bshd->bsh", gout.astype(jnp.float32),
                           out.astype(jnp.float32))
    delta_all = delta_all.reshape(b, nq, chunk, kh, g).transpose(
        1, 0, 3, 4, 2)
    go = gout.reshape(b, nq, chunk, kh, g, dv).transpose(1, 0, 3, 4, 2, 5)
    qc_all = q.reshape(b, nq, chunk, kh, g, d).transpose(1, 0, 3, 4, 2, 5)
    ks = k.reshape(b, nkv, chunk, kh, d).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nkv, chunk, kh, dv).transpose(1, 0, 2, 3, 4)
    qp_all = qpos.reshape(nq, chunk)
    kp_all = kpos.reshape(nkv, chunk)

    def q_body(carry, inp):
        dk_acc, dv_acc, kj0 = carry  # (nkv, B, ckv, KH, d/dv) fp32
        qc, qp, m_q, l_q, go_q, delta_q = inp
        linv = 1.0 / jnp.maximum(l_q, 1e-30)

        def kv_body(c, inp2):
            kj, dq_c, dk_acc, dv_acc = c
            kc, vc, kp = inp2

            def compute(c):
                dq_c, dk_acc, dv_acc = c
                s = jnp.einsum("bkgqd,bskd->bkgqs", qc, kc,
                               preferred_element_type=jnp.float32)
                mask = _block_mask(qp, kp, window)
                s = jnp.where(mask[None, None, None], s, _NEG_INF)
                p = jnp.exp(s - m_q[..., None]) * linv[..., None]
                dv_blk = jnp.einsum("bkgqs,bkgqd->bskd",
                                    p.astype(go_q.dtype), go_q,
                                    preferred_element_type=jnp.float32)
                dp = jnp.einsum("bkgqd,bskd->bkgqs", go_q, vc,
                                preferred_element_type=jnp.float32)
                ds = p * (dp - delta_q[..., None])
                dq_blk = jnp.einsum("bkgqs,bskd->bkgqd",
                                    ds.astype(kc.dtype), kc,
                                    preferred_element_type=jnp.float32)
                dk_blk = jnp.einsum("bkgqs,bkgqd->bskd",
                                    ds.astype(qc.dtype), qc,
                                    preferred_element_type=jnp.float32)
                return (dq_c + dq_blk,
                        dk_acc.at[kj].add(dk_blk),
                        dv_acc.at[kj].add(dv_blk))

            visible = kp.min() <= qp.max()
            if window is not None:
                visible &= kp.max() > qp.min() - window
            dq_c, dk_acc, dv_acc = jax.lax.cond(
                visible, compute, lambda c: c, (dq_c, dk_acc, dv_acc))
            return (kj + 1, dq_c, dk_acc, dv_acc), None

        dq0 = jnp.zeros((b, kh, g, chunk, d), jnp.float32)
        (_, dq_c, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_body, (jnp.zeros((), jnp.int32), dq0, dk_acc, dv_acc),
            (ks, vs, kp_all))
        return (dk_acc, dv_acc, kj0), dq_c

    dk0 = jnp.zeros((nkv, b, chunk, kh, d), jnp.float32)
    dv0 = jnp.zeros((nkv, b, chunk, kh, dv), jnp.float32)
    (dk_acc, dv_acc, _), dqs = jax.lax.scan(
        q_body, (dk0, dv0, 0), (qc_all, qp_all, ms, ls, go, delta_all))

    dq = dqs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, d).astype(q.dtype)
    dk = dk_acc.transpose(1, 0, 2, 3, 4).reshape(b, skv, kh, d).astype(
        k.dtype)
    dvv = dv_acc.transpose(1, 0, 2, 3, 4).reshape(b, skv, kh, dv).astype(
        v.dtype)
    return dq, dk, dvv, jnp.zeros_like(qpos), jnp.zeros_like(kpos)


flash_reference.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


# ---------------------------------------------------------------------------
# single-token decode references
# ---------------------------------------------------------------------------

def decode_attention_ref(qf, k_cache, v_cache, kpos, qpos, *, window=None):
    """Single-token attention against a (ring-buffer) KV cache.

    qf: (B, KH, G, D) pre-scaled grouped query; caches: (B, L, KH, D/Dv);
    kpos: (B, L) absolute position of each cache slot (-1 for empty);
    qpos: (B,).  Returns (B, KH, G, Dv) fp32.
    """
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache,
                   preferred_element_type=jnp.float32)
    valid = kpos >= 0
    valid &= kpos <= qpos[:, None]
    if window is not None:
        valid &= qpos[:, None] - kpos < window
    s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                      preferred_element_type=jnp.float32)


def decode_attention_q8_ref(qf, k_codes, v_codes, k_scale, v_scale, kpos,
                            qpos, *, window=None):
    """Int8-cache decode; scales fold into the dots: s = (q . codes) *
    k_scale;  out = (p * v_scale) . codes.  qf as in decode_attention_ref;
    returns (B, KH, G, D) fp32."""
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k_codes.astype(qf.dtype),
                   preferred_element_type=jnp.float32)
    s = s * k_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
    valid = (kpos >= 0) & (kpos <= qpos[:, None])
    if window is not None:
        valid &= qpos[:, None] - kpos < window
    s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    pv = p * v_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
    return jnp.einsum("bkgs,bskd->bkgd", pv.astype(qf.dtype),
                      v_codes.astype(qf.dtype),
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# paged decode references (serving engine: KV pool + per-request page table)
# ---------------------------------------------------------------------------

def gather_pages(pool, page_table):
    """(P, pg, ...) pool + (S, npp) page table -> (S, npp * pg, ...) view.

    Negative (unallocated) page-table entries are clamped to physical page
    0 — the reserved null page — and the *caller* masks them out via the
    gathered positions (``paged_kpos`` returns -1 for those slots), so the
    clamped rows never contribute to attention.
    """
    pt = jnp.maximum(page_table, 0)
    g = pool[pt]  # (S, npp, pg, ...)
    s, npp, pg = g.shape[:3]
    return g.reshape((s, npp * pg) + g.shape[3:])


def paged_kpos(pos_pool, page_table):
    """Gathered (S, L) key positions with unallocated pages forced to -1
    (empty), regardless of what the clamped null page holds."""
    kpos = gather_pages(pos_pool, page_table)
    pg = pos_pool.shape[1]
    alloc = jnp.repeat(page_table >= 0, pg, axis=1)
    return jnp.where(alloc, kpos, -1)


def _zero_fully_masked(out, kpos, qpos, window):
    """Inactive slots (qpos = -1, or nothing visible) return 0, matching
    the Pallas kernels' empty online-softmax state — plain softmax would
    instead emit a uniform average of garbage rows."""
    valid = (kpos >= 0) & (kpos <= qpos[:, None])
    if window is not None:
        valid &= qpos[:, None] - kpos < window
    any_valid = jnp.any(valid, axis=-1)  # (S,)
    return jnp.where(any_valid[:, None, None, None], out, 0.0)


def decode_attention_paged_ref(qf, k_pool, v_pool, pos_pool, page_table,
                               qpos, *, window=None):
    """Single-token attention against a paged KV pool.

    qf: (S, KH, G, D) pre-scaled grouped query; pools: (P, pg, KH, D/Dv)
    with pos_pool (P, pg) absolute positions (-1 empty); page_table:
    (S, npp) physical page per logical page (-1 unallocated); qpos: (S,)
    (-1 for inactive slots, which return 0).  Returns (S, KH, G, Dv) fp32
    — bit-identical to ``decode_attention_ref`` on the gathered contiguous
    cache for every slot with at least one visible key.
    """
    k = gather_pages(k_pool, page_table)
    v = gather_pages(v_pool, page_table)
    kpos = paged_kpos(pos_pool, page_table)
    out = decode_attention_ref(qf, k, v, kpos, qpos, window=window)
    return _zero_fully_masked(out, kpos, qpos, window)


def decode_attention_paged_q8_ref(qf, k_pool, v_pool, k_scale_pool,
                                  v_scale_pool, pos_pool, page_table,
                                  qpos, *, window=None):
    """Paged int8-pool decode.  Pools: codes (P, pg, KH, D) int8, scales
    (P, pg, KH) fp16; otherwise as ``decode_attention_paged_ref``."""
    k = gather_pages(k_pool, page_table)
    v = gather_pages(v_pool, page_table)
    ks = gather_pages(k_scale_pool, page_table)
    vs = gather_pages(v_scale_pool, page_table)
    kpos = paged_kpos(pos_pool, page_table)
    out = decode_attention_q8_ref(qf, k, v, ks, vs, kpos, qpos,
                                  window=window)
    return _zero_fully_masked(out, kpos, qpos, window)


