"""Pure-jnp oracles for the Pallas compressor kernels.

Independent re-implementations of the kernel math (they deliberately do
not share code with the kernels); every kernel test asserts allclose /
exact-match against these.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.packing import storage_bits

_EPS = 1e-6


# ---------------------------------------------------------------------------
# kernel slot packing
# ---------------------------------------------------------------------------
#
# The fused kernels pack one code per power-of-two sub-byte slot
# (``storage_bits``) — NOT the exact cross-byte bitstream the wire
# payloads use (``core.packing.pack_bits``).  These oracles mirror the
# kernel layout; the codec dispatch converts to the exact bitstream at
# the payload boundary for non-power-of-two widths.

def _pack_slots(codes2d: jnp.ndarray, bits: int) -> jnp.ndarray:
    """(R, C) codes -> (R, C / per) uint8 words, per-slot shift-or."""
    sb = storage_bits(bits)
    per = 8 // sb
    r, c = codes2d.shape
    grouped = codes2d.astype(jnp.uint8).reshape(r, c // per, per)
    shifts = (jnp.arange(per, dtype=jnp.uint8) * sb)[None, None, :]
    return (grouped << shifts).sum(axis=-1).astype(jnp.uint8)


def _unpack_slots(words: jnp.ndarray, bits: int, c: int) -> jnp.ndarray:
    """Inverse of :func:`_pack_slots`: (R, C / per) words -> (R, C)."""
    sb = storage_bits(bits)
    per = 8 // sb
    shifts = (jnp.arange(per, dtype=jnp.uint8) * sb)[None, None, :]
    mask = jnp.uint8((1 << sb) - 1)
    return ((words[..., None] >> shifts) & mask).reshape(words.shape[0], c)


# ---------------------------------------------------------------------------
# RD-FSQ (clip -> linear scale -> symmetric round -> pack)
# ---------------------------------------------------------------------------

def rdfsq_stats(x2d: jnp.ndarray, clip_sigma: float = 3.0):
    """Per-row (lo, hi) after the mu +- k*sigma clip.  x2d: (R, C)."""
    xf = x2d.astype(jnp.float32)
    mu = xf.mean(axis=1, keepdims=True)
    sd = xf.std(axis=1, keepdims=True)
    xc = jnp.clip(xf, mu - clip_sigma * sd, mu + clip_sigma * sd)
    return xc.min(axis=1, keepdims=True), xc.max(axis=1, keepdims=True)


def rdfsq_codes_ref(x2d: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray,
                    bits: int) -> jnp.ndarray:
    """(R, C) codes in {0..2^bits - 1} (uint8, pre-packing)."""
    d = 2 ** bits
    half = (d - 1) / 2.0
    xf = jnp.clip(x2d.astype(jnp.float32), lo, hi)
    e = 2.0 * (xf - lo) / (hi - lo + _EPS) - 1.0
    if d % 2 == 1:
        z = jnp.round(half * e)
    else:
        z = jnp.round(half * e - 0.5) + 0.5
    z = jnp.clip(z, -half, half)
    return (z + half).astype(jnp.uint8)


def rdfsq_quantize_ref(x2d, lo, hi, bits: int) -> jnp.ndarray:
    """Packed uint8 words in kernel slot layout: (R, C / per)."""
    codes = rdfsq_codes_ref(x2d, lo, hi, bits)
    return _pack_slots(codes, bits)


def rdfsq_dequantize_ref(packed: jnp.ndarray, lo, hi, bits: int,
                         n_cols: int) -> jnp.ndarray:
    d = 2 ** bits
    half = (d - 1) / 2.0
    codes = _unpack_slots(packed, bits, n_cols)
    cvals = (codes.astype(jnp.float32) - half) / half
    return (cvals + 1.0) / 2.0 * (hi - lo) + lo


# ---------------------------------------------------------------------------
# weight-only packed dequant-matmul (repro.wq)
# ---------------------------------------------------------------------------
#
# The packed weight store lays the exact core.packing bitstream down the
# input axis PER OUTPUT COLUMN: 8 consecutive codes of a column span
# exactly ``bits`` whole bytes.  The oracle mirrors that layout with its
# own uint32-word arithmetic (independent of both core.packing and the
# Pallas kernel).

def wq_unpack_ref(words: jnp.ndarray, bits: int, d_in: int) -> jnp.ndarray:
    """(packed_rows, C) uint8 column bitstreams -> (d_in, C) uint8 codes."""
    nb = (d_in + 7) // 8  # 8-code groups per column
    c = words.shape[1]
    pad = nb * bits - words.shape[0]
    w = jnp.pad(words, ((0, max(pad, 0)), (0, 0))).astype(jnp.uint32)
    w = w.reshape(nb, bits, c)
    byte_shifts = (jnp.arange(bits, dtype=jnp.uint32) * 8)[None, :, None]
    word32 = (w << byte_shifts).sum(axis=1)  # (nb, C): 8 codes each
    code_shifts = (jnp.arange(8, dtype=jnp.uint32) * bits)[None, :, None]
    mask = jnp.uint32(2 ** bits - 1)
    codes = (word32[:, None, :] >> code_shifts) & mask
    return codes.reshape(nb * 8, c)[:d_in].astype(jnp.uint8)


def wq_dequant_ref(words: jnp.ndarray, scales: jnp.ndarray,
                   mins: jnp.ndarray, *, bits: int, group: int,
                   d_in: int) -> jnp.ndarray:
    """fp32 (d_in, C) weights in STORAGE channel order."""
    codes = wq_unpack_ref(words, bits, d_in).astype(jnp.float32)
    n_groups, c = scales.shape
    pad = n_groups * group - d_in
    cf = jnp.pad(codes, ((0, pad), (0, 0))).reshape(n_groups, group, c)
    w = cf * scales.astype(jnp.float32)[:, None, :] \
        + mins.astype(jnp.float32)[:, None, :]
    return w.reshape(n_groups * group, c)[:d_in]


def wq_matmul_ref(x2d: jnp.ndarray, words: jnp.ndarray, scales: jnp.ndarray,
                  mins: jnp.ndarray, *, bits: int, group: int,
                  d_in: int) -> jnp.ndarray:
    """(M, d_in) @ dequant(words) -> (M, C) fp32 (fp32 accumulation).

    The contraction happens in the activation dtype (bf16 activations
    stay bf16 operands, like the dense ``x @ w.astype(x.dtype)`` path)
    with an fp32 accumulator — the same convention as the Pallas kernel.
    """
    w = wq_dequant_ref(words, scales, mins, bits=bits, group=group,
                       d_in=d_in).astype(x2d.dtype)
    return jnp.matmul(x2d, w, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# NF-b blockwise quantization
# ---------------------------------------------------------------------------

def nf_codes_ref(blocks: jnp.ndarray, book: jnp.ndarray):
    """blocks: (NB, G).  Returns (codes uint8, m (NB,1), rng (NB,1))."""
    xf = blocks.astype(jnp.float32)
    m = xf.min(axis=1, keepdims=True)
    mx = xf.max(axis=1, keepdims=True)
    rng = mx - m
    norm = 2.0 * (xf - m) / (rng + 1e-8) - 1.0
    dist = jnp.abs(norm[..., None] - book.astype(jnp.float32))
    codes = jnp.argmin(dist, axis=-1).astype(jnp.uint8)
    return codes, m, rng


def nf_quantize_ref(blocks, book, bits: int):
    codes, m, rng = nf_codes_ref(blocks, book)
    return _pack_slots(codes, bits), m, rng


def nf_dequantize_ref(packed, m, rng, book, bits: int,
                      g: int) -> jnp.ndarray:
    codes = _unpack_slots(packed, bits, g)
    norm = book.astype(jnp.float32)[codes]
    return (norm + 1.0) / 2.0 * rng + m
