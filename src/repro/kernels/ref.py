"""Pure-jnp oracles for the Pallas compressor kernels.

Independent re-implementations of the kernel math (they deliberately do
not share code with the kernels); every kernel test asserts allclose /
exact-match against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import pack_bits, storage_bits, unpack_bits

_EPS = 1e-6


# ---------------------------------------------------------------------------
# RD-FSQ (clip -> linear scale -> symmetric round -> pack)
# ---------------------------------------------------------------------------

def rdfsq_stats(x2d: jnp.ndarray, clip_sigma: float = 3.0):
    """Per-row (lo, hi) after the mu +- k*sigma clip.  x2d: (R, C)."""
    xf = x2d.astype(jnp.float32)
    mu = xf.mean(axis=1, keepdims=True)
    sd = xf.std(axis=1, keepdims=True)
    xc = jnp.clip(xf, mu - clip_sigma * sd, mu + clip_sigma * sd)
    return xc.min(axis=1, keepdims=True), xc.max(axis=1, keepdims=True)


def rdfsq_codes_ref(x2d: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray,
                    bits: int) -> jnp.ndarray:
    """(R, C) codes in {0..2^bits - 1} (uint8, pre-packing)."""
    d = 2 ** bits
    half = (d - 1) / 2.0
    xf = jnp.clip(x2d.astype(jnp.float32), lo, hi)
    e = 2.0 * (xf - lo) / (hi - lo + _EPS) - 1.0
    if d % 2 == 1:
        z = jnp.round(half * e)
    else:
        z = jnp.round(half * e - 0.5) + 0.5
    z = jnp.clip(z, -half, half)
    return (z + half).astype(jnp.uint8)


def rdfsq_quantize_ref(x2d, lo, hi, bits: int) -> jnp.ndarray:
    """Packed uint8 words, row-major packing per row: (R, C*b/8)."""
    codes = rdfsq_codes_ref(x2d, lo, hi, bits)
    r, c = codes.shape
    per = 8 // storage_bits(bits)
    return jax.vmap(lambda row: pack_bits(row, bits))(codes).reshape(
        r, c // per)


def rdfsq_dequantize_ref(packed: jnp.ndarray, lo, hi, bits: int,
                         n_cols: int) -> jnp.ndarray:
    d = 2 ** bits
    half = (d - 1) / 2.0
    r = packed.shape[0]
    codes = jax.vmap(lambda row: unpack_bits(row, bits, n_cols))(packed)
    cvals = (codes.astype(jnp.float32) - half) / half
    return (cvals + 1.0) / 2.0 * (hi - lo) + lo


# ---------------------------------------------------------------------------
# NF-b blockwise quantization
# ---------------------------------------------------------------------------

def nf_codes_ref(blocks: jnp.ndarray, book: jnp.ndarray):
    """blocks: (NB, G).  Returns (codes uint8, m (NB,1), rng (NB,1))."""
    xf = blocks.astype(jnp.float32)
    m = xf.min(axis=1, keepdims=True)
    mx = xf.max(axis=1, keepdims=True)
    rng = mx - m
    norm = 2.0 * (xf - m) / (rng + 1e-8) - 1.0
    dist = jnp.abs(norm[..., None] - book.astype(jnp.float32))
    codes = jnp.argmin(dist, axis=-1).astype(jnp.uint8)
    return codes, m, rng


def nf_quantize_ref(blocks, book, bits: int):
    codes, m, rng = nf_codes_ref(blocks, book)
    nb, g = codes.shape
    per = 8 // storage_bits(bits)
    packed = jax.vmap(lambda row: pack_bits(row, bits))(codes).reshape(
        nb, g // per)
    return packed, m, rng


def nf_dequantize_ref(packed, m, rng, book, bits: int,
                      g: int) -> jnp.ndarray:
    codes = jax.vmap(lambda row: unpack_bits(row, bits, g))(packed)
    norm = book.astype(jnp.float32)[codes]
    return (norm + 1.0) / 2.0 * rng + m
