"""rwkv6-7b [ssm] — Finch, data-dependent decay, attention-free
[arXiv:2404.05892].

The split-quantization technique applies to the cut hidden states exactly
as for attention archs (DESIGN.md SS4); decode is O(1)-state so long_500k
runs natively.
"""
from repro.configs.base import ArchConfig, default_split

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,          # d_model / rwkv_head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    attn_type="none",
    rwkv_head_dim=64,
    split=default_split(cut_layer=16),
    source="arXiv:2404.05892 (RWKV6 Finch 7B)",
)
