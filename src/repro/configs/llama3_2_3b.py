"""llama3.2-3b [dense] — small llama3 [hf:meta-llama/Llama-3.2-1B family]."""
from repro.configs.base import ArchConfig, default_split

CONFIG = ArchConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500000.0,
    sliding_window=4096,  # engaged only for long_500k (see DESIGN.md)
    split=default_split(cut_layer=14),
    source="hf:meta-llama/Llama-3.2-1B (scaled to 3B per assignment)",
)
