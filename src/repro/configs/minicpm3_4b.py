"""minicpm3-4b [dense, MLA] [hf:openbmb/MiniCPM3-4B].

Multi-head Latent Attention with q_lora=768, kv_lora=256 (per the
MiniCPM3-4B model card); assignment's "GQA kv=40" corresponds to MLA's
full-head effective KV.
"""
from repro.configs.base import ArchConfig, default_split

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    rope_theta=10000.0,
    sliding_window=4096,
    attn_type="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    split=default_split(cut_layer=31),
    source="hf:openbmb/MiniCPM3-4B",
)
