"""ArchConfig — one declarative description per architecture.

Every assigned architecture is a pure-data instance of this dataclass; the
model builder (`repro.models.transformer`) interprets ``block_pattern()`` to
assemble the decoder stack.  ``reduced()`` produces the CPU smoke-test
variant mandated by the brief (2 layers, d_model <= 512, <= 4 experts).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.quantizers import QuantConfig
from repro.core.split import SplitConfig


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    # --- attention ---
    attn_type: str = "gqa"  # gqa | mla | none
    sliding_window: Optional[int] = None  # engaged for long_500k
    # --- MLA ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- MoE ---
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    dense_residual: bool = False
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    rwkv_head_dim: int = 64
    hybrid_attn_every: int = 0  # zamba2: shared attn block every N layers
    # --- multimodal (frontend is a stub; see DESIGN.md) ---
    modality: str = "text"  # text | vlm | audio
    n_image_tokens: int = 0
    d_vision: int = 0
    d_connector: int = 0  # hidden width of the 2-layer MLP connector
    n_codebooks: int = 0
    # --- split learning (the paper's technique) ---
    split: SplitConfig = dataclasses.field(default_factory=SplitConfig)
    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # 8 stores GQA KV caches as int8 codes + fp16 scales (beyond-paper;
    # halves the decode cache footprint and read traffic)
    kv_cache_bits: int = 16
    # >1 enables two-level (sqrt-L) checkpointing with this group size:
    # ~2 sqrt(L) stored layer inputs instead of L, at ~1 extra forward of
    # recompute + extra FSDP regathers (EXPERIMENTS.md SSPerf A8/C2).
    # 0 = auto: segments whose stored layer inputs exceed the byte budget
    # (REPRO_REMAT_BUDGET_BYTES) get k ~ sqrt(L) from
    # repro.models.stack.auto_group_size; small stacks stay single-level.
    remat_group: int = 0
    # --- provenance ---
    source: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """long_500k needs a sub-quadratic path (SSM state or window)."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None)

    def block_pattern(self) -> Tuple[str, ...]:
        """Per-layer block types."""
        if self.family == "ssm":
            return ("rwkv6",) * self.n_layers
        if self.family == "hybrid":
            pat = []
            for i in range(self.n_layers):
                if (self.hybrid_attn_every
                        and (i + 1) % self.hybrid_attn_every == 0):
                    pat.append("shared_attn")
                else:
                    pat.append("mamba2")
            return tuple(pat)
        if self.family == "moe" or self.n_experts > 0:
            pat = ["dense"] * self.first_dense_layers
            pat += ["moe"] * (self.n_layers - self.first_dense_layers)
            return tuple(pat)
        return ("dense",) * self.n_layers

    def segments(self) -> Tuple[Tuple[str, int], ...]:
        """Consecutive same-type runs, split at the compressor cut layer.

        Layers [0, cut) run on the split-learning client, [cut, L) on the
        server; segments never straddle the cut so parameters can be
        stacked and scanned per segment.
        """
        pattern = self.block_pattern()
        cut = self.split.resolve_cut(self.n_layers)
        segs = []
        run_type, run_len = None, 0
        for i, t in enumerate(pattern):
            boundary = i == cut
            if t != run_type or boundary:
                if run_len:
                    segs.append((run_type, run_len))
                run_type, run_len = t, 1
            else:
                run_len += 1
        if run_len:
            segs.append((run_type, run_len))
        return tuple(segs)

    def client_server_segments(self):
        cut = self.split.resolve_cut(self.n_layers)
        segs = self.segments()
        client, server, seen = [], [], 0
        for t, n in segs:
            (client if seen < cut else server).append((t, n))
            seen += n
        return tuple(client), tuple(server)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: 2 layers, d_model <= 512, <= 4 experts."""
        d = min(self.d_model, 256)
        heads = min(self.n_heads, 4) or 4
        kv = min(self.n_kv_heads, heads) or heads
        updates = dict(
            n_layers=2,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=d // heads,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
            split=dataclasses.replace(self.split, cut_layer=1),
        )
        if self.n_experts:
            updates.update(n_experts=min(self.n_experts, 4),
                           moe_top_k=min(self.moe_top_k, 2),
                           moe_d_ff=min(self.moe_d_ff or 256, 256),
                           n_shared_experts=min(self.n_shared_experts, 1),
                           first_dense_layers=min(self.first_dense_layers, 1))
        if self.attn_type == "mla":
            updates.update(q_lora_rank=min(self.q_lora_rank, 64),
                           kv_lora_rank=min(self.kv_lora_rank, 32),
                           qk_nope_dim=16, qk_rope_dim=16, v_head_dim=16,
                           head_dim=32)
        if self.family in ("ssm", "hybrid"):
            updates.update(ssm_state=min(self.ssm_state or 16, 16),
                           ssm_headdim=min(self.ssm_headdim, 32),
                           rwkv_head_dim=min(self.rwkv_head_dim, 32),
                           hybrid_attn_every=2 if self.hybrid_attn_every
                           else 0)
        if self.modality == "vlm":
            updates.update(n_image_tokens=min(self.n_image_tokens, 16),
                           d_vision=min(self.d_vision, 64),
                           d_connector=min(self.d_connector or d, 128))
        if self.modality == "audio":
            updates.update(n_codebooks=min(self.n_codebooks, 2))
        return dataclasses.replace(self, **updates)


def default_split(cut_layer: int = -1, method: str = "rdfsq",
                  bits: int = 2) -> SplitConfig:
    return SplitConfig(cut_layer=cut_layer,
                       quant=QuantConfig(method=method, bits=bits))
