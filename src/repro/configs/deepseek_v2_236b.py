"""deepseek-v2-236b [moe, MLA] [arXiv:2405.04434].

MLA kv_lora=512; 2 shared + 160 routed experts, top-6, expert d_ff=1536;
first layer dense (d_ff=12288 per model card).
"""
from repro.configs.base import ArchConfig, default_split

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,          # dense first layer / shared-path width basis
    vocab_size=102400,
    rope_theta=10000.0,
    sliding_window=4096,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=160,
    moe_top_k=6,
    moe_d_ff=1536,
    n_shared_experts=2,
    first_dense_layers=1,
    split=default_split(cut_layer=30),
    source="arXiv:2405.04434 (DeepSeek-V2)",
)
