"""musicgen-large [audio] — decoder-only over EnCodec tokens [arXiv:2306.05284].

EnCodec itself is a stub: input_specs supplies the 4-codebook token grid
(delay-pattern flattening is a data-layout question for the stubbed
frontend).  The decoder embeds the 4 codebooks additively and predicts all
4 per step (4 output heads).
"""
from repro.configs.base import ArchConfig, default_split

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    modality="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    rope_theta=10000.0,
    sliding_window=4096,
    n_codebooks=4,
    split=default_split(cut_layer=24),
    source="arXiv:2306.05284 (MusicGen-large)",
)
