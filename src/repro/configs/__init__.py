"""Architecture registry: 10 assigned architectures + the paper's own model.

Each module defines ``CONFIG``; ``get_config(name)`` returns it and
``ARCHS`` lists all ids.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ArchConfig

ARCHS = (
    "llama3_2_3b",
    "llava_next_34b",
    "musicgen_large",
    "deepseek_coder_33b",
    "zamba2_2_7b",
    "minicpm3_4b",
    "deepseek_v2_236b",
    "arctic_480b",
    "granite_3_8b",
    "rwkv6_7b",
    "tinyllava",
)

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update({
    "llama3.2-3b": "llama3_2_3b",
    "llava-next-34b": "llava_next_34b",
    "musicgen-large": "musicgen_large",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "zamba2-2.7b": "zamba2_2_7b",
    "minicpm3-4b": "minicpm3_4b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "arctic-480b": "arctic_480b",
    "granite-3-8b": "granite_3_8b",
    "rwkv6-7b": "rwkv6_7b",
})


def get_config(name: str) -> ArchConfig:
    key = _ALIASES.get(name, name)
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCHS}
