"""llava-next-34b [vlm] — anyres tiling backbone.

The SigLIP/CLIP vision tower is a stub (input_specs supplies patch
embeddings at d_vision); the 2-layer GELU connector + decoder backbone are
fully implemented.  The paper's split cut sits right after the connector —
cut_layer=0 puts the compressor between the connector and the first decoder
layer, which is exactly the Quantized-TinyLLaVA deployment.
2880 image tokens model anyres 4-tile + base encoding (5 x 576).
"""
from repro.configs.base import ArchConfig, default_split

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    modality="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5000000.0,
    sliding_window=4096,
    n_image_tokens=2880,
    d_vision=1152,
    d_connector=7168,
    split=default_split(cut_layer=0),  # paper-faithful: cut after connector
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (34B-scale backbone)",
)
