"""The paper's own model: Quantized-TinyLLaVA.

SigLIP-SO400M vision tower is a stub producing 729 patch embeddings at
d_vision=1152; the 2-layer GELU connector and an OpenELM-270M-class decoder
(16L, d=1280) are fully implemented.  Cut after the connector with a 2-bit
RD-FSQ compressor — the paper's headline configuration.
"""
from repro.configs.base import ArchConfig, default_split

CONFIG = ArchConfig(
    name="tinyllava",
    family="vlm",
    modality="vlm",
    n_layers=16,
    d_model=1280,
    n_heads=20,
    n_kv_heads=5,
    head_dim=64,
    d_ff=3456,
    vocab_size=32000,
    rope_theta=10000.0,
    sliding_window=4096,
    n_image_tokens=729,
    d_vision=1152,
    d_connector=1280,
    split=default_split(cut_layer=0, method="rdfsq", bits=2),
    source="paper SS4.1: SigLIP-SO400M (stub) + OpenELM-270M-class LM",
)
