"""granite-3-8b [dense] — GQA [hf:ibm-granite/granite-3.0-2b-base family]."""
from repro.configs.base import ArchConfig, default_split

CONFIG = ArchConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    rope_theta=10000.0,
    sliding_window=4096,
    split=default_split(cut_layer=20),
    source="hf:ibm-granite/granite-3.0-2b-base (8B per assignment)",
)
