"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base]."""
from repro.configs.base import ArchConfig, default_split

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,           # dense-residual MLP width
    vocab_size=32000,
    rope_theta=10000.0,
    sliding_window=4096,
    n_experts=128,
    moe_top_k=2,
    moe_d_ff=4864,
    dense_residual=True,
    split=default_split(cut_layer=17),
    source="hf:Snowflake/snowflake-arctic-base",
)
