"""zamba2-2.7b [hybrid] — Mamba2 backbone + parameter-shared attention
blocks every 6 layers [arXiv:2411.15242].

The shared block consumes concat(hidden, initial embedding) through a
2d->d input projection (simplification of Zamba2's concatenation scheme;
see DESIGN.md).  ssm_state=64 per assignment.
"""
from repro.configs.base import ArchConfig, default_split

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    rope_theta=10000.0,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    hybrid_attn_every=6,
    split=default_split(cut_layer=27),
    source="arXiv:2411.15242 (Zamba2-2.7B)",
)
