"""deepseek-coder-33b [dense] — llama-arch [arXiv:2401.14196]."""
from repro.configs.base import ArchConfig, default_split

CONFIG = ArchConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    rope_theta=100000.0,
    sliding_window=4096,
    split=default_split(cut_layer=31),
    source="arXiv:2401.14196 (DeepSeek-Coder 33B)",
)
