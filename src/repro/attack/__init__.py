from repro.attack.inversion import (attack_forward, init_attack_params,
                                    reconstruction_loss, train_attack)

__all__ = ["attack_forward", "init_attack_params", "reconstruction_loss",
           "train_attack"]
