"""Feature-inversion attack (paper Section 5).

A fully-convolutional spatial decoder reconstructs the input image from
the intermediate features an attacker observes on the split-learning wire.
Architecture mirrors the paper at reduced scale: features reshaped onto
their patch grid, then upsampling blocks (bilinear resize + 3x3 conv)
until the image resolution is reached.

Losses: L1 + 0.5 * MSE + 2.0 * gradient-matching perceptual proxy
(no pretrained VGG/LPIPS offline; DESIGN.md SS3 assumption #4 — the
reproduced claim is the *ordering* of reconstruction losses across
compression methods, Figure 4).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.optim import AdamWConfig, adamw_update, init_opt_state


def conv2d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def upsample2x(x: jnp.ndarray) -> jnp.ndarray:
    b, h, w, c = x.shape
    return jax.image.resize(x, (b, 2 * h, 2 * w, c), "bilinear")


def init_attack_params(key, d_feature: int, widths=(64, 32, 16),
                       out_channels: int = 1) -> Dict:
    ks = jax.random.split(key, len(widths) + 1)
    params: Dict[str, jnp.ndarray] = {}
    c_in = d_feature
    for i, c_out in enumerate(widths):
        params[f"w{i}"] = jax.random.normal(
            ks[i], (3, 3, c_in, c_out)) * (9 * c_in) ** -0.5
        params[f"b{i}"] = jnp.zeros((c_out,))
        c_in = c_out
    params["w_out"] = jax.random.normal(
        ks[-1], (3, 3, c_in, out_channels)) * (9 * c_in) ** -0.5
    params["b_out"] = jnp.zeros((out_channels,))
    return params


def attack_forward(params: Dict, feats: jnp.ndarray,
                   grid: Tuple[int, int]) -> jnp.ndarray:
    """feats: (B, N, D) patch features -> reconstructed image (B, H, W, C).

    Each upsampling block doubles resolution: grid (4,4) + 3 blocks -> 32x32.
    """
    b, n, d = feats.shape
    gh, gw = grid
    x = feats.reshape(b, gh, gw, d)
    i = 0
    while f"w{i}" in params:
        x = upsample2x(x)
        x = jax.nn.relu(conv2d(x, params[f"w{i}"], params[f"b{i}"]))
        i += 1
    return jnp.tanh(conv2d(x, params["w_out"], params["b_out"]))


def _image_grads(img: jnp.ndarray):
    gx = img[:, 1:, :, :] - img[:, :-1, :, :]
    gy = img[:, :, 1:, :] - img[:, :, :-1, :]
    return gx, gy


def reconstruction_loss(pred: jnp.ndarray, target: jnp.ndarray
                        ) -> jnp.ndarray:
    """1.0 * L1 + 0.5 * MSE + 2.0 * gradient-perceptual proxy."""
    l1 = jnp.mean(jnp.abs(pred - target))
    mse = jnp.mean((pred - target) ** 2)
    pgx, pgy = _image_grads(pred)
    tgx, tgy = _image_grads(target)
    perc = jnp.mean(jnp.abs(pgx - tgx)) + jnp.mean(jnp.abs(pgy - tgy))
    return 1.0 * l1 + 0.5 * mse + 2.0 * perc


def train_attack(key, feats_train, imgs_train, feats_val, imgs_val, *,
                 grid: Tuple[int, int], n_steps: int = 200,
                 batch: int = 16, lr: float = 1e-3
                 ) -> Tuple[Dict, List[float]]:
    """Train the inversion model; returns (params, val-loss history)."""
    d = feats_train.shape[-1]
    params = init_attack_params(key, d)
    opt_cfg = AdamWConfig(lr=lr, weight_decay=1e-5, clip_norm=10.0)
    opt = init_opt_state(params, opt_cfg)

    @jax.jit
    def step(params, opt, feats, imgs):
        def loss_fn(p):
            pred = attack_forward(p, feats, grid)
            return reconstruction_loss(pred, imgs)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, loss

    @jax.jit
    def val_loss(params):
        pred = attack_forward(params, feats_val, grid)
        return reconstruction_loss(pred, imgs_val)

    n = feats_train.shape[0]
    history = []
    for i in range(n_steps):
        key, sub = jax.random.split(key)
        idx = jax.random.randint(sub, (batch,), 0, n)
        params, opt, _ = step(params, opt, feats_train[idx], imgs_train[idx])
        if i % 25 == 0 or i == n_steps - 1:
            history.append(float(val_loss(params)))
    return params, history
