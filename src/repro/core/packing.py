"""Exact b-bit integer packing into uint8 words, for every b in [1, 8].

The paper transmits quantized indices ``I`` in {0, ..., 2^b - 1}.  On the wire
(TPU ICI in our adaptation, TCP in the paper's) those must be *packed*: a
2-bit code stored in an int8 wastes 6 bits and would forfeit 3/4 of the
promised communication saving.  This module implements exact, invertible
*bitstream* packing for every width b in [1, 8]: code ``i`` occupies bits
``[i*b, (i+1)*b)`` of the stream (LSB-first within each byte), so ``n``
codes cost exactly ``ceil(n*b / 8)`` bytes — a 3-bit payload is 3/16 of
bf16 on the wire, not the 4/16 the old slot-padded packers paid (odd
widths used to ride the next power-of-two slot; that overhead is gone,
which is what makes fine-grained per-group bit allocation worth its
bytes).  For b in {1, 2, 4, 8} the layout is bit-identical to the old
slot packing, so power-of-two payloads (including the Pallas kernels',
which still pack per row at those widths) are unchanged.
"""
from __future__ import annotations

import jax.numpy as jnp

#: Widths the *fused Pallas kernels* pack natively (one code per
#: power-of-two slot inside a byte).  The jnp bitstream packers below
#: support every width in [1, 8] exactly; odd widths fall back to them.
KERNEL_SLOT_BITS = (1, 2, 4, 8)

# Backward-compatible alias: everything in [1, 8] is now supported.
SUPPORTED_BITS = (1, 2, 3, 4, 5, 6, 7, 8)

# Codes per packing group: groups of 8 codes span exactly ``bits`` whole
# bytes, so the cross-byte bit arithmetic reduces to two reshapes.
_GROUP = 8


def _check_bits(bits: int) -> None:
    if bits <= 0 or bits > 8:
        raise ValueError(f"bits must be in [1, 8], got {bits}")


def storage_bits(bits: int) -> int:
    """Physical bits per code in a *Pallas kernel slot* (next power of 2).

    The bitstream packers in this module cost exactly ``bits`` physical
    bits per code; this helper survives for the fused kernels, which pack
    one code per power-of-two sub-byte slot (``kernels/ops.py``) — the
    codec dispatch routes non-power-of-two widths to the jnp bitstream
    path instead.
    """
    _check_bits(bits)
    for b in KERNEL_SLOT_BITS:
        if bits <= b:
            return b
    raise AssertionError


def packed_size(n: int, bits: int) -> int:
    """Number of uint8 words needed for ``n`` codes of width ``bits``.

    Exact: ``ceil(n * bits / 8)`` — no slot padding at any width.
    """
    _check_bits(bits)
    return (n * bits + 7) // 8


def pack_bits(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack a flat uint8 code array (values < 2**bits) into uint8 words.

    Returns a 1-D uint8 array of length ``packed_size(codes.size, bits)``;
    code ``i`` occupies stream bits ``[i*bits, (i+1)*bits)``, LSB-first.
    """
    _check_bits(bits)
    flat = codes.reshape(-1).astype(jnp.uint8)
    n = flat.shape[0]
    pad = (-n) % _GROUP
    if pad:
        flat = jnp.pad(flat, (0, pad))
    # (G, 8) codes -> (G, 8, bits) bits -> (G, bits, 8) byte lanes -> bytes
    grouped = flat.reshape(-1, _GROUP)
    code_shifts = jnp.arange(bits, dtype=jnp.uint8)
    bit_lanes = (grouped[:, :, None] >> code_shifts) & jnp.uint8(1)
    bit_lanes = bit_lanes.reshape(-1, bits, 8)
    byte_shifts = jnp.arange(8, dtype=jnp.uint8)
    words = (bit_lanes << byte_shifts).sum(axis=-1).astype(jnp.uint8)
    # zero-padded codes only ever populate the tail bytes past the exact
    # bitstream length, so slicing to packed_size loses nothing
    return words.reshape(-1)[: packed_size(n, bits)]


def unpack_bits(words: jnp.ndarray, bits: int, n: int) -> jnp.ndarray:
    """Inverse of :func:`pack_bits`; returns the first ``n`` codes (uint8).

    ``words`` must hold exactly the bitstream :func:`pack_bits` emitted for
    ``n`` codes: at least ``packed_size(n, bits)`` bytes (anything shorter
    would silently decode the missing tail as zeros — a corruption, not a
    ragged shape) and at most the 8-code-group-rounded length (anything
    longer means ``n``/``bits`` disagree with the producer).  Ragged
    ``n % 8 != 0`` tails are exact: the final byte's unused high bits are
    the producer's zero padding.
    """
    _check_bits(bits)
    flat = words.reshape(-1)
    n_groups = (n + _GROUP - 1) // _GROUP
    need = packed_size(n, bits)
    if flat.shape[0] < need:
        raise ValueError(
            f"unpack_bits: word stream has {flat.shape[0]} bytes but "
            f"{n} codes at {bits} bits need packed_size = {need}; "
            f"refusing to zero-fill the missing tail")
    if flat.shape[0] > n_groups * bits:
        raise ValueError(
            f"unpack_bits: word stream has {flat.shape[0]} bytes but "
            f"{n} codes at {bits} bits occupy at most "
            f"{n_groups * bits} (group-rounded) — n/bits disagree with "
            f"the producer")
    pad = n_groups * bits - flat.shape[0]
    if pad > 0:
        flat = jnp.pad(flat, (0, pad))
    byte_shifts = jnp.arange(8, dtype=jnp.uint8)
    bit_lanes = (flat.reshape(-1, bits)[:, :, None] >> byte_shifts) \
        & jnp.uint8(1)
    bit_lanes = bit_lanes.reshape(-1, 8, bits)
    code_shifts = jnp.arange(bits, dtype=jnp.uint8)
    codes = (bit_lanes << code_shifts).sum(axis=-1).astype(jnp.uint8)
    return codes.reshape(-1)[:n]
