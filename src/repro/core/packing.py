"""Exact b-bit integer packing into uint8 words.

The paper transmits quantized indices ``I`` in {0, ..., 2^b - 1}.  On the wire
(TPU ICI in our adaptation, TCP in the paper's) those must be *packed*: a 2-bit
code stored in an int8 wastes 6 bits and would forfeit 3/4 of the promised
communication saving.  This module implements exact, invertible packing for
b in {1, 2, 4, 8}; 3-bit codes are transported in 4-bit slots (documented
4/3 overhead, still 4x better than fp16).
"""
from __future__ import annotations

import jax.numpy as jnp

SUPPORTED_BITS = (1, 2, 4, 8)


def storage_bits(bits: int) -> int:
    """Physical bits per code on the wire (3-bit rides in a 4-bit slot)."""
    if bits <= 0 or bits > 8:
        raise ValueError(f"bits must be in [1, 8], got {bits}")
    for b in SUPPORTED_BITS:
        if bits <= b:
            return b
    raise AssertionError


def packed_size(n: int, bits: int) -> int:
    """Number of uint8 words needed for ``n`` codes of width ``bits``."""
    b = storage_bits(bits)
    per_word = 8 // b
    return (n + per_word - 1) // per_word


def pack_bits(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack a flat uint8 code array (values < 2**bits) into uint8 words.

    Returns a 1-D uint8 array of length ``packed_size(codes.size, bits)``.
    """
    b = storage_bits(bits)
    per_word = 8 // b
    flat = codes.reshape(-1).astype(jnp.uint8)
    n = flat.shape[0]
    pad = (-n) % per_word
    if pad:
        flat = jnp.pad(flat, (0, pad))
    grouped = flat.reshape(-1, per_word)
    shifts = jnp.arange(per_word, dtype=jnp.uint8) * b
    words = (grouped << shifts).sum(axis=-1).astype(jnp.uint8)
    return words


def unpack_bits(words: jnp.ndarray, bits: int, n: int) -> jnp.ndarray:
    """Inverse of :func:`pack_bits`; returns the first ``n`` codes (uint8)."""
    b = storage_bits(bits)
    per_word = 8 // b
    shifts = jnp.arange(per_word, dtype=jnp.uint8) * b
    mask = jnp.uint8((1 << b) - 1)
    codes = (words[:, None] >> shifts) & mask
    return codes.reshape(-1)[:n]
