"""Split-learning boundary: compressor module + cross-partition transport.

Figure 2 of the paper: the compressor is an (optional, learnable) linear
encoder on the client, a quantizer, the wire, a dequantizer, and an
(optional, learnable) linear decoder on the server.  Two execution modes:

* ``compressor_roundtrip`` — in-graph quantize->dequantize with STE, used for
  end-to-end training (paper Table 3) and for the 40-combo dry-runs, where
  client and server halves are co-located SPMD programs.
* ``quantized_ship`` — the real wire: encode to the bit-packed payload,
  ``jax.lax.ppermute`` every payload array across the ``pod`` mesh axis,
  decode on the receiving pod.  A ``custom_vjp`` ships the (uncompressed,
  per the paper's forward-only compression scope) cotangent back on the
  reverse permutation.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quantizers
from repro.core.payload import CommPayload
from repro.core.quantizers import QuantConfig


@dataclasses.dataclass(frozen=True)
class SplitConfig:
    """Where and how the model is cut.

    ``n_stages`` / ``stage_quants`` describe the *pipeline* topology used
    by ``launch/split_pipeline.py`` (BEYOND-PAPER: the paper's deployment
    is the 2-partition client/server special case).  ``n_stages`` equal
    partitions give ``n_stages - 1`` quantized cuts; ``stage_quants``
    optionally overrides the compressor per cut (empty = ``quant``
    everywhere).  The in-graph single-cut path (``cut_layer`` +
    ``compressor_roundtrip``) is unaffected by either field.
    """

    cut_layer: int = -1  # boundary index into the block stack; -1 = L // 2
    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)
    learnable_codec: bool = True  # Figure-2 linear encoder/decoder
    enabled: bool = True
    n_stages: int = 2  # pipeline partitions (paper: 2 = client/server)
    stage_quants: Tuple[QuantConfig, ...] = ()  # per-cut overrides

    def resolve_cut(self, n_layers: int) -> int:
        cut = self.cut_layer if self.cut_layer >= 0 else n_layers // 2
        return min(max(cut, 0), n_layers)

    def resolve_stage_quants(self) -> Tuple[QuantConfig, ...]:
        """One QuantConfig per pipeline cut (length ``n_stages - 1``)."""
        n_cuts = self.n_stages - 1
        if not self.stage_quants:
            return (self.quant,) * n_cuts
        if len(self.stage_quants) != n_cuts:
            raise ValueError(
                f"stage_quants has {len(self.stage_quants)} entries for "
                f"{n_cuts} cuts ({self.n_stages} stages)")
        return tuple(self.stage_quants)

    def with_plans(self, plans: Tuple[Tuple[int, ...], ...]) -> "SplitConfig":
        """The same topology carrying new per-cut allocation plans.

        ``plans[c]`` becomes cut c's ``group_widths`` (an empty tuple
        reverts that cut to its static width).  Returns a new frozen
        config, so the trainers' jit caches key on the plan for free.
        """
        quants = self.resolve_stage_quants()
        if len(plans) != len(quants):
            raise ValueError(
                f"{len(plans)} plans for {len(quants)} cuts")
        return dataclasses.replace(self, stage_quants=tuple(
            dataclasses.replace(q, group_widths=tuple(p))
            for q, p in zip(quants, plans)))


# ---------------------------------------------------------------------------
# learnable linear codec (Figure 2 client encoder / server decoder)
# ---------------------------------------------------------------------------

def init_codec_params(key: jax.Array, d_model: int,
                      dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    """Near-identity init so the cut is transparent at step 0."""
    k1, k2 = jax.random.split(key)
    eye = jnp.eye(d_model, dtype=dtype)
    noise = 0.01 / (d_model ** 0.5)
    return dict(
        enc_w=eye + noise * jax.random.normal(k1, (d_model, d_model), dtype),
        enc_b=jnp.zeros((d_model,), dtype),
        dec_w=eye + noise * jax.random.normal(k2, (d_model, d_model), dtype),
        dec_b=jnp.zeros((d_model,), dtype),
    )


def client_encode_pre(params: Optional[Dict], cfg: SplitConfig,
                      x: jnp.ndarray) -> jnp.ndarray:
    if cfg.learnable_codec and params is not None:
        return x @ params["enc_w"].astype(x.dtype) + \
            params["enc_b"].astype(x.dtype)
    return x


def server_decode_post(params: Optional[Dict], cfg: SplitConfig,
                       x_hat: jnp.ndarray) -> jnp.ndarray:
    if cfg.learnable_codec and params is not None:
        return x_hat @ params["dec_w"].astype(x_hat.dtype) + \
            params["dec_b"].astype(x_hat.dtype)
    return x_hat


# ---------------------------------------------------------------------------
# in-graph mode (end-to-end training / dry-run)
# ---------------------------------------------------------------------------

def compressor_roundtrip(params: Optional[Dict], cfg: SplitConfig,
                         x: jnp.ndarray,
                         rng: Optional[jax.Array] = None
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full Figure-2 path with the wire replaced by identity.

    Returns (server-side feature, commitment loss).
    """
    if not cfg.enabled or cfg.quant.method == "none":
        return x, jnp.zeros((), jnp.float32)
    h = client_encode_pre(params, cfg, x)
    h_hat, commit = quantizers.roundtrip(cfg.quant, h, rng)
    y = server_decode_post(params, cfg, h_hat)
    return y, commit


# ---------------------------------------------------------------------------
# wire mode (true cross-pod transfer)
# ---------------------------------------------------------------------------

_WIRE_INT = {2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}


def _one_ppermute(a: jnp.ndarray, axis_name: str, perm) -> jnp.ndarray:
    """ppermute one payload leaf at exactly its wire width.

    Float leaves cross the link bitcast to the same-width unsigned int.
    This is not cosmetic: XLA's simplifier reorders dtype converts across
    collectives (and the CPU backend strips opt-barriers before it runs),
    so a bf16 payload followed by an upcast can silently become an f32
    collective-permute — 2x the wire bytes the CommPayload accounts for.
    No convert can legally cross a bitcast, so the packed wire width is
    pinned by construction on every backend.
    """
    dt = a.dtype
    if jnp.issubdtype(dt, jnp.floating):
        u = _WIRE_INT[dt.itemsize]
        out = jax.lax.ppermute(jax.lax.bitcast_convert_type(a, u),
                               axis_name, perm)
        return jax.lax.bitcast_convert_type(out, dt)
    return jax.lax.ppermute(a, axis_name, perm)


def _tree_ppermute(tree, axis_name: str, perm):
    return jax.tree_util.tree_map(
        lambda a: _one_ppermute(a, axis_name, perm), tree)


@partial(jax.custom_vjp, nondiff_argnums=(0, 2, 3, 4))
def quantized_ship(cfg: QuantConfig, x: jnp.ndarray, axis_name: str,
                   perm: Tuple[Tuple[int, int], ...],
                   bwd_cfg: Optional[QuantConfig] = None) -> jnp.ndarray:
    """Quantize -> pack -> ppermute across ``axis_name`` -> decode.

    Only the *packed* payload crosses the link, which is why the collective
    bytes in the lowered HLO shrink by ~16/bits vs shipping bf16.

    ``bwd_cfg`` (BEYOND-PAPER, EXPERIMENTS.md SSPerf D): the paper limits
    compression to the forward pass and returns the cotangent at full
    precision; passing a QuantConfig here quantizes + packs the gradient
    on the reverse permutation too, compressing the backward wire by the
    same ratio.
    """
    payload = quantizers.encode(cfg, x)
    shipped = _tree_ppermute(payload, axis_name, list(perm))
    return quantizers.decode(cfg, shipped)


def _ship_fwd(cfg, x, axis_name, perm, bwd_cfg):
    return quantized_ship(cfg, x, axis_name, perm, bwd_cfg), None


def _ship_bwd(cfg, axis_name, perm, bwd_cfg, _res, g):
    rev = [(dst, src) for (src, dst) in perm]
    if bwd_cfg is None:
        # Paper scope: the cotangent returns uncompressed — but still at
        # ITS dtype: _one_ppermute's bitcast stops XLA widening the
        # backward wire to f32 (same convert-reorder as the forward).
        return (_one_ppermute(g, axis_name, rev),)
    payload = quantizers.encode(bwd_cfg, g)
    shipped = _tree_ppermute(payload, axis_name, rev)
    return (quantizers.decode(bwd_cfg, shipped),)


quantized_ship.defvjp(_ship_fwd, _ship_bwd)


# ---------------------------------------------------------------------------
# wire links — layer 2 of the stage/wire/scheduler decomposition
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WireLink:
    """One directed quantized edge of a split topology.

    A link owns everything about its cut: the forward ``QuantConfig``, the
    optional backward (cotangent) quant, and the *per-link* static byte
    accounting.  ``src``/``dst`` are stage indices on the ``pod`` mesh
    axis.  ``client`` tags hub links with the owning client id (chain
    links leave it None) — per-client quantizer calibration state is keyed
    by it (:func:`init_wire_calib` / :func:`update_wire_calib`).

    Byte accounting contract: each link is counted exactly once, on the
    devices that execute it.  This replaces the old
    ``pipeline_wire_bytes`` sum over distinct cut configs, which charged
    every device with every cut group's payload — an SPMD overcount
    whenever per-cut ``stage_quants`` were heterogeneous (a device at a
    2-bit cut never actually transmits the 4-bit cut's payload, even
    though the SPMD program makes it execute that ship op).
    """

    src: int
    dst: int
    quant: QuantConfig
    bwd_quant: Optional[QuantConfig] = None
    client: Optional[int] = None
    # SplitLoRA gradient-return codec: when the link's stages train LoRA
    # adapters, the returned/applied gradient traffic shrinks to the
    # adapter-grad tree, compressed by this codec (None = raw fp).  The
    # cotangent crossing the link (bwd_quant) is unchanged.
    grad_quant: Optional[QuantConfig] = None

    @property
    def perm(self) -> Tuple[Tuple[int, int], ...]:
        return ((self.src, self.dst),)

    @property
    def plan(self) -> Tuple[int, ...]:
        """The link's bit-allocation plan (empty = static single width)."""
        return tuple(self.quant.group_widths)

    def with_plan(self, widths: Tuple[int, ...],
                  perm: Tuple[int, ...] = ()) -> "WireLink":
        """The same link carrying a new allocation plan.

        Plans live on the forward ``QuantConfig`` (``group_widths`` plus
        the optional sorted-grouping ``channel_perm``), so a re-planned
        link hashes differently — the schedulers' jit caches recompile
        (or cache-hit) per plan with no extra plumbing.  The backward
        quant is untouched: the paper scopes compression to the forward
        wire, and the adaptive signal (boundary activation entropy) says
        nothing about the cotangent distribution.
        """
        return dataclasses.replace(
            self, quant=dataclasses.replace(self.quant,
                                            group_widths=tuple(widths),
                                            channel_perm=tuple(perm)))

    def ship(self, x: jnp.ndarray, axis_name: str = "pod") -> jnp.ndarray:
        """The real wire: encode -> ppermute src->dst -> decode."""
        return quantized_ship(self.quant, x, axis_name, self.perm,
                              self.bwd_quant)

    def fwd_wire_bytes(self, x_sds) -> int:
        """Static forward payload bytes for one activation of shape/dtype
        ``x_sds`` (works on ShapeDtypeStruct — no data touched)."""
        payload = jax.eval_shape(partial(quantizers.encode, self.quant),
                                 jax.ShapeDtypeStruct(x_sds.shape,
                                                      x_sds.dtype))
        return payload.wire_bytes()

    def bwd_wire_bytes(self, x_sds) -> int:
        """Static backward (cotangent) bytes: the packed payload when
        ``bwd_quant`` is set, else the uncompressed activation bytes (the
        paper's forward-only compression scope)."""
        if self.bwd_quant is None:
            return math.prod(x_sds.shape) * jnp.dtype(x_sds.dtype).itemsize
        payload = jax.eval_shape(partial(quantizers.encode, self.bwd_quant),
                                 jax.ShapeDtypeStruct(x_sds.shape,
                                                      x_sds.dtype))
        return payload.wire_bytes()

    def grad_wire_bytes(self, grad_tree_sds) -> int:
        """Static bytes of ONE direction of the SplitLoRA gradient return:
        the quantized adapter-grad tree (see :func:`tree_payload_bytes`).
        The trip crosses the link twice (up + back), once per step."""
        return tree_payload_bytes(self.grad_quant, grad_tree_sds)

    def grad_trip(self, grad_tree, axis_name: str = "pod"):
        """Round-trip the adapter-grad tree across this link (up + back),
        decoding to the gradient the optimizer applies."""
        return grad_return_trip(self.grad_quant, grad_tree, axis_name,
                                self.perm)


def tree_payload_bytes(q: Optional[QuantConfig], tree_sds) -> int:
    """Static wire bytes of a quantized *pytree* (one payload per leaf).

    ``q is None`` means the raw tree crosses uncompressed (at each leaf's
    own dtype width, as ``_one_ppermute`` pins it).  Used for the hub's
    adapter-grad return accounting: the SplitLoRA gradient wire carries
    the whole adapter-grad tree, not a single boundary activation.
    """
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree_sds):
        if q is None or q.method == "identity":
            total += math.prod(leaf.shape) * jnp.dtype(leaf.dtype).itemsize
        else:
            payload = jax.eval_shape(
                partial(quantizers.encode, q),
                jax.ShapeDtypeStruct(leaf.shape, leaf.dtype))
            total += payload.wire_bytes()
    return int(total)


def grad_return_trip(q: Optional[QuantConfig], tree, axis_name: str,
                     perm: Tuple[Tuple[int, int], ...]):
    """SplitLoRA gradient return: the adapter-grad tree crosses the link
    as a quantized payload, up and back.

    The client encodes each adapter-grad leaf with ``q``, ships the
    packed payload to the hub on ``perm``, the hub returns the payload it
    accepted on the reverse permutation, and the client decodes — the
    gradient the optimizer then applies has honestly crossed the codec
    in both directions (nothing for XLA to dead-code away), and each
    direction costs exactly ``tree_payload_bytes(q, tree)`` on the wire.
    ``q is None`` round-trips the raw tree (bitcast-pinned widths).
    """
    rev = [(dst, src) for (src, dst) in perm]

    def one(leaf):
        if q is None or q.method == "identity":
            up = _one_ppermute(leaf, axis_name, list(perm))
            return _one_ppermute(up, axis_name, rev)
        payload = quantizers.encode(q, leaf)
        up = _tree_ppermute(payload, axis_name, list(perm))
        back = _tree_ppermute(up, axis_name, rev)
        return quantizers.decode(q, back).astype(leaf.dtype)

    return jax.tree_util.tree_map(one, tree)


def pipeline_links(split: SplitConfig,
                   bwd_quant: Optional[QuantConfig] = None
                   ) -> Tuple[WireLink, ...]:
    """Chain topology: cut c connects stage c -> c+1."""
    return tuple(WireLink(src=c, dst=c + 1, quant=q, bwd_quant=bwd_quant)
                 for c, q in enumerate(split.resolve_stage_quants()))


def group_links(links: Tuple[WireLink, ...]
                ) -> Tuple[Tuple[QuantConfig, Optional[QuantConfig],
                                 Tuple[WireLink, ...]], ...]:
    """Group links with identical (quant, bwd_quant) so a scheduler can
    emit ONE collective per group (a multi-pair ppermute) instead of one
    per link.  Only valid when no destination repeats within a group —
    chain cuts qualify; hub links to the shared server do not (ppermute
    forbids a destination receiving from two sources), so hub schedulers
    ship per link."""
    groups: list = []
    for link in links:
        for i, (q, bq, ls) in enumerate(groups):
            if q == link.quant and bq == link.bwd_quant:
                groups[i] = (q, bq, ls + (link,))
                break
        else:
            groups.append((link.quant, link.bwd_quant, (link,)))
    return tuple(groups)


@dataclasses.dataclass(frozen=True)
class HubConfig:
    """Many-client split-learning hub: N clients sharing one server stage.

    BEYOND-PAPER (ROADMAP item 2): the paper deploys exactly one client
    and one server; the SL-for-LLM survey and VFLAIR-LLM frame the real
    setting as N clients — each with its own data distribution, quantizer
    calibration and tick rate — sharing one server stack.  Stage layout:
    pods 0..N-1 run per-client bottom halves (embed + L/2 blocks), pod N
    runs the shared server half (L/2 blocks + head), batched over
    arriving clients.

    ``client_quants`` optionally overrides the wire compressor per client
    (empty = ``quant`` everywhere) — heterogeneous entries exercise the
    per-link byte accounting.  ``tick_rates`` drives the async scheduler:
    client c produces a microbatch every ``tick_rates[c]`` global ticks
    (empty = all 1 = lockstep-equivalent arrival pattern).
    """

    n_clients: int = 1
    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)
    client_quants: Tuple[QuantConfig, ...] = ()
    bwd_quant: Optional[QuantConfig] = None
    tick_rates: Tuple[int, ...] = ()
    # SplitLoRA: codec for the adapter-grad return wire (see
    # ``WireLink.grad_quant``); only read when the hub trains with
    # ``lora_rank > 0``.  None = raw fp adapter grads.
    grad_quant: Optional[QuantConfig] = None

    @property
    def server_stage(self) -> int:
        """Pod index of the shared server stage."""
        return self.n_clients

    def resolve_client_quants(self) -> Tuple[QuantConfig, ...]:
        if not self.client_quants:
            return (self.quant,) * self.n_clients
        if len(self.client_quants) != self.n_clients:
            raise ValueError(
                f"client_quants has {len(self.client_quants)} entries for "
                f"{self.n_clients} clients")
        return tuple(self.client_quants)

    def resolve_tick_rates(self) -> Tuple[int, ...]:
        if not self.tick_rates:
            return (1,) * self.n_clients
        if len(self.tick_rates) != self.n_clients:
            raise ValueError(
                f"tick_rates has {len(self.tick_rates)} entries for "
                f"{self.n_clients} clients")
        if any(r < 1 for r in self.tick_rates):
            raise ValueError(f"tick rates must be >= 1: {self.tick_rates}")
        return tuple(self.tick_rates)

    def links(self) -> Tuple[WireLink, ...]:
        """Star topology: client c -> server, one link per client."""
        return tuple(WireLink(src=c, dst=self.server_stage, quant=q,
                              bwd_quant=self.bwd_quant, client=c,
                              grad_quant=self.grad_quant)
                     for c, q in enumerate(self.resolve_client_quants()))

    def with_plans(self, plans: Tuple[Tuple[int, ...], ...]) -> "HubConfig":
        """The same hub carrying new per-client allocation plans
        (``plans[c]`` -> client c's ``group_widths``; empty reverts to
        that client's static width)."""
        quants = self.resolve_client_quants()
        if len(plans) != len(quants):
            raise ValueError(
                f"{len(plans)} plans for {len(quants)} clients")
        return dataclasses.replace(self, client_quants=tuple(
            dataclasses.replace(q, group_widths=tuple(p))
            for q, p in zip(quants, plans)))


# ---------------------------------------------------------------------------
# per-client quantizer calibration state
# ---------------------------------------------------------------------------

def init_wire_calib() -> Dict[str, jnp.ndarray]:
    """Per-link codec calibration state: EMAs of the activation statistics
    the wire codecs derive their scales from (RD-FSQ: mu/sigma and the
    clipped min/max; NF-b: the per-block absmax is bounded by the same
    range).  One state per (link, client); the hub keeps them isolated so
    one client's distribution never leaks into another's codec."""
    z = jnp.zeros((), jnp.float32)
    return dict(mean=z, std=z, lo=z, hi=z, count=z)


def update_wire_calib(calib: Dict[str, jnp.ndarray], x: jnp.ndarray,
                      decay: float = 0.9) -> Dict[str, jnp.ndarray]:
    """EMA-update a calibration state with one activation batch.

    The first update adopts the batch statistics outright (``count`` == 0)
    so a fresh state is immediately usable instead of being dragged toward
    its zero init; later updates blend with ``decay``.
    """
    xf = x.astype(jnp.float32)
    batch = dict(mean=jnp.mean(xf), std=jnp.std(xf),
                 lo=jnp.min(xf), hi=jnp.max(xf))
    count = calib["count"]
    out = {k: jnp.where(count > 0.0,
                        decay * calib[k] + (1.0 - decay) * batch[k],
                        batch[k])
           for k in batch}
    out["count"] = count + 1.0
    return out


def calib_scale_error(calib: Dict[str, jnp.ndarray],
                      other: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Relative distance between two calibration states' ranges — the
    isolation metric the hub tests assert on."""
    span_a = calib["hi"] - calib["lo"]
    span_b = other["hi"] - other["lo"]
    return jnp.abs(span_a - span_b) / (jnp.maximum(
        jnp.abs(span_a), jnp.abs(span_b)) + 1e-8)


# ---------------------------------------------------------------------------
# in-graph cotangent quantization (async hub backward wire)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def quantize_cotangent(cfg: QuantConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Identity forward; the cotangent is pushed through ``cfg``'s wire
    codec (encode -> decode) on the way back.

    The in-graph twin of ``quantized_ship``'s ``bwd_cfg`` path, for
    schedulers whose client and server halves are co-located in one
    program (the async hub simulator): the forward activation already
    crossed via the STE roundtrip; this op makes the *gradient* traffic
    take the quantized wire form too.
    """
    return x


def _qc_fwd(cfg, x):
    return x, None


def _qc_bwd(cfg, _res, g):
    if cfg is None or cfg.method == "identity":
        return (g,)
    g_hat = quantizers.decode(cfg, quantizers.encode(cfg, g))
    return (g_hat.astype(g.dtype),)


quantize_cotangent.defvjp(_qc_fwd, _qc_bwd)


def wire_payload(cfg: SplitConfig, params: Optional[Dict], x: jnp.ndarray,
                 rng: Optional[jax.Array] = None) -> CommPayload:
    """Client-side wire form (for byte accounting / Table 4 benchmarks)."""
    h = client_encode_pre(params, cfg, x)
    return quantizers.encode(cfg.quant, h, rng)


def analytic_bits_per_scalar(q: QuantConfig, h_dim: int) -> float:
    """Paper Table 2 closed forms.

    A grouped plan's analytic rate is the width averaged over equal
    channel groups — exact, because the bitstream packers charge every
    width its true cost (3-bit groups cost 3 bits, not a 4-bit slot).
    """
    if q.method in ("fsq", "rdfsq", "nf"):
        if q.grouped:
            return q.mean_bits()
        return float(q.bits)
    if q.method == "topk":
        from repro.core.quantizers.topk import budget
        k_det, k_rand = budget(q, h_dim)
        return 16.0 * (k_det + k_rand) / h_dim
    if q.method == "identity":
        return 16.0
    raise ValueError(q.method)
