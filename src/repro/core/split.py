"""Split-learning boundary: compressor module + cross-partition transport.

Figure 2 of the paper: the compressor is an (optional, learnable) linear
encoder on the client, a quantizer, the wire, a dequantizer, and an
(optional, learnable) linear decoder on the server.  Two execution modes:

* ``compressor_roundtrip`` — in-graph quantize->dequantize with STE, used for
  end-to-end training (paper Table 3) and for the 40-combo dry-runs, where
  client and server halves are co-located SPMD programs.
* ``quantized_ship`` — the real wire: encode to the bit-packed payload,
  ``jax.lax.ppermute`` every payload array across the ``pod`` mesh axis,
  decode on the receiving pod.  A ``custom_vjp`` ships the (uncompressed,
  per the paper's forward-only compression scope) cotangent back on the
  reverse permutation.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quantizers
from repro.core.payload import CommPayload
from repro.core.quantizers import QuantConfig


@dataclasses.dataclass(frozen=True)
class SplitConfig:
    """Where and how the model is cut.

    ``n_stages`` / ``stage_quants`` describe the *pipeline* topology used
    by ``launch/split_pipeline.py`` (BEYOND-PAPER: the paper's deployment
    is the 2-partition client/server special case).  ``n_stages`` equal
    partitions give ``n_stages - 1`` quantized cuts; ``stage_quants``
    optionally overrides the compressor per cut (empty = ``quant``
    everywhere).  The in-graph single-cut path (``cut_layer`` +
    ``compressor_roundtrip``) is unaffected by either field.
    """

    cut_layer: int = -1  # boundary index into the block stack; -1 = L // 2
    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)
    learnable_codec: bool = True  # Figure-2 linear encoder/decoder
    enabled: bool = True
    n_stages: int = 2  # pipeline partitions (paper: 2 = client/server)
    stage_quants: Tuple[QuantConfig, ...] = ()  # per-cut overrides

    def resolve_cut(self, n_layers: int) -> int:
        cut = self.cut_layer if self.cut_layer >= 0 else n_layers // 2
        return min(max(cut, 0), n_layers)

    def resolve_stage_quants(self) -> Tuple[QuantConfig, ...]:
        """One QuantConfig per pipeline cut (length ``n_stages - 1``)."""
        n_cuts = self.n_stages - 1
        if not self.stage_quants:
            return (self.quant,) * n_cuts
        if len(self.stage_quants) != n_cuts:
            raise ValueError(
                f"stage_quants has {len(self.stage_quants)} entries for "
                f"{n_cuts} cuts ({self.n_stages} stages)")
        return tuple(self.stage_quants)


# ---------------------------------------------------------------------------
# learnable linear codec (Figure 2 client encoder / server decoder)
# ---------------------------------------------------------------------------

def init_codec_params(key: jax.Array, d_model: int,
                      dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    """Near-identity init so the cut is transparent at step 0."""
    k1, k2 = jax.random.split(key)
    eye = jnp.eye(d_model, dtype=dtype)
    noise = 0.01 / (d_model ** 0.5)
    return dict(
        enc_w=eye + noise * jax.random.normal(k1, (d_model, d_model), dtype),
        enc_b=jnp.zeros((d_model,), dtype),
        dec_w=eye + noise * jax.random.normal(k2, (d_model, d_model), dtype),
        dec_b=jnp.zeros((d_model,), dtype),
    )


def client_encode_pre(params: Optional[Dict], cfg: SplitConfig,
                      x: jnp.ndarray) -> jnp.ndarray:
    if cfg.learnable_codec and params is not None:
        return x @ params["enc_w"].astype(x.dtype) + \
            params["enc_b"].astype(x.dtype)
    return x


def server_decode_post(params: Optional[Dict], cfg: SplitConfig,
                       x_hat: jnp.ndarray) -> jnp.ndarray:
    if cfg.learnable_codec and params is not None:
        return x_hat @ params["dec_w"].astype(x_hat.dtype) + \
            params["dec_b"].astype(x_hat.dtype)
    return x_hat


# ---------------------------------------------------------------------------
# in-graph mode (end-to-end training / dry-run)
# ---------------------------------------------------------------------------

def compressor_roundtrip(params: Optional[Dict], cfg: SplitConfig,
                         x: jnp.ndarray,
                         rng: Optional[jax.Array] = None
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full Figure-2 path with the wire replaced by identity.

    Returns (server-side feature, commitment loss).
    """
    if not cfg.enabled or cfg.quant.method == "none":
        return x, jnp.zeros((), jnp.float32)
    h = client_encode_pre(params, cfg, x)
    h_hat, commit = quantizers.roundtrip(cfg.quant, h, rng)
    y = server_decode_post(params, cfg, h_hat)
    return y, commit


# ---------------------------------------------------------------------------
# wire mode (true cross-pod transfer)
# ---------------------------------------------------------------------------

_WIRE_INT = {2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}


def _one_ppermute(a: jnp.ndarray, axis_name: str, perm) -> jnp.ndarray:
    """ppermute one payload leaf at exactly its wire width.

    Float leaves cross the link bitcast to the same-width unsigned int.
    This is not cosmetic: XLA's simplifier reorders dtype converts across
    collectives (and the CPU backend strips opt-barriers before it runs),
    so a bf16 payload followed by an upcast can silently become an f32
    collective-permute — 2x the wire bytes the CommPayload accounts for.
    No convert can legally cross a bitcast, so the packed wire width is
    pinned by construction on every backend.
    """
    dt = a.dtype
    if jnp.issubdtype(dt, jnp.floating):
        u = _WIRE_INT[dt.itemsize]
        out = jax.lax.ppermute(jax.lax.bitcast_convert_type(a, u),
                               axis_name, perm)
        return jax.lax.bitcast_convert_type(out, dt)
    return jax.lax.ppermute(a, axis_name, perm)


def _tree_ppermute(tree, axis_name: str, perm):
    return jax.tree_util.tree_map(
        lambda a: _one_ppermute(a, axis_name, perm), tree)


@partial(jax.custom_vjp, nondiff_argnums=(0, 2, 3, 4))
def quantized_ship(cfg: QuantConfig, x: jnp.ndarray, axis_name: str,
                   perm: Tuple[Tuple[int, int], ...],
                   bwd_cfg: Optional[QuantConfig] = None) -> jnp.ndarray:
    """Quantize -> pack -> ppermute across ``axis_name`` -> decode.

    Only the *packed* payload crosses the link, which is why the collective
    bytes in the lowered HLO shrink by ~16/bits vs shipping bf16.

    ``bwd_cfg`` (BEYOND-PAPER, EXPERIMENTS.md SSPerf D): the paper limits
    compression to the forward pass and returns the cotangent at full
    precision; passing a QuantConfig here quantizes + packs the gradient
    on the reverse permutation too, compressing the backward wire by the
    same ratio.
    """
    payload = quantizers.encode(cfg, x)
    shipped = _tree_ppermute(payload, axis_name, list(perm))
    return quantizers.decode(cfg, shipped)


def _ship_fwd(cfg, x, axis_name, perm, bwd_cfg):
    return quantized_ship(cfg, x, axis_name, perm, bwd_cfg), None


def _ship_bwd(cfg, axis_name, perm, bwd_cfg, _res, g):
    rev = [(dst, src) for (src, dst) in perm]
    if bwd_cfg is None:
        # Paper scope: the cotangent returns uncompressed — but still at
        # ITS dtype: _one_ppermute's bitcast stops XLA widening the
        # backward wire to f32 (same convert-reorder as the forward).
        return (_one_ppermute(g, axis_name, rev),)
    payload = quantizers.encode(bwd_cfg, g)
    shipped = _tree_ppermute(payload, axis_name, rev)
    return (quantizers.decode(bwd_cfg, shipped),)


quantized_ship.defvjp(_ship_fwd, _ship_bwd)


def wire_payload(cfg: SplitConfig, params: Optional[Dict], x: jnp.ndarray,
                 rng: Optional[jax.Array] = None) -> CommPayload:
    """Client-side wire form (for byte accounting / Table 4 benchmarks)."""
    h = client_encode_pre(params, cfg, x)
    return quantizers.encode(cfg.quant, h, rng)


def analytic_bits_per_scalar(q: QuantConfig, h_dim: int) -> float:
    """Paper Table 2 closed forms."""
    if q.method in ("fsq", "rdfsq", "nf"):
        return float(q.bits)
    if q.method == "topk":
        from repro.core.quantizers.topk import budget
        k_det, k_rand = budget(q, h_dim)
        return 16.0 * (k_det + k_rand) / h_dim
    if q.method == "identity":
        return 16.0
    raise ValueError(q.method)
