"""Stage programs — what ONE split partition computes (layer 1 of 3).

The split stack is decomposed into three reusable layers:

  1. **stage programs** (this module): embed / body / head segments built
     on the ``repro.models.stack`` executor, with stage-stacked parameter
     trees and shard_map specs.  These used to live as closures inside
     ``launch/split_pipeline.build_pipeline_step``; extracting them lets
     the chain pipeline and the many-client hub share one definition of
     "what a partition computes".
  2. **wire links** (``repro.core.split.WireLink``): how activations and
     cotangents cross between stages, with per-link quantization and
     static byte accounting.
  3. **schedulers** (``repro.launch.schedules``): who ticks when —
     lockstep GPipe fill/drain, the N-client hub, and the
     staleness-tolerant async mode.

A stage program is deliberately *not* a stateful object: inside the SPMD
``shard_map`` programs every pod executes the same code and branches on
its stage index at runtime, so the useful unit is a set of pure segment
functions (:func:`embed_tokens`, :func:`run_blocks`, :func:`head_ce`)
plus the :class:`StageProgram` record describing which segments a given
partition owns (used for introspection, per-stage param counts and the
README topology tables).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import stack as stack_mod
from repro.models import transformer as tf
from repro.models.layers import embedding as emb_mod
from repro.models.layers.norms import rms_norm
from repro.train.losses import cross_entropy


@dataclasses.dataclass(frozen=True)
class StageProgram:
    """One partition of the split topology.

    ``first`` stages own the token embedding (they consume tokens);
    ``last`` stages own the final norm + head (they emit the CE loss);
    every stage owns ``per_stage`` transformer blocks.  The hub's shared
    server stage is a ``last`` (but not ``first``) program executed once
    for N clients' microbatches.
    """

    index: int
    n_stages: int
    per_stage: int
    first: bool
    last: bool
    # SplitLoRA: rank of the low-rank adapters this stage trains.  0 means
    # full fine-tuning (every base weight steps); r > 0 freezes the base
    # weights and steps only the (per-stage) adapter pytree, which also
    # shrinks the hub's gradient-return wire to the adapter-grad payload.
    lora_rank: int = 0

    @property
    def name(self) -> str:
        kind = ("client" if self.first else
                "server" if self.last else "mid")
        return f"stage{self.index}/{kind}"


def chain_programs(cfg: ArchConfig, n_stages: int,
                   lora_rank: int = 0) -> Tuple[StageProgram, ...]:
    """The linear pipeline: stage s runs layers [s*L/N, (s+1)*L/N)."""
    assert cfg.n_layers % n_stages == 0, (cfg.n_layers, n_stages)
    per = cfg.n_layers // n_stages
    return tuple(StageProgram(index=s, n_stages=n_stages, per_stage=per,
                              first=(s == 0), last=(s == n_stages - 1),
                              lora_rank=lora_rank)
                 for s in range(n_stages))


def hub_programs(cfg: ArchConfig, n_clients: int,
                 lora_rank: int = 0) -> Tuple[StageProgram, ...]:
    """The star topology: N client stages (embed + bottom half) feeding one
    shared server stage (top half + head)."""
    assert cfg.n_layers % 2 == 0, cfg.n_layers
    per = cfg.n_layers // 2
    clients = tuple(StageProgram(index=c, n_stages=n_clients + 1,
                                 per_stage=per, first=True, last=False,
                                 lora_rank=lora_rank)
                    for c in range(n_clients))
    server = StageProgram(index=n_clients, n_stages=n_clients + 1,
                          per_stage=per, first=False, last=True,
                          lora_rank=lora_rank)
    return clients + (server,)


# ---------------------------------------------------------------------------
# segment functions (the closures formerly inside build_pipeline_step)
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ArchConfig, params: Dict, tokens: jnp.ndarray,
                 dtype=None) -> jnp.ndarray:
    """First-stage input segment: token ids -> (..., S, D) activations."""
    return emb_mod.embed(params["embed"], tokens,
                         dtype if dtype is not None else tf.cdtype(cfg))


def run_blocks(cfg: ArchConfig, blocks: Dict, x: jnp.ndarray,
               positions: jnp.ndarray,
               adapters: Optional[Dict] = None,
               lora_scale: float = 1.0) -> jnp.ndarray:
    """Body segment: run a layer-stacked block tree through the unified
    stack executor (same remat policy as the monolithic forward).

    With ``adapters`` (a layer-stacked LoRA tree mirroring ``blocks``),
    the executor scans the *tuple* pytree ``(blocks, adapters)`` so each
    layer's slice keeps block and adapter paths aligned, and the block
    runs on the effective weights ``w + scale * A @ B`` — base leaves
    stay frozen; gradients flow to the adapter factors only.
    """
    if adapters is None:
        def body(h, p):
            h, _, _ = tf.block_forward(cfg, "dense", p, h,
                                       positions=positions, window=None)
            return h, ({}, None)

        x, _, _ = stack_mod.run_stack(body, x, blocks, remat=cfg.remat,
                                      remat_group=cfg.remat_group)
        return x

    from repro.peft import apply_lora

    def body(h, pa):
        p, ad = pa
        p_eff = apply_lora(p, ad, scale=lora_scale)
        h, _, _ = tf.block_forward(cfg, "dense", p_eff, h,
                                   positions=positions, window=None)
        return h, ({}, None)

    x, _, _ = stack_mod.run_stack(body, x, (blocks, adapters),
                                  remat=cfg.remat,
                                  remat_group=cfg.remat_group)
    return x


def head_ce(cfg: ArchConfig, params: Dict, h: jnp.ndarray,
            labels: jnp.ndarray) -> jnp.ndarray:
    """Last-stage output segment: final norm + vocab head + masked CE."""
    out = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = emb_mod.head_logits(params["head"], out)
    return cross_entropy(logits, labels)


def quantized_stage_blocks(params: Dict, stage: StageProgram,
                           weight_quant: str = "int4", *, group: int = 128,
                           hessians: Optional[Dict] = None):
    """Packed block tree for serving one stage to inference-only clients.

    Slices the stage's layer stack out of the stage-stacked ``blocks``
    tree and quantizes every structural w* site (``repro.wq``), so the
    hub's shared server stage answers inference clients from int4/int3
    weights while the trainable fp stack stays untouched.  The result
    drops into :func:`run_blocks` / :func:`head_ce` unchanged — the
    packed stores serve their sites through ``x @ w`` like the dense
    leaves they replace.  Returns ``(blocks, report)`` with the
    per-site (dense_bytes, packed_bytes) report.
    """
    from repro import wq

    blocks = jax.tree_util.tree_map(lambda v: v[stage.index],
                                    params["blocks"])
    wcfg = wq.parse_weight_quant(weight_quant, group=group)
    return wq.quantize_tree(blocks, wcfg, stacked_axes=1,
                            hessians=hessians)


# ---------------------------------------------------------------------------
# stage-stacked parameters + shard_map specs
# ---------------------------------------------------------------------------

def init_stage_params(key, cfg: ArchConfig, n_stages: int,
                      per_stage: Optional[int] = None,
                      lora_rank: int = 0) -> Dict:
    """Stage-stacked parameters: blocks (n_stages, per_stage, ...).

    Embed / head / final norm are shared (replicated): in the chain
    topology only the first / last stage reads them; in the hub every
    client embeds with the shared table.  ``per_stage`` defaults to
    ``n_layers // n_stages`` (the chain); the hub passes
    ``n_layers // 2`` with ``n_stages = n_clients + 1`` stacked stage
    trees (N client halves + 1 server half).

    With ``lora_rank > 0`` the dict gains an ``"adapters"`` entry: a
    LoRA tree mirroring ``blocks`` (same stage/layer stacking on every
    leaf) — the only parameters a SplitLoRA run steps.
    """
    if per_stage is None:
        assert cfg.n_layers % n_stages == 0, (cfg.n_layers, n_stages)
        per_stage = cfg.n_layers // n_stages
    k1, k2, k3, k4 = jax.random.split(key, 4)
    lkeys = jax.random.split(k1, n_stages * per_stage).reshape(
        n_stages, per_stage, -1)
    blocks = jax.vmap(jax.vmap(
        lambda k: tf.init_block_params(k, cfg, "dense")))(lkeys)
    params = dict(
        embed=emb_mod.init_embedding(k2, cfg.vocab_size, cfg.d_model,
                                     tf.pdtype(cfg)),
        head=emb_mod.init_head(k3, cfg.d_model, cfg.vocab_size,
                               dtype=tf.pdtype(cfg)),
        final_norm=jnp.ones((cfg.d_model,), tf.pdtype(cfg)),
        blocks=blocks,
    )
    if lora_rank > 0:
        from repro.peft import init_lora_params

        params["adapters"] = init_lora_params(k4, blocks, lora_rank)
    return params


def stage_param_specs(cfg: ArchConfig, n_stages: int,
                      per_stage: Optional[int] = None,
                      axis: str = "pod", lora_rank: int = 0) -> Dict:
    """shard_map in_specs: block stacks sharded over the stage axis,
    shared embed/head/norm replicated.  Adapter stacks (when
    ``lora_rank > 0``) shard over the stage axis exactly like blocks."""
    sds = jax.eval_shape(
        lambda: init_stage_params(jax.random.PRNGKey(0), cfg, n_stages,
                                  per_stage, lora_rank=lora_rank))
    specs = dict(
        embed=jax.tree_util.tree_map(lambda _: P(), dict(emb=0)),
        head=jax.tree_util.tree_map(lambda _: P(), dict(w=0)),
        final_norm=P(),
        blocks=jax.tree_util.tree_map(lambda _: P(axis), sds["blocks"]),
    )
    if lora_rank > 0:
        specs["adapters"] = jax.tree_util.tree_map(lambda _: P(axis),
                                                   sds["adapters"])
    return specs
