"""Core — the paper's contribution: compression + split-learning boundary."""
from repro.core.payload import CommPayload, bits_per_scalar
from repro.core.quantizers import QuantConfig, decode, encode, roundtrip
from repro.core.split import (HubConfig, SplitConfig, WireLink,
                              analytic_bits_per_scalar, calib_scale_error,
                              compressor_roundtrip, group_links,
                              init_codec_params, init_wire_calib,
                              pipeline_links, quantize_cotangent,
                              quantized_ship, update_wire_calib,
                              wire_payload)
from repro.core import entropy, packing

__all__ = [
    "CommPayload", "bits_per_scalar", "QuantConfig", "encode", "decode",
    "roundtrip", "SplitConfig", "compressor_roundtrip", "init_codec_params",
    "quantized_ship", "wire_payload", "analytic_bits_per_scalar", "entropy",
    "packing", "HubConfig", "WireLink", "group_links", "pipeline_links",
    "quantize_cotangent", "init_wire_calib", "update_wire_calib",
    "calib_scale_error",
]
