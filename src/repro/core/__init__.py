"""Core — the paper's contribution: compression + split-learning boundary."""
from repro.core.payload import CommPayload, bits_per_scalar
from repro.core.quantizers import QuantConfig, decode, encode, roundtrip
from repro.core.split import (SplitConfig, analytic_bits_per_scalar,
                              compressor_roundtrip, init_codec_params,
                              quantized_ship, wire_payload)
from repro.core import entropy, packing

__all__ = [
    "CommPayload", "bits_per_scalar", "QuantConfig", "encode", "decode",
    "roundtrip", "SplitConfig", "compressor_roundtrip", "init_codec_params",
    "quantized_ship", "wire_payload", "analytic_bits_per_scalar", "entropy",
    "packing",
]
