"""RD-FSQ — Robust & Distortion-aware FSQ (paper Algorithm 2, the new method).

Improvements over FSQ implemented exactly as in Section 3.2.2:

1. *Linear scaling* replaces tanh.  Values are first clipped to
   [mu - 3 sigma, mu + 3 sigma] to tame outliers, then min-max scaled onto
   (-1, 1).  (The paper prints ``2 (x - max)/(max - min) - 1`` which maps
   max -> -1 and min -> -3; the intended — and used — form is
   ``2 (x - min)/(max - min) - 1``.  Acknowledged erratum.)
2. *Distortion regularization*: cosine commitment loss
   ``L_comm = 1 - cos((d-1)/2 * e, sg(z))`` back-propagated on the client
   and added to the server CE loss with weight alpha.

The wire payload is the packed codes plus two fp16 scalars (lo, hi) per
statistics group so the server can invert the scaling exactly before its
learnable linear decoder.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.payload import CommPayload
from repro.core.quantizers import base
from repro.utils.tree import ste

_EPS = 1e-6


def _scale(cfg: base.QuantConfig, x: jnp.ndarray):
    """Clip to mu +- k*sigma then min-max scale onto [-1, 1]."""
    xf = x.astype(jnp.float32)
    axes = base.stats_axes(cfg, x.ndim)
    mu = jnp.mean(xf, axis=axes, keepdims=True)
    sigma = jnp.std(xf, axis=axes, keepdims=True)
    xc = jnp.clip(xf, mu - cfg.clip_sigma * sigma, mu + cfg.clip_sigma * sigma)
    lo = jnp.min(xc, axis=axes, keepdims=True)
    hi = jnp.max(xc, axis=axes, keepdims=True)
    e = 2.0 * (xc - lo) / (hi - lo + _EPS) - 1.0
    return e, lo, hi


def _quantize(cfg: base.QuantConfig, x: jnp.ndarray):
    d = cfg.levels
    half = (d - 1) / 2.0
    e, lo, hi = _scale(cfg, x)
    z = base.symmetric_round(e, d)
    idx = (z + half).astype(jnp.uint8)
    return e, z, idx, lo, hi


def _commit_loss(cfg: base.QuantConfig, e: jnp.ndarray,
                 z: jnp.ndarray) -> jnp.ndarray:
    """L_comm = 1 - cos((d-1)/2 * e, sg(z)), cosine over per-sample vectors."""
    half = (cfg.levels - 1) / 2.0
    a = (half * e).reshape(e.shape[0], -1)
    b = jax.lax.stop_gradient(z).reshape(z.shape[0], -1)
    num = jnp.sum(a * b, axis=-1)
    den = jnp.sqrt(jnp.sum(a * a, axis=-1) * jnp.sum(b * b, axis=-1) + _EPS)
    return jnp.mean(1.0 - num / den)


def _reconstruct(cfg: base.QuantConfig, idx: jnp.ndarray, lo, hi):
    d = cfg.levels
    half = (d - 1) / 2.0
    c = (idx.astype(jnp.float32) - half) / half  # Algorithm 2 line 9
    return (c + 1.0) / 2.0 * (hi - lo) + lo  # exact inverse of the scaling


def encode(cfg: base.QuantConfig, x: jnp.ndarray,
           rng: Optional[jax.Array] = None) -> CommPayload:
    _, _, idx, lo, hi = _quantize(cfg, x)
    words = packing.pack_bits(idx, cfg.bits)
    scales = jnp.stack(
        [lo.reshape(-1), hi.reshape(-1)], axis=-1).astype(jnp.float16)
    return CommPayload(
        data=words,
        scales=scales,
        meta=dict(method="rdfsq", impl="jnp", bits=cfg.bits,
                  shape=tuple(x.shape), dtype=str(x.dtype),
                  stats_shape=tuple(lo.shape)),
    )


def decode(cfg: base.QuantConfig, payload: CommPayload) -> jnp.ndarray:
    shape = payload.meta["shape"]
    stats_shape = payload.meta["stats_shape"]
    n = 1
    for s in shape:
        n *= s
    idx = packing.unpack_bits(payload.data, cfg.bits, n).reshape(shape)
    lo = payload.scales[:, 0].astype(jnp.float32).reshape(stats_shape)
    hi = payload.scales[:, 1].astype(jnp.float32).reshape(stats_shape)
    return _reconstruct(cfg, idx, lo, hi).astype(
        payload.meta.get("dtype", "float32"))


def roundtrip(cfg: base.QuantConfig, x: jnp.ndarray,
              rng: Optional[jax.Array] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    e, z, idx, lo, hi = _quantize(cfg, x)
    # fp16 side-info on the wire: reproduce its precision in-graph too so the
    # roundtrip matches decode(encode(x)) bit-for-bit.
    lo16 = lo.astype(jnp.float16).astype(jnp.float32)
    hi16 = hi.astype(jnp.float16).astype(jnp.float32)
    x_hat = _reconstruct(cfg, idx, lo16, hi16).astype(x.dtype)
    commit = _commit_loss(cfg, e, z)
    return ste(x, x_hat), commit


base.register("rdfsq", encode, decode, roundtrip)
