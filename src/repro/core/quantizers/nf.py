"""b-bit NormalFloat (QLoRA) activation quantization — paper Algorithm 3.

Generalizes QLoRA's NF4 (Dettmers et al. 2023) to arbitrary bit-width b and
applies it to *activations* on the split-learning wire:

  * Gaussian-quantile codebook NF_b with 2^b entries (exact zero included,
    asymmetric positive/negative halves, normalized to [-1, 1]).
  * Blockwise normalization: flatten to blocks of G, per-block (min, max),
    map onto [-1, 1], nearest-codebook-entry lookup.
  * Double quantization: the per-block ranges are themselves quantized to
    8-bit with one fp16 scale per group of ``dq_group`` blocks.

Wire payload = packed b-bit codes + uint8 range codes + fp16 block minima
+ fp16 group scales.  The extra side-info vs RD-FSQ is exactly the
"auxiliary information for dequantization" the paper blames for QLoRA's
higher Table-4 cost.
"""
from __future__ import annotations

import math
from functools import lru_cache
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.payload import CommPayload
from repro.core.quantizers import base
from repro.utils.tree import ste

_EPS = 1e-8


def _erfinv_scalar(y: float) -> float:
    """erfinv via Newton on math.erf (host-side, exact to ~1e-14;
    avoids a scipy dependency and stays trace-free under jit)."""
    if y <= -1.0 or y >= 1.0:
        raise ValueError("erfinv domain")
    x = 0.0
    for _ in range(80):
        err = math.erf(x) - y
        d = 2.0 / math.sqrt(math.pi) * math.exp(-x * x)
        step = err / d
        x -= step
        if abs(step) < 1e-15:
            break
    return x


def _norm_ppf(p) -> np.ndarray:
    """Standard normal quantile (pure host computation)."""
    arr = np.atleast_1d(np.asarray(p, dtype=np.float64))
    out = np.array([math.sqrt(2.0) * _erfinv_scalar(2.0 * v - 1.0)
                    for v in arr])
    return out


@lru_cache(maxsize=None)
def nf_codebook(bits: int) -> Tuple[float, ...]:
    """NF_b codebook: 2^b Gaussian-quantile levels on [-1, 1] with exact 0.

    Follows the QLoRA construction (asymmetric halves so zero is
    representable), with the offset generalized as 1 - 1/(2*2^b)
    (= 0.96875 for b=4, matching NF4's 0.9677 to 3 decimals).
    """
    n = 2 ** bits
    offset = 1.0 - 1.0 / (2 * n)
    pos = _norm_ppf(np.linspace(offset, 0.5, n // 2 + 1))[:-1]  # n//2 values
    neg = -_norm_ppf(np.linspace(offset, 0.5, n // 2))[:-1]  # n//2 - 1 values
    vals = np.concatenate([neg[::-1], [0.0], pos[::-1]])
    vals = np.sort(vals)
    vals = vals / np.abs(vals).max()
    assert vals.shape[0] == n
    return tuple(float(v) for v in vals)


def _to_blocks(cfg: base.QuantConfig, x: jnp.ndarray):
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    g = cfg.block_size
    pad = (-n) % g
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, g), n


def _block_quantize(cfg: base.QuantConfig, blocks: jnp.ndarray):
    """Per-block normalize + nearest NF_b entry (Algorithm 3 lines 3-7)."""
    book = jnp.asarray(nf_codebook(cfg.bits), jnp.float32)
    m = jnp.min(blocks, axis=-1, keepdims=True)
    mx = jnp.max(blocks, axis=-1, keepdims=True)
    rng = mx - m
    norm = 2.0 * (blocks - m) / (rng + _EPS) - 1.0
    dist = jnp.abs(norm[..., None] - book)  # (B, G, 2^b) — tiny last axis
    q = jnp.argmin(dist, axis=-1).astype(jnp.uint8)
    return q, m[..., 0], rng[..., 0], book


def _double_quant(cfg: base.QuantConfig, rng_vals: jnp.ndarray):
    """8-bit quantization of the per-block ranges with fp16 group scales."""
    nb = rng_vals.shape[0]
    gq = cfg.dq_group
    pad = (-nb) % gq
    padded = jnp.pad(rng_vals, (0, pad))
    groups = padded.reshape(-1, gq)
    gscale = jnp.max(jnp.abs(groups), axis=-1, keepdims=True)
    codes = jnp.round(groups / (gscale + _EPS) * 255.0).astype(jnp.uint8)
    # ship only the nb real codes — the group padding is reconstructed on
    # the receiving side, not paid for on the wire
    return codes.reshape(-1)[:nb], gscale[:, 0].astype(jnp.float16), nb


def _double_dequant(codes: jnp.ndarray, gscale: jnp.ndarray, gq: int,
                    nb: int) -> jnp.ndarray:
    codes = jnp.pad(codes.reshape(-1), (0, (-codes.size) % gq))
    groups = codes.reshape(-1, gq).astype(jnp.float32)
    vals = groups / 255.0 * gscale.astype(jnp.float32)[:, None]
    return vals.reshape(-1)[:nb]


def _reconstruct(book: jnp.ndarray, q: jnp.ndarray, m: jnp.ndarray,
                 rng_vals: jnp.ndarray) -> jnp.ndarray:
    """Algorithm 3 lines 15-16."""
    norm = book[q]
    return (norm + 1.0) / 2.0 * rng_vals[:, None] + m[:, None]


def encode(cfg: base.QuantConfig, x: jnp.ndarray,
           rng: Optional[jax.Array] = None) -> CommPayload:
    blocks, n = _to_blocks(cfg, x)
    q, m, rng_vals, _ = _block_quantize(cfg, blocks)
    words = packing.pack_bits(q, cfg.bits)
    aux = dict(block_min=m.astype(jnp.float16))
    if cfg.double_quant:
        codes, gscale, _ = _double_quant(cfg, rng_vals)
        scales = codes
        aux["dq_scale"] = gscale
    else:
        scales = rng_vals.astype(jnp.float16)
    return CommPayload(
        data=words, scales=scales, aux=aux,
        meta=dict(method="nf", impl="jnp", bits=cfg.bits,
                  shape=tuple(x.shape), dtype=str(x.dtype), n=n,
                  n_blocks=blocks.shape[0],
                  double_quant=cfg.double_quant),
    )


def decode(cfg: base.QuantConfig, payload: CommPayload) -> jnp.ndarray:
    shape = payload.meta["shape"]
    n = payload.meta["n"]
    nb = payload.meta["n_blocks"]
    book = jnp.asarray(nf_codebook(cfg.bits), jnp.float32)
    q = packing.unpack_bits(payload.data, cfg.bits,
                            nb * cfg.block_size).reshape(nb, cfg.block_size)
    m = payload.aux["block_min"].astype(jnp.float32)
    if payload.meta["double_quant"]:
        rng_vals = _double_dequant(payload.scales, payload.aux["dq_scale"],
                                   cfg.dq_group, nb)
    else:
        rng_vals = payload.scales.astype(jnp.float32)
    x_hat = _reconstruct(book, q, m, rng_vals)
    return x_hat.reshape(-1)[:n].reshape(shape).astype(
        payload.meta.get("dtype", "float32"))


def roundtrip(cfg: base.QuantConfig, x: jnp.ndarray,
              rng: Optional[jax.Array] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    blocks, n = _to_blocks(cfg, x)
    q, m, rng_vals, book = _block_quantize(cfg, blocks)
    m16 = m.astype(jnp.float16).astype(jnp.float32)
    if cfg.double_quant:
        codes, gscale, nb = _double_quant(cfg, rng_vals)
        rng_used = _double_dequant(codes, gscale, cfg.dq_group,
                                   rng_vals.shape[0])
    else:
        rng_used = rng_vals.astype(jnp.float16).astype(jnp.float32)
    x_hat = _reconstruct(book, q, m16, rng_used)
    x_hat = x_hat.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)
    return ste(x, x_hat), jnp.zeros((), jnp.float32)


base.register("nf", encode, decode, roundtrip)
