"""Identity compressor — the paper's "Original Model" 16-bit baseline."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.payload import CommPayload
from repro.core.quantizers import base


def encode(cfg: base.QuantConfig, x: jnp.ndarray,
           rng: Optional[jax.Array] = None) -> CommPayload:
    return CommPayload(
        data=x.astype(jnp.bfloat16),
        meta=dict(method="identity", bits=16, shape=tuple(x.shape),
                  dtype=str(x.dtype)),
    )


def decode(cfg: base.QuantConfig, payload: CommPayload) -> jnp.ndarray:
    return payload.data.astype(payload.meta.get("dtype", "float32"))


def roundtrip(cfg: base.QuantConfig, x: jnp.ndarray,
              rng: Optional[jax.Array] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return x.astype(jnp.bfloat16).astype(x.dtype), jnp.zeros((), jnp.float32)


base.register("identity", encode, decode, roundtrip)
