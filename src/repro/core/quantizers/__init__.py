"""Compression methods for split-learning activation transmission."""
from repro.core.quantizers.base import (QuantConfig, decode, encode, methods,
                                        roundtrip)

# registration side-effects
from repro.core.quantizers import fsq, identity, nf, rdfsq, topk  # noqa: F401, E402

__all__ = ["QuantConfig", "encode", "decode", "roundtrip", "methods"]
