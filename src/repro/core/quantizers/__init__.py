"""Compression methods for split-learning activation transmission."""
from repro.core.quantizers.base import (QuantConfig, decode, encode, methods,
                                        resolve_impl, roundtrip)

# registration side-effects: jnp oracles first, then the Pallas backends
# (which import repro.kernels and may fall back to the jnp encoders)
from repro.core.quantizers import fsq, identity, nf, rdfsq, topk  # noqa: F401, E402
from repro.core.quantizers import pallas_codecs  # noqa: F401, E402

__all__ = ["QuantConfig", "encode", "decode", "roundtrip", "methods",
           "resolve_impl"]
