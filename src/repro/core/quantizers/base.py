"""Quantizer API + registry.

Every compression method in the paper is exposed through three pure
functions, dispatched on ``QuantConfig.method``:

``encode(cfg, x, rng)   -> CommPayload``
    Wire form: bit-packed integer codes + scale side-info.  This is what the
    split-learning client transmits (paper Table 4 measures exactly this).

``decode(cfg, payload)  -> x_hat``
    Server-side reconstruction from the wire form.

``roundtrip(cfg, x, rng) -> (x_hat, aux_loss)``
    Differentiable in-graph quantize->dequantize with the straight-through
    estimator, used for end-to-end training (paper Table 3) and for the
    40-combo dry-runs.  ``aux_loss`` is RD-FSQ's commitment loss (0 for all
    other methods).

All three agree numerically: ``decode(cfg, encode(cfg, x, rng)) ==
roundtrip(cfg, x, rng)[0]`` (tested property).

Backend dispatch (mirrors ``kernels/attention_ops.py``): ``encode`` may
run either the pure-jnp registration (the oracle) or a fused Pallas
quantize+pack kernel registered via :func:`register_backend`.  Selection
order:

  1. explicit ``impl=`` keyword (parity tests / benchmarks);
  2. the ``REPRO_QUANT_IMPL`` environment variable (``pallas`` | ``jnp``);
  3. default: Pallas on TPU backends, jnp elsewhere (the interpreter is
     exact but slow, so CPU CI stays on jnp unless a test opts in).

``decode`` dispatches on the payload's own ``meta["impl"]`` tag — a
payload always decodes with the backend that produced it, so the two
sides of the wire never disagree about the packed layout.  ``roundtrip``
is always the jnp/STE path (it must be differentiable; the kernels are
encode/decode only).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.payload import CommPayload
from repro.utils.dispatch import resolve_backend_impl

_VALID_IMPLS = ("pallas", "jnp")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static configuration for one compression method instance."""

    method: str = "rdfsq"  # fsq | rdfsq | nf | topk | identity
    bits: int = 2  # d = 2**bits discrete levels
    # --- NF-b (QLoRA) ---
    block_size: int = 64  # G in Algorithm 3
    double_quant: bool = True  # 8-bit quantization of block scales
    dq_group: int = 256  # blocks per double-quant group
    # --- RD-FSQ ---
    commit_alpha: float = 0.25  # alpha weighting L_comm
    clip_sigma: float = 3.0  # mu +- 3 sigma outlier clip
    # --- Randomized Top-K ---
    rand_frac: float = 0.25  # fraction of the budget spent on random picks
    # --- shared ---
    stats_axis: str = "sample"  # 'sample' (per batch row) | 'tensor'

    @property
    def levels(self) -> int:
        return 2 ** self.bits


_ENCODERS: Dict[str, Callable] = {}
_DECODERS: Dict[str, Callable] = {}
_ROUNDTRIPS: Dict[str, Callable] = {}
# (method, impl) -> fn for non-default backends (currently impl='pallas')
_BACKEND_ENCODERS: Dict[Tuple[str, str], Callable] = {}
_BACKEND_DECODERS: Dict[Tuple[str, str], Callable] = {}


def register(method: str, encode_fn, decode_fn, roundtrip_fn) -> None:
    _ENCODERS[method] = encode_fn
    _DECODERS[method] = decode_fn
    _ROUNDTRIPS[method] = roundtrip_fn


def register_backend(method: str, impl: str, encode_fn, decode_fn) -> None:
    """Register an alternative (fused-kernel) encode/decode pair.

    The backend must preserve the wire semantics: ``decode(encode(x))``
    reconstructs the same values the jnp oracle produces (the packed
    payload *layout* may differ — each backend decodes its own payloads,
    tagged via ``meta['impl']``).
    """
    if impl not in _VALID_IMPLS:
        raise ValueError(f"unknown quantizer impl {impl!r}")
    _BACKEND_ENCODERS[(method, impl)] = encode_fn
    _BACKEND_DECODERS[(method, impl)] = decode_fn


def resolve_impl(impl: Optional[str] = None) -> str:
    """Resolve the codec backend (see module docstring for order)."""
    return resolve_backend_impl(impl, "REPRO_QUANT_IMPL", "quantizer",
                                _VALID_IMPLS)


def encode(cfg: QuantConfig, x: jnp.ndarray,
           rng: Optional[jax.Array] = None,
           impl: Optional[str] = None) -> CommPayload:
    fn = _BACKEND_ENCODERS.get((cfg.method, resolve_impl(impl)))
    if fn is not None:
        return fn(cfg, x, rng)
    return _ENCODERS[cfg.method](cfg, x, rng)


def decode(cfg: QuantConfig, payload: CommPayload) -> jnp.ndarray:
    fn = _BACKEND_DECODERS.get((cfg.method,
                                payload.meta.get("impl", "jnp")))
    if fn is not None:
        return fn(cfg, payload)
    return _DECODERS[cfg.method](cfg, payload)


def roundtrip(cfg: QuantConfig, x: jnp.ndarray,
              rng: Optional[jax.Array] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return _ROUNDTRIPS[cfg.method](cfg, x, rng)


def methods() -> Tuple[str, ...]:
    return tuple(sorted(_ENCODERS))


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def stats_axes(cfg: QuantConfig, ndim: int):
    """Axes over which scaling statistics are computed.

    'sample': one scale set per leading-batch row (what crosses the wire is
    then 2 fp16 scalars per sample — negligible); 'tensor': a single global
    scale set.
    """
    if cfg.stats_axis == "sample":
        return tuple(range(1, ndim))
    if cfg.stats_axis == "tensor":
        return tuple(range(ndim))
    raise ValueError(f"unknown stats_axis {cfg.stats_axis!r}")


def symmetric_round(e: jnp.ndarray, d: int) -> jnp.ndarray:
    """Paper Algorithms 1/2 lines 3-6: round e in [-1,1] to d levels.

    Returns z on the symmetric grid; for even d the grid is half-integer
    ({-(d-1)/2, ..., -0.5, 0.5, ..., (d-1)/2}).
    """
    half = (d - 1) / 2.0
    if d % 2 == 1:
        z = jnp.round(half * e)
    else:
        z = jnp.round(half * e - 0.5) + 0.5
    return jnp.clip(z, -half, half)
