"""Quantizer API + registry.

Every compression method in the paper is exposed through three pure
functions, dispatched on ``QuantConfig.method``:

``encode(cfg, x, rng)   -> CommPayload``
    Wire form: bit-packed integer codes + scale side-info.  This is what the
    split-learning client transmits (paper Table 4 measures exactly this).

``decode(cfg, payload)  -> x_hat``
    Server-side reconstruction from the wire form.

``roundtrip(cfg, x, rng) -> (x_hat, aux_loss)``
    Differentiable in-graph quantize->dequantize with the straight-through
    estimator, used for end-to-end training (paper Table 3) and for the
    40-combo dry-runs.  ``aux_loss`` is RD-FSQ's commitment loss (0 for all
    other methods).

All three agree numerically: ``decode(cfg, encode(cfg, x, rng)) ==
roundtrip(cfg, x, rng)[0]`` (tested property).

Backend dispatch (mirrors ``kernels/attention_ops.py``): ``encode`` may
run either the pure-jnp registration (the oracle) or a fused Pallas
quantize+pack kernel registered via :func:`register_backend`.  Selection
order:

  1. explicit ``impl=`` keyword (parity tests / benchmarks);
  2. the ``REPRO_QUANT_IMPL`` environment variable (``pallas`` | ``jnp``);
  3. default: Pallas on TPU backends, jnp elsewhere (the interpreter is
     exact but slow, so CPU CI stays on jnp unless a test opts in).

``decode`` dispatches on the payload's own ``meta["impl"]`` tag — a
payload always decodes with the backend that produced it, so the two
sides of the wire never disagree about the packed layout.  ``roundtrip``
is always the jnp/STE path (it must be differentiable; the kernels are
encode/decode only).

Grouped mixed precision (ROADMAP item 3): a ``QuantConfig`` whose
``group_widths`` is non-empty is an *allocation plan* — the channel
(last) axis splits into equal contiguous groups, group g quantized at
``group_widths[g]`` bits with its own scale statistics.  All three
entry points transparently take the grouped path
(:func:`encode_grouped` / :func:`decode_grouped` /
:func:`roundtrip_grouped`), producing/consuming a
:class:`~repro.core.payload.GroupedPayload`; each group dispatches
through the backend registry independently (mixed Pallas/jnp groups are
fine — every sub-payload self-describes).

A plan may additionally carry ``channel_perm``: the encoder gathers the
channel axis into that order before grouping and the decoder scatters
it back, so "groups" are arbitrary channel SETS, not just contiguous
ranges.  An entropy-sorted permutation concentrates the per-channel
spread into between-group spread — the allocator then starves the
genuinely low-information groups and (when the deficit warrants)
widens the high-information ones, which contiguous grouping averages
away.  Like the widths, the permutation is plan metadata synced out of
band at re-plan time (both wire endpoints hold the same
``QuantConfig``): it costs zero payload bytes, and ``wire_bytes()`` is
unchanged by it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.payload import CommPayload, GroupedPayload
from repro.utils.dispatch import resolve_backend_impl

_VALID_IMPLS = ("pallas", "jnp")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static configuration for one compression method instance."""

    method: str = "rdfsq"  # fsq | rdfsq | nf | topk | identity
    bits: int = 2  # d = 2**bits discrete levels
    # --- NF-b (QLoRA) ---
    block_size: int = 64  # G in Algorithm 3
    double_quant: bool = True  # 8-bit quantization of block scales
    dq_group: int = 256  # blocks per double-quant group
    # --- RD-FSQ ---
    commit_alpha: float = 0.25  # alpha weighting L_comm
    clip_sigma: float = 3.0  # mu +- 3 sigma outlier clip
    # --- Randomized Top-K ---
    rand_frac: float = 0.25  # fraction of the budget spent on random picks
    # --- shared ---
    stats_axis: str = "sample"  # 'sample' (per batch row) | 'tensor'
    # --- grouped mixed precision (the adaptive wire's allocation plan) ---
    # Non-empty: the channel (last) axis splits into len(group_widths)
    # contiguous equal groups; group g is quantized at group_widths[g]
    # bits with its OWN scale statistics, and the wire form becomes a
    # GroupedPayload.  Empty: the static single-width wire (``bits``).
    # A tuple on a frozen dataclass, so a plan is hashable — jit caches
    # (and the schedulers' cached update fns) key on it directly.
    group_widths: Tuple[int, ...] = ()
    # Optional channel gather order applied before grouping (and inverted
    # after reassembly): entropy-sorted grouping.  Plan metadata, not
    # payload content — zero wire bytes.  Empty = identity.
    channel_perm: Tuple[int, ...] = ()
    # Double-quantize the grouped wire's scale side-info: every group's
    # fp16 scales ship as 8-bit codes against one shared per-payload
    # (lo, hi) fp16 range (GroupedPayload.scale_meta).  Halves the scale
    # bytes — which dominate narrow-width grouped payloads at small
    # per-(sample, group) populations.  Encode/decode wire form only;
    # the differentiable roundtrip keeps exact fp16 scales.
    scale_dq: bool = False

    @property
    def levels(self) -> int:
        return 2 ** self.bits

    @property
    def grouped(self) -> bool:
        return bool(self.group_widths)

    def group_cfgs(self) -> Tuple["QuantConfig", ...]:
        """One ungrouped per-group config (bits = that group's width)."""
        return tuple(dataclasses.replace(self, bits=w, group_widths=())
                     for w in self.group_widths)

    def mean_bits(self) -> float:
        """Average code width per scalar (equal groups)."""
        if not self.group_widths:
            return float(self.bits)
        return sum(self.group_widths) / len(self.group_widths)


_ENCODERS: Dict[str, Callable] = {}
_DECODERS: Dict[str, Callable] = {}
_ROUNDTRIPS: Dict[str, Callable] = {}
# (method, impl) -> fn for non-default backends (currently impl='pallas')
_BACKEND_ENCODERS: Dict[Tuple[str, str], Callable] = {}
_BACKEND_DECODERS: Dict[Tuple[str, str], Callable] = {}


def register(method: str, encode_fn, decode_fn, roundtrip_fn) -> None:
    _ENCODERS[method] = encode_fn
    _DECODERS[method] = decode_fn
    _ROUNDTRIPS[method] = roundtrip_fn


def register_backend(method: str, impl: str, encode_fn, decode_fn) -> None:
    """Register an alternative (fused-kernel) encode/decode pair.

    The backend must preserve the wire semantics: ``decode(encode(x))``
    reconstructs the same values the jnp oracle produces (the packed
    payload *layout* may differ — each backend decodes its own payloads,
    tagged via ``meta['impl']``).
    """
    if impl not in _VALID_IMPLS:
        raise ValueError(f"unknown quantizer impl {impl!r}")
    _BACKEND_ENCODERS[(method, impl)] = encode_fn
    _BACKEND_DECODERS[(method, impl)] = decode_fn


def resolve_impl(impl: Optional[str] = None) -> str:
    """Resolve the codec backend (see module docstring for order)."""
    return resolve_backend_impl(impl, "REPRO_QUANT_IMPL", "quantizer",
                                _VALID_IMPLS)


def _group_splits(cfg: QuantConfig, d: int) -> int:
    """Validate the plan against the channel axis; returns group size."""
    g = len(cfg.group_widths)
    if d % g != 0:
        raise ValueError(
            f"channel axis {d} does not divide into {g} groups")
    bad = [w for w in cfg.group_widths if not 1 <= w <= 8]
    if bad:
        raise ValueError(f"group widths must be in [1, 8]: {bad}")
    return d // g


def _group_rngs(rng: Optional[jax.Array], n: int):
    if rng is None:
        return (None,) * n
    return tuple(jax.random.split(rng, n))


def _apply_perm(cfg: QuantConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Gather the channel axis into plan order (identity if unset)."""
    if not cfg.channel_perm:
        return x
    if len(cfg.channel_perm) != x.shape[-1]:
        raise ValueError(
            f"channel_perm has {len(cfg.channel_perm)} entries for a "
            f"{x.shape[-1]}-channel axis")
    return jnp.take(x, jnp.asarray(cfg.channel_perm), axis=-1)


def _invert_perm(cfg: QuantConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Scatter the reassembled channel axis back to wire order."""
    if not cfg.channel_perm:
        return x
    inv = sorted(range(len(cfg.channel_perm)),
                 key=cfg.channel_perm.__getitem__)
    return jnp.take(x, jnp.asarray(inv), axis=-1)


def _dq_scales(groups):
    """8-bit double-quant of the groups' fp16 scale side-info.

    One affine (lo, hi) range is shared by every scale tensor in the
    payload — the codes are ``round(255 * (s - lo) / (hi - lo))`` uint8
    and the range ships as a (2,) fp16 ``scale_meta``.  Groups without
    scales (FSQ) or with already-integer scales (NF's own block-scale
    double quant) pass through untouched.
    """
    def eligible(g):
        return (g.scales is not None
                and jnp.issubdtype(g.scales.dtype, jnp.floating))

    vals = [g.scales for g in groups if eligible(g)]
    if not vals:
        return tuple(groups), None
    flat = jnp.concatenate([v.reshape(-1).astype(jnp.float32)
                            for v in vals])
    lo, hi = jnp.min(flat), jnp.max(flat)
    span = jnp.maximum(hi - lo, 1e-12)
    out = []
    for g in groups:
        if not eligible(g):
            out.append(g)
            continue
        codes = jnp.round((g.scales.astype(jnp.float32) - lo) / span
                          * 255.0).astype(jnp.uint8)
        out.append(dataclasses.replace(
            g, scales=codes, meta=dict(g.meta, scale_dq=True)))
    meta = jnp.stack([lo, hi]).astype(jnp.float16)
    return tuple(out), meta


def _undq_scales(payload: GroupedPayload):
    """Invert :func:`_dq_scales`: rebuild fp16 scales from uint8 codes."""
    lo = payload.scale_meta[0].astype(jnp.float32)
    hi = payload.scale_meta[1].astype(jnp.float32)
    span = jnp.maximum(hi - lo, 1e-12)
    out = []
    for g in payload.groups:
        if g.scales is None or not g.meta.get("scale_dq"):
            out.append(g)
            continue
        scales = (lo + g.scales.astype(jnp.float32) / 255.0 * span
                  ).astype(jnp.float16)
        meta = {k: v for k, v in g.meta.items() if k != "scale_dq"}
        out.append(dataclasses.replace(g, scales=scales, meta=meta))
    return tuple(out)


def encode_grouped(cfg: QuantConfig, x: jnp.ndarray,
                   rng: Optional[jax.Array] = None,
                   impl: Optional[str] = None) -> GroupedPayload:
    """Grouped mixed-precision wire form: slice the channel (last) axis
    into equal contiguous groups and encode each at its planned width.

    Each group payload carries its own scale statistics (computed over
    the group only — per-group normalization is itself most of the
    adaptive win: one outlier channel no longer dilates every channel's
    grid), and each group dispatches through the backend registry
    independently, so power-of-two groups may take the fused Pallas
    kernels while odd widths take the exact jnp bitstream path.
    """
    gs = _group_splits(cfg, x.shape[-1])
    x = _apply_perm(cfg, x)
    groups = []
    for i, (sub_cfg, r) in enumerate(zip(cfg.group_cfgs(),
                                         _group_rngs(rng,
                                                     len(cfg.group_widths)))):
        xg = jax.lax.slice_in_dim(x, i * gs, (i + 1) * gs, axis=x.ndim - 1)
        groups.append(encode(sub_cfg, xg, r, impl))
    groups, scale_meta = (_dq_scales(groups) if cfg.scale_dq
                          else (tuple(groups), None))
    return GroupedPayload(
        groups=groups,
        scale_meta=scale_meta,
        meta=dict(method=cfg.method, widths=tuple(cfg.group_widths),
                  group_size=gs, shape=tuple(x.shape), dtype=str(x.dtype),
                  permuted=bool(cfg.channel_perm)),
    )


def decode_grouped(cfg: QuantConfig, payload: GroupedPayload) -> jnp.ndarray:
    """Reassemble the channel axis from the per-group reconstructions."""
    groups = (_undq_scales(payload) if payload.scale_meta is not None
              else payload.groups)
    parts = [decode(sub_cfg, g)
             for sub_cfg, g in zip(cfg.group_cfgs(), groups)]
    return _invert_perm(cfg, jnp.concatenate(parts, axis=-1))


def roundtrip_grouped(cfg: QuantConfig, x: jnp.ndarray,
                      rng: Optional[jax.Array] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Differentiable (STE) grouped roundtrip — jnp stays the oracle.

    The aux (commitment) loss is the mean over groups, matching the
    ungrouped loss scale for equal-size groups.
    """
    gs = _group_splits(cfg, x.shape[-1])
    x = _apply_perm(cfg, x)
    parts, auxes = [], []
    for i, (sub_cfg, r) in enumerate(zip(cfg.group_cfgs(),
                                         _group_rngs(rng,
                                                     len(cfg.group_widths)))):
        xg = jax.lax.slice_in_dim(x, i * gs, (i + 1) * gs, axis=x.ndim - 1)
        xh, aux = _ROUNDTRIPS[cfg.method](sub_cfg, xg, r)
        parts.append(xh)
        auxes.append(aux)
    x_hat = _invert_perm(cfg, jnp.concatenate(parts, axis=-1))
    return x_hat, jnp.mean(jnp.stack(auxes))


def encode(cfg: QuantConfig, x: jnp.ndarray,
           rng: Optional[jax.Array] = None,
           impl: Optional[str] = None):
    if cfg.grouped:
        return encode_grouped(cfg, x, rng, impl)
    fn = _BACKEND_ENCODERS.get((cfg.method, resolve_impl(impl)))
    if fn is not None:
        return fn(cfg, x, rng)
    return _ENCODERS[cfg.method](cfg, x, rng)


def decode(cfg: QuantConfig, payload) -> jnp.ndarray:
    if isinstance(payload, GroupedPayload):
        return decode_grouped(cfg, payload)
    fn = _BACKEND_DECODERS.get((cfg.method,
                                payload.meta.get("impl", "jnp")))
    if fn is not None:
        return fn(cfg, payload)
    return _DECODERS[cfg.method](cfg, payload)


def roundtrip(cfg: QuantConfig, x: jnp.ndarray,
              rng: Optional[jax.Array] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if cfg.grouped:
        return roundtrip_grouped(cfg, x, rng)
    return _ROUNDTRIPS[cfg.method](cfg, x, rng)


def methods() -> Tuple[str, ...]:
    return tuple(sorted(_ENCODERS))


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def stats_axes(cfg: QuantConfig, ndim: int):
    """Axes over which scaling statistics are computed.

    'sample': one scale set per leading-batch row (what crosses the wire is
    then 2 fp16 scalars per sample — negligible); 'tensor': a single global
    scale set.
    """
    if cfg.stats_axis == "sample":
        return tuple(range(1, ndim))
    if cfg.stats_axis == "tensor":
        return tuple(range(ndim))
    raise ValueError(f"unknown stats_axis {cfg.stats_axis!r}")


def symmetric_round(e: jnp.ndarray, d: int) -> jnp.ndarray:
    """Paper Algorithms 1/2 lines 3-6: round e in [-1,1] to d levels.

    Returns z on the symmetric grid; for even d the grid is half-integer
    ({-(d-1)/2, ..., -0.5, 0.5, ..., (d-1)/2}).
    """
    half = (d - 1) / 2.0
    if d % 2 == 1:
        z = jnp.round(half * e)
    else:
        z = jnp.round(half * e - 0.5) + 0.5
    return jnp.clip(z, -half, half)
