"""Fused Pallas wire codecs, registered behind the quantizer dispatch.

The compressor sits serially on the split-learning wire (it runs on every
microbatch before the cross-pod transfer), so its latency adds directly
to the communication-critical path.  The jnp registrations in
``rdfsq.py`` / ``nf.py`` materialize the 8-bit intermediate codes plus
separate pack ops; the fused kernels in ``repro.kernels`` stream
clip -> scale -> round -> pack in a single VMEM pass.  This module adapts
those kernels to the ``CommPayload`` wire contract and registers them as
the ``pallas`` backend, so ``core.split.quantized_ship``,
``core.split.wire_payload`` and the split pipeline pick them up with zero
call-site churn (``REPRO_QUANT_IMPL=pallas`` or ``impl='pallas'``).

Payload layout note: the kernels pack codes per sample row / per block
(so rows stay tile-aligned), while the jnp oracle packs one flat stream.
Total wire bytes agree whenever the per-row code count divides the
8/storage-bits packing factor; reconstruction numerics agree with the
jnp ``roundtrip`` in every case (tested).  A payload is always decoded
by the backend that produced it — ``meta['impl']`` travels in the static
session handshake, never on the wire.

Configs the kernels do not cover (``stats_axis='tensor'``, NF block
sizes that straddle packed words, non-power-of-two widths — the kernels
pack one code per sub-byte slot, while the exact cross-byte bitstream
layout for odd widths lives in the jnp packers) fall back to the jnp
oracle encoder, whose payloads self-describe via the missing ``impl``
tag.  Grouped mixed-precision payloads dispatch per group ABOVE this
registry (``base.encode_grouped``), so a grouped wire mixes backends
freely: power-of-two groups take the kernels, odd-width groups take the
jnp bitstream.
"""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp

from repro.core.packing import KERNEL_SLOT_BITS, storage_bits
from repro.core.payload import CommPayload
from repro.core.quantizers import base, nf, rdfsq
from repro.kernels import ops


# ---------------------------------------------------------------------------
# RD-FSQ
# ---------------------------------------------------------------------------

def _rdfsq_encode(cfg: base.QuantConfig, x: jnp.ndarray,
                  rng: Optional[jnp.ndarray] = None) -> CommPayload:
    if cfg.stats_axis != "sample" or x.ndim < 2:
        return rdfsq.encode(cfg, x, rng)  # kernel stats are per sample row
    if cfg.bits not in KERNEL_SLOT_BITS:
        return rdfsq.encode(cfg, x, rng)  # odd widths: exact jnp bitstream
    words, stats = ops.rdfsq_quantize(x, cfg.bits, cfg.clip_sigma)
    return CommPayload(
        data=words,
        scales=stats,
        meta=dict(method="rdfsq", impl="pallas", bits=cfg.bits,
                  shape=tuple(x.shape), dtype=str(x.dtype)),
    )


def _rdfsq_decode(cfg: base.QuantConfig, payload: CommPayload) -> jnp.ndarray:
    shape = payload.meta["shape"]
    n_cols = math.prod(shape[1:])
    x2d = ops.rdfsq_dequantize(
        payload.data, payload.scales, cfg.bits, n_cols,
        out_dtype=jnp.dtype(payload.meta.get("dtype", "float32")))
    return x2d.reshape(shape)


# ---------------------------------------------------------------------------
# NF-b (QLoRA)
# ---------------------------------------------------------------------------

def _nf_encode(cfg: base.QuantConfig, x: jnp.ndarray,
               rng: Optional[jnp.ndarray] = None) -> CommPayload:
    if cfg.bits not in KERNEL_SLOT_BITS:
        return nf.encode(cfg, x, rng)  # odd widths: exact jnp bitstream
    if cfg.block_size % (8 // storage_bits(cfg.bits)) != 0:
        return nf.encode(cfg, x, rng)  # rows would straddle packed words
    words, scales, aux = ops.nf_quantize(
        x, cfg.bits, block=cfg.block_size, double_quant=cfg.double_quant,
        dq_group=cfg.dq_group)
    return CommPayload(
        data=words, scales=scales, aux=aux,
        meta=dict(method="nf", impl="pallas", bits=cfg.bits,
                  shape=tuple(x.shape), dtype=str(x.dtype), n=x.size,
                  double_quant=cfg.double_quant),
    )


def _nf_decode(cfg: base.QuantConfig, payload: CommPayload) -> jnp.ndarray:
    shape = payload.meta["shape"]
    n = payload.meta["n"]
    flat = ops.nf_dequantize(
        payload.data, payload.scales, payload.aux, cfg.bits, n,
        block=cfg.block_size, double_quant=payload.meta["double_quant"],
        dq_group=cfg.dq_group,
        out_dtype=jnp.dtype(payload.meta.get("dtype", "float32")))
    return flat.reshape(shape)


base.register_backend("rdfsq", "pallas", _rdfsq_encode, _rdfsq_decode)
base.register_backend("nf", "pallas", _nf_encode, _nf_decode)
