"""Randomized Top-K sparsification (Zheng et al., IJCAI 2023) — baseline.

Per sample, the K highest-magnitude activation scalars are kept
deterministically; a further ``rand_frac * K`` slots are spent on uniform
random picks from the remainder (scaled by 1/p for unbiasedness) to preserve
representation diversity.  Everything else is zeroed.

The K budget is derived from the configured bit-width so methods are
comparable at equal wire cost: the paper's Table 2 counts Top-K at 16K/H
bits/scalar, so ``K = bits * H / 16``.

Static shapes throughout (required for jit): the random picks are realized
with a Gumbel-top-k over noise restricted to the non-top-k set.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.payload import CommPayload
from repro.core.quantizers import base
from repro.utils.tree import ste

_NEG = -1e30


def budget(cfg: base.QuantConfig, h: int) -> Tuple[int, int]:
    """(deterministic K, randomized K) for feature size ``h``."""
    k_total = max(1, int(round(cfg.bits * h / 16.0)))
    k_total = min(k_total, h)
    k_rand = int(round(k_total * cfg.rand_frac))
    k_det = max(1, k_total - k_rand)
    k_rand = min(k_rand, h - k_det)
    return k_det, k_rand


def _select(cfg: base.QuantConfig, x: jnp.ndarray,
            rng: Optional[jax.Array]):
    b = x.shape[0]
    flat = x.astype(jnp.float32).reshape(b, -1)
    h = flat.shape[1]
    k_det, k_rand = budget(cfg, h)
    mag = jnp.abs(flat)
    det_vals, det_idx = jax.lax.top_k(mag, k_det)
    det_mask = jnp.zeros_like(flat).at[
        jnp.arange(b)[:, None], det_idx].set(1.0)

    if k_rand > 0:
        if rng is None:
            rng = jax.random.PRNGKey(0)
        noise = jax.random.uniform(rng, flat.shape)
        noise = jnp.where(det_mask > 0, _NEG, noise)
        _, rnd_idx = jax.lax.top_k(noise, k_rand)  # uniform w/o replacement
        p = k_rand / max(1, h - k_det)
        rnd_scale = 1.0 / p
    else:
        rnd_idx = jnp.zeros((b, 0), jnp.int32)
        rnd_scale = 1.0
    idx = jnp.concatenate([det_idx, rnd_idx], axis=-1)
    gathered = jnp.take_along_axis(flat, idx, axis=-1)
    scale = jnp.concatenate(
        [jnp.ones((k_det,)), jnp.full((rnd_idx.shape[1],), rnd_scale)])
    vals = gathered * scale  # unbiased estimate
    return idx.astype(jnp.int32), vals, h


def _scatter(idx: jnp.ndarray, vals: jnp.ndarray, shape) -> jnp.ndarray:
    b = idx.shape[0]
    h = 1
    for s in shape[1:]:
        h *= s
    out = jnp.zeros((b, h), jnp.float32)
    out = out.at[jnp.arange(b)[:, None], idx].set(vals.astype(jnp.float32))
    return out.reshape(shape)


def encode(cfg: base.QuantConfig, x: jnp.ndarray,
           rng: Optional[jax.Array] = None) -> CommPayload:
    idx, vals, _ = _select(cfg, x, rng)
    return CommPayload(
        data=vals.astype(jnp.float16),
        aux=dict(indices=idx),
        meta=dict(method="topk", bits=cfg.bits, shape=tuple(x.shape),
                  dtype=str(x.dtype)),
    )


def decode(cfg: base.QuantConfig, payload: CommPayload) -> jnp.ndarray:
    shape = payload.meta["shape"]
    out = _scatter(payload.aux["indices"],
                   payload.data.astype(jnp.float32), shape)
    return out.astype(payload.meta.get("dtype", "float32"))


def roundtrip(cfg: base.QuantConfig, x: jnp.ndarray,
              rng: Optional[jax.Array] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    idx, vals, _ = _select(cfg, x, rng)
    vals16 = vals.astype(jnp.float16).astype(jnp.float32)
    x_hat = _scatter(idx, vals16, x.shape).astype(x.dtype)
    return ste(x, x_hat), jnp.zeros((), jnp.float32)


base.register("topk", encode, decode, roundtrip)
