"""FSQ — Finite Scalar Quantization (paper Algorithm 1, Mentzer et al. 2023).

tanh scaling + symmetric rounding; STE for gradients.  This is the baseline
the paper's RD-FSQ improves on (tanh saturation -> codebook under-use).

Note on Algorithm 1 line 11: the paper prints ``C = (I - (d-1)/2) / (d-1)``
which does not invert line 9 (it would halve the range).  Algorithm 2 line 9
uses ``/ ((d-1)/2)`` for the identical construction, so we use that
(reconstruction back onto [-1, 1]) for both — an acknowledged erratum.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.payload import CommPayload
from repro.core.quantizers import base
from repro.utils.tree import ste

_ATANH_CLIP = 1.0 - 1e-4


def _quantize(cfg: base.QuantConfig, x: jnp.ndarray):
    d = cfg.levels
    half = (d - 1) / 2.0
    e = jnp.tanh(x.astype(jnp.float32))
    z = base.symmetric_round(e, d)
    idx = (z + half).astype(jnp.uint8)  # I in {0, ..., d-1}
    return e, z, idx


def _reconstruct(cfg: base.QuantConfig, idx: jnp.ndarray) -> jnp.ndarray:
    d = cfg.levels
    half = (d - 1) / 2.0
    c = (idx.astype(jnp.float32) - half) / half  # back onto [-1, 1]
    # Fixed (non-learnable) inverse of the tanh encode; when a learnable
    # codec wraps the quantizer (Figure 2) the linear decoder refines this.
    return jnp.arctanh(jnp.clip(c, -_ATANH_CLIP, _ATANH_CLIP))


def encode(cfg: base.QuantConfig, x: jnp.ndarray,
           rng: Optional[jax.Array] = None) -> CommPayload:
    _, _, idx = _quantize(cfg, x)
    words = packing.pack_bits(idx, cfg.bits)
    return CommPayload(
        data=words,
        meta=dict(method="fsq", bits=cfg.bits, shape=tuple(x.shape),
                  dtype=str(x.dtype)),
    )


def decode(cfg: base.QuantConfig, payload: CommPayload) -> jnp.ndarray:
    shape = payload.meta["shape"]
    n = 1
    for s in shape:
        n *= s
    idx = packing.unpack_bits(payload.data, cfg.bits, n).reshape(shape)
    return _reconstruct(cfg, idx).astype(payload.meta.get("dtype", "float32"))


def roundtrip(cfg: base.QuantConfig, x: jnp.ndarray,
              rng: Optional[jax.Array] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    _, _, idx = _quantize(cfg, x)
    x_hat = _reconstruct(cfg, idx).astype(x.dtype)
    return ste(x, x_hat), jnp.zeros((), jnp.float32)


base.register("fsq", encode, decode, roundtrip)
