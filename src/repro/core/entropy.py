"""Entropy-grounded optimal bit-width selection (paper Section 3.3 + App. A).

Shannon's source coding theorem bounds the expected optimal code length by
H(X) <= E[S] < H(X) + 1 bits, so ceil(H) bits/scalar suffice to transmit the
boundary activations losslessly at the chosen quantization granularity.

H(X) is estimated with a Gaussian kernel density estimate using Scott's rule
bandwidth h = (4/3)^(1/5) * sigma * n^(-1/5), then numerically integrating
-p log2 p on a grid (the paper's Figure A1 procedure).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def scott_bandwidth(n: int, sigma: float) -> float:
    return (4.0 / 3.0) ** 0.2 * sigma * n ** (-0.2)


def kde_pdf(samples: jnp.ndarray, grid: jnp.ndarray,
            bandwidth: float) -> jnp.ndarray:
    """Gaussian KDE evaluated on ``grid``."""
    n = samples.shape[0]
    u = (grid[:, None] - samples[None, :]) / bandwidth
    phi = jnp.exp(-0.5 * u * u) / math.sqrt(2.0 * math.pi)
    return phi.mean(axis=1) / bandwidth


def differential_entropy_bits(samples: jnp.ndarray,
                              grid_points: int = 1024,
                              max_samples: int = 4096,
                              seed: int = 0) -> Tuple[float, dict]:
    """Estimate H(X) in bits via KDE + trapezoid integration.

    Returns (entropy_bits, diagnostics).  Matches the paper's Appendix-A
    protocol: Scott's-rule bandwidth, Gaussian kernel, grid integration of
    -p(x) log2 p(x).
    """
    flat = jnp.asarray(samples, jnp.float32).reshape(-1)
    n_total = flat.shape[0]
    if n_total > max_samples:
        idx = jax.random.choice(jax.random.PRNGKey(seed), n_total,
                                (max_samples,), replace=False)
        flat = flat[idx]
    n = flat.shape[0]
    sigma = float(jnp.std(flat)) + 1e-12
    h = scott_bandwidth(n, sigma)
    lo = float(jnp.min(flat)) - 4.0 * h
    hi = float(jnp.max(flat)) + 4.0 * h
    grid = jnp.linspace(lo, hi, grid_points)
    p = kde_pdf(flat, grid, h)
    p = jnp.maximum(p, 1e-30)
    integrand = -p * jnp.log2(p)
    ent = float(jnp.trapezoid(integrand, grid))
    return ent, dict(bandwidth=h, sigma=sigma, n=n, grid=(lo, hi))


def optimal_bits(entropy_bits: float) -> int:
    """ceil(H) per the source-coding bound; at least 1 bit."""
    return max(1, int(np.ceil(entropy_bits)))


def estimate_optimal_bits(samples: jnp.ndarray, **kw) -> Tuple[int, float]:
    ent, _ = differential_entropy_bits(samples, **kw)
    return optimal_bits(ent), ent
