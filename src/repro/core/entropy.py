"""Entropy-grounded optimal bit-width selection (paper Section 3.3 + App. A).

Shannon's source coding theorem bounds the expected optimal code length by
H(X) <= E[S] < H(X) + 1 bits, so ceil(H) bits/scalar suffice to transmit the
boundary activations losslessly at the chosen quantization granularity.

H(X) is estimated with a Gaussian kernel density estimate using Scott's rule
bandwidth h = (4/3)^(1/5) * sigma * n^(-1/5), then numerically integrating
-p log2 p on a grid (the paper's Figure A1 procedure).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def scott_bandwidth(n: int, sigma: float) -> float:
    return (4.0 / 3.0) ** 0.2 * sigma * n ** (-0.2)


def kde_pdf(samples: jnp.ndarray, grid: jnp.ndarray,
            bandwidth: float) -> jnp.ndarray:
    """Gaussian KDE evaluated on ``grid``."""
    n = samples.shape[0]
    u = (grid[:, None] - samples[None, :]) / bandwidth
    phi = jnp.exp(-0.5 * u * u) / math.sqrt(2.0 * math.pi)
    return phi.mean(axis=1) / bandwidth


def differential_entropy_bits(samples: jnp.ndarray,
                              grid_points: int = 1024,
                              max_samples: int = 4096,
                              seed: int = 0) -> Tuple[float, dict]:
    """Estimate H(X) in bits via KDE + trapezoid integration.

    Returns (entropy_bits, diagnostics).  Matches the paper's Appendix-A
    protocol: Scott's-rule bandwidth, Gaussian kernel, grid integration of
    -p(x) log2 p(x).
    """
    flat = jnp.asarray(samples, jnp.float32).reshape(-1)
    n_total = flat.shape[0]
    if n_total > max_samples:
        idx = jax.random.choice(jax.random.PRNGKey(seed), n_total,
                                (max_samples,), replace=False)
        flat = flat[idx]
    n = flat.shape[0]
    sigma = float(jnp.std(flat)) + 1e-12
    h = scott_bandwidth(n, sigma)
    lo = float(jnp.min(flat)) - 4.0 * h
    hi = float(jnp.max(flat)) + 4.0 * h
    grid = jnp.linspace(lo, hi, grid_points)
    p = kde_pdf(flat, grid, h)
    p = jnp.maximum(p, 1e-30)
    integrand = -p * jnp.log2(p)
    ent = float(jnp.trapezoid(integrand, grid))
    return ent, dict(bandwidth=h, sigma=sigma, n=n, grid=(lo, hi))


def optimal_bits(entropy_bits: float) -> int:
    """ceil(H) per the source-coding bound; at least 1 bit."""
    return max(1, int(np.ceil(entropy_bits)))


def discretized_entropy_bits(samples: jnp.ndarray, delta: float,
                             **kw) -> Tuple[float, dict]:
    """Entropy of X quantized at bin width ``delta``: H_disc ~ h(X) - log2 d.

    The standard fine-quantization limit (Cover & Thomas Thm 8.3.1):
    the discrete entropy of ``round(X / delta)`` approaches
    ``h(X) - log2(delta)`` as ``delta -> 0``.  Unlike raw differential
    entropy this is a real (discrete) entropy, and it is invariant under
    a joint rescaling of the data and the bin.

    ``delta`` is clamped away from 0: a degenerate (constant) sample set
    yields a 0-width quantizer grid, where the estimate is meaningless
    but must not raise mid-measurement.
    """
    ent, diag = differential_entropy_bits(samples, **kw)
    return ent - math.log2(max(delta, 1e-30)), diag


def estimate_optimal_bits(samples: jnp.ndarray,
                          delta: Optional[float] = None,
                          **kw) -> Tuple[int, float]:
    """Scale-invariant optimal bit width via the source-coding bound.

    Differential entropy obeys h(aX) = h(X) + log2|a|, so ceiling the
    *raw* KDE estimate (the paper's Appendix-A protocol, reproduced in
    :func:`differential_entropy_bits`) recommends a different bit width
    whenever the client merely rescales its activations — a bug, since
    every quantizer here (RD-FSQ/FSQ/NF) normalizes by the observed data
    range before rounding, making the wire content scale-free.

    Fix: discretize at the quantizer's bin width.  ``delta`` defaults to
    the sample standard deviation — the data-derived unit every
    normalizing quantizer's grid is proportional to — giving
    ``H_disc = h(X) - log2(sigma) = h(X / sigma)``: rescaling shifts
    ``h`` and ``log2(delta)`` by the same amount and H_disc (hence the
    recommended width) is unchanged.  Compactly supported activation
    distributions land in the paper's Table-1 regime at every scale
    (uniform: log2(sqrt(12)) ~ 1.79 bits -> 2-bit optimal); a Gaussian
    is ~2.05 -> 3.  Pass an explicit ``delta`` (e.g. the RD-FSQ grid
    pitch ``(hi - lo) / (2**b - 1)``) to evaluate a specific quantizer
    grid.
    """
    ent, diag = differential_entropy_bits(samples, **kw)
    if delta is None:
        delta = float(diag["sigma"])
    h_disc = ent - math.log2(max(delta, 1e-30))
    return optimal_bits(h_disc), h_disc
