"""Entropy-grounded optimal bit-width selection (paper Section 3.3 + App. A).

Shannon's source coding theorem bounds the expected optimal code length by
H(X) <= E[S] < H(X) + 1 bits, so ceil(H) bits/scalar suffice to transmit the
boundary activations losslessly at the chosen quantization granularity.

H(X) is estimated with a Gaussian kernel density estimate using Scott's rule
bandwidth h = (4/3)^(1/5) * sigma * n^(-1/5), then numerically integrating
-p log2 p on a grid (the paper's Figure A1 procedure).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def scott_bandwidth(n: int, sigma: float) -> float:
    return (4.0 / 3.0) ** 0.2 * sigma * n ** (-0.2)


def kde_pdf(samples: jnp.ndarray, grid: jnp.ndarray,
            bandwidth: float) -> jnp.ndarray:
    """Gaussian KDE evaluated on ``grid``."""
    n = samples.shape[0]
    u = (grid[:, None] - samples[None, :]) / bandwidth
    phi = jnp.exp(-0.5 * u * u) / math.sqrt(2.0 * math.pi)
    return phi.mean(axis=1) / bandwidth


def differential_entropy_bits(samples: jnp.ndarray,
                              grid_points: int = 1024,
                              max_samples: int = 4096,
                              seed: int = 0) -> Tuple[float, dict]:
    """Estimate H(X) in bits via KDE + trapezoid integration.

    Returns (entropy_bits, diagnostics).  Matches the paper's Appendix-A
    protocol: Scott's-rule bandwidth, Gaussian kernel, grid integration of
    -p(x) log2 p(x).
    """
    flat = jnp.asarray(samples, jnp.float32).reshape(-1)
    n_total = flat.shape[0]
    if n_total > max_samples:
        idx = jax.random.choice(jax.random.PRNGKey(seed), n_total,
                                (max_samples,), replace=False)
        flat = flat[idx]
    n = flat.shape[0]
    sigma = float(jnp.std(flat)) + 1e-12
    h = scott_bandwidth(n, sigma)
    lo = float(jnp.min(flat)) - 4.0 * h
    hi = float(jnp.max(flat)) + 4.0 * h
    grid = jnp.linspace(lo, hi, grid_points)
    p = kde_pdf(flat, grid, h)
    p = jnp.maximum(p, 1e-30)
    integrand = -p * jnp.log2(p)
    ent = float(jnp.trapezoid(integrand, grid))
    return ent, dict(bandwidth=h, sigma=sigma, n=n, grid=(lo, hi))


#: Widest code the wire stack can carry: the bitstream packers, the
#: quantizer grids (2^b levels in a uint8 index) and the Pallas codecs
#: all top out at 8 bits — past that the payload would have to widen its
#: index dtype, at which point shipping raw bf16 is cheaper anyway.
MAX_WIRE_BITS = 8


def optimal_bits(entropy_bits: float) -> int:
    """ceil(H) per the source-coding bound, clamped to [1, 8].

    The upper clamp is a contract with the wire stack: a heavy-tailed or
    wide-range sample can push the KDE estimate past 8 bits, but no
    packer or quantizer supports codes wider than ``MAX_WIRE_BITS`` —
    an unclamped recommendation would crash the codec it feeds.
    """
    return min(MAX_WIRE_BITS, max(1, int(np.ceil(entropy_bits))))


def discretized_entropy_bits(samples: jnp.ndarray, delta: float,
                             **kw) -> Tuple[float, dict]:
    """Entropy of X quantized at bin width ``delta``: H_disc ~ h(X) - log2 d.

    The standard fine-quantization limit (Cover & Thomas Thm 8.3.1):
    the discrete entropy of ``round(X / delta)`` approaches
    ``h(X) - log2(delta)`` as ``delta -> 0``.  Unlike raw differential
    entropy this is a real (discrete) entropy, and it is invariant under
    a joint rescaling of the data and the bin.

    ``delta`` is clamped away from 0: a degenerate (constant) sample set
    yields a 0-width quantizer grid, where the estimate is meaningless
    but must not raise mid-measurement.
    """
    ent, diag = differential_entropy_bits(samples, **kw)
    return ent - math.log2(max(delta, 1e-30)), diag


def estimate_optimal_bits(samples: jnp.ndarray,
                          delta: Optional[float] = None,
                          **kw) -> Tuple[int, float]:
    """Scale-invariant optimal bit width via the source-coding bound.

    Differential entropy obeys h(aX) = h(X) + log2|a|, so ceiling the
    *raw* KDE estimate (the paper's Appendix-A protocol, reproduced in
    :func:`differential_entropy_bits`) recommends a different bit width
    whenever the client merely rescales its activations — a bug, since
    every quantizer here (RD-FSQ/FSQ/NF) normalizes by the observed data
    range before rounding, making the wire content scale-free.

    Fix: discretize at the quantizer's bin width.  ``delta`` defaults to
    the sample standard deviation — the data-derived unit every
    normalizing quantizer's grid is proportional to — giving
    ``H_disc = h(X) - log2(sigma) = h(X / sigma)``: rescaling shifts
    ``h`` and ``log2(delta)`` by the same amount and H_disc (hence the
    recommended width) is unchanged.  Compactly supported activation
    distributions land in the paper's Table-1 regime at every scale
    (uniform: log2(sqrt(12)) ~ 1.79 bits -> 2-bit optimal); a Gaussian
    is ~2.05 -> 3.  Pass an explicit ``delta`` (e.g. the RD-FSQ grid
    pitch ``(hi - lo) / (2**b - 1)``) to evaluate a specific quantizer
    grid.
    """
    ent, diag = differential_entropy_bits(samples, **kw)
    if delta is None:
        delta = float(diag["sigma"])
    h_disc = ent - math.log2(max(delta, 1e-30))
    return optimal_bits(h_disc), h_disc


# ---------------------------------------------------------------------------
# streaming per-channel entropy (the adaptive wire's online signal)
# ---------------------------------------------------------------------------
#
# The KDE protocol above is an offline, per-tensor measurement (paper
# Appendix A).  The adaptive wire needs the *per-channel* discretized
# entropy, updated every training step, cheap enough to run next to the
# codec: an EMA histogram per channel.  Samples are centered per channel
# and binned in units of a shared reference scale sigma_ref (the EMA
# tensor-level std), so the bin width is delta_bin = sigma_ref * SPAN /
# n_bins and the readout at the codec-comparable bin width delta =
# sigma_ref is
#
#     H_disc(delta = sigma_ref) ~= H(histogram) + log2(SPAN / n_bins)
#
# (the standard fine-quantization shift between two bin widths).  Like
# `estimate_optimal_bits`, the estimate is invariant under a joint
# rescaling of the tensor: sigma_ref absorbs the scale.  Channels whose
# distributions are wide or multimodal RELATIVE to the tensor's scale
# read high; near-constant channels read low (floored at 0) — exactly
# the allocation signal feature-wise compression wants.

_EMA_SPAN = 16.0  # histogram support: +-8 sigma_ref around the channel mean


def init_entropy_ema(n_channels: int, n_bins: int = 64) -> dict:
    """Fresh per-channel EMA-histogram state (cold: count == 0 adopts the
    first batch outright, mirroring ``split.update_wire_calib``)."""
    return dict(
        hist=jnp.zeros((n_channels, n_bins), jnp.float32),
        sigma=jnp.zeros((), jnp.float32),
        count=jnp.zeros((), jnp.float32),
    )


def update_entropy_ema(state: dict, x: jnp.ndarray,
                       decay: float = 0.9) -> dict:
    """EMA-update the per-channel histograms with one activation batch.

    ``x`` is (..., C); all leading axes are sample axes.  Pure jnp and
    shape-static, so it jits (and can ride inside a compiled train step
    or run host-side between steps).
    """
    n_bins = state["hist"].shape[1]
    xf = x.astype(jnp.float32).reshape(-1, x.shape[-1])
    sigma_b = jnp.std(xf) + 1e-12
    sigma = jnp.where(state["count"] > 0.0,
                      decay * state["sigma"] + (1.0 - decay) * sigma_b,
                      sigma_b)
    mu_c = jnp.mean(xf, axis=0, keepdims=True)
    z = (xf - mu_c) / sigma  # channel-centered, tensor-scaled
    idx = jnp.clip(jnp.floor((z + _EMA_SPAN / 2.0)
                             * (n_bins / _EMA_SPAN)),
                   0, n_bins - 1).astype(jnp.int32)
    one_hot = jax.nn.one_hot(idx, n_bins, dtype=jnp.float32)
    p_b = jnp.mean(one_hot, axis=0)  # (C, n_bins)
    hist = jnp.where(state["count"] > 0.0,
                     decay * state["hist"] + (1.0 - decay) * p_b,
                     p_b)
    return dict(hist=hist, sigma=sigma, count=state["count"] + 1.0)


def entropy_ema_bits(state: dict) -> jnp.ndarray:
    """(C,) per-channel discretized entropy at bin width sigma_ref.

    Floored at 0 (a discrete entropy cannot be negative; the bin-width
    shift can push degenerate channels below it).
    """
    p = state["hist"]
    n_bins = p.shape[1]
    h_hist = -jnp.sum(jnp.where(p > 0.0, p * jnp.log2(jnp.maximum(p, 1e-30)),
                                0.0), axis=1)
    shift = math.log2(_EMA_SPAN / n_bins)
    return jnp.maximum(h_hist + shift, 0.0)


# ---------------------------------------------------------------------------
# greedy water-filling bit allocation under a wire-byte budget
# ---------------------------------------------------------------------------

def allocate_bits(entropies, budget_bytes: float, *,
                  group_size: int, scalars_per_channel: int,
                  min_bits: int = 1, max_bits: int = MAX_WIRE_BITS
                  ) -> Tuple[int, ...]:
    """Per-group code widths under a total payload-byte budget.

    ``entropies`` is the (C,) per-channel discretized-entropy signal
    (:func:`entropy_ema_bits` or offline :func:`discretized_entropy_bits`
    per channel); channels group contiguously into ``C / group_size``
    groups (the same geometry ``QuantConfig.group_widths`` quantizes).
    ``scalars_per_channel`` converts widths to wire bytes: one shipped
    activation carries ``scalars_per_channel`` values of every channel
    (e.g. ``B * S`` for a (B, S, C) boundary slab), so group g at width
    w costs ``group_size * scalars_per_channel * w / 8`` payload bytes —
    exact at every width, thanks to the bitstream packers.

    Greedy water-filling (the mixed-precision tuning-ladder shape from
    the neural-compressor exemplars): start every group at ``min_bits``,
    then repeatedly grant +1 bit to the group with the largest remaining
    source-coding deficit ``H_g - w_g`` while the budget allows.  Ties
    break toward the lowest group index (deterministic plans — the jit
    caches key on them).  Raises if even the all-``min_bits`` floor
    exceeds the budget.
    """
    ent = np.asarray(entropies, np.float64).reshape(-1)
    if ent.size % group_size != 0:
        raise ValueError(
            f"{ent.size} channels do not divide into groups of {group_size}")
    h_group = ent.reshape(-1, group_size).mean(axis=1)
    n_groups = h_group.shape[0]
    bytes_per_bit = group_size * scalars_per_channel / 8.0
    widths = np.full(n_groups, min_bits, np.int64)
    spent = n_groups * min_bits * bytes_per_bit
    if spent > budget_bytes:
        raise ValueError(
            f"budget {budget_bytes}B cannot cover the {min_bits}-bit floor "
            f"({spent}B for {n_groups} groups)")
    while spent + bytes_per_bit <= budget_bytes:
        deficit = h_group - widths
        deficit[widths >= max_bits] = -np.inf
        g = int(np.argmax(deficit))
        if not np.isfinite(deficit[g]) or deficit[g] <= 0.0:
            break  # every group already meets its source-coding bound
        widths[g] += 1
        spent += bytes_per_bit
    return tuple(int(w) for w in widths)


def channel_order(entropies) -> Tuple[int, ...]:
    """Entropy-ascending channel permutation (``QuantConfig.channel_perm``).

    Contiguous grouping averages the per-channel entropy spread away:
    a 1.7-bit channel-level spread collapses to ~0.3 bits between
    averaged groups, and water-filling then has nothing to differentiate
    on.  Sorting first makes each group entropy-homogeneous, so the
    group means span the full channel range and the allocator's grants
    (and starvations) land on channels that genuinely deserve them.
    Deterministic: ties break by channel index (stable argsort).
    """
    ent = np.asarray(entropies, np.float64).reshape(-1)
    return tuple(int(i) for i in np.argsort(ent, kind="stable"))


def plan_grouped(entropies, budget_bytes: float, *,
                 group_size: int, scalars_per_channel: int,
                 min_bits: int = 1, max_bits: int = MAX_WIRE_BITS
                 ) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Sorted-grouping allocation: returns ``(channel_perm, group_widths)``.

    The permutation orders channels by ascending entropy; the widths are
    :func:`allocate_bits` run on the SORTED signal, so width g applies to
    the g-th entropy-ranked channel set once the codec gathers with the
    permutation.  Drop both onto a ``QuantConfig`` to get the wire this
    plan describes.
    """
    perm = channel_order(entropies)
    ent_sorted = np.asarray(entropies, np.float64).reshape(-1)[list(perm)]
    widths = allocate_bits(ent_sorted, budget_bytes, group_size=group_size,
                           scalars_per_channel=scalars_per_channel,
                           min_bits=min_bits, max_bits=max_bits)
    return perm, widths
