"""CommPayload — what actually crosses the client/server wire.

A registered pytree whose array leaves are exactly the tensors transmitted
between split-learning partitions.  ``wire_bytes`` is the ground truth for
every communication-cost number in EXPERIMENTS.md (paper Table 4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CommPayload:
    """Quantized activation payload.

    Attributes
    ----------
    data:
        The main payload.  For FSQ/RD-FSQ/NF-b this is the *bit-packed*
        uint8 code words; for Top-K it is the kept values (fp16); for the
        identity (original-model) path it is the raw bf16 activations.
    scales:
        Per-block / per-sample scale information (fp16 or uint8 when double
        quantized).  None when the method needs none.
    aux:
        Everything else on the wire (block minima, double-quant group scales,
        top-k indices, ...), keyed by name.
    meta:
        Static metadata (shape, bits, method) — NOT transmitted as a tensor;
        in a real deployment it is part of the session handshake.
    """

    data: jnp.ndarray
    scales: Optional[jnp.ndarray] = None
    aux: Dict[str, jnp.ndarray] = dataclasses.field(default_factory=dict)
    meta: Dict[str, Any] = dataclasses.field(
        default_factory=dict, metadata=dict(static=True)
    )

    def wire_bytes(self) -> int:
        """Total bytes on the wire for this payload.

        Computed from shape/dtype (not ``.size``) so it also works on a
        ``jax.eval_shape`` result — payload shapes are static, which is
        what makes the split pipeline's per-tick wire bytes a
        compile-time constant.
        """
        def nbytes(a) -> int:
            n = 1
            for s in a.shape:
                n *= s
            return n * jnp.dtype(a.dtype).itemsize

        total = nbytes(self.data)
        if self.scales is not None:
            total += nbytes(self.scales)
        for v in self.aux.values():
            total += nbytes(v)
        return int(total)

    def arrays(self) -> Tuple[jnp.ndarray, ...]:
        out = [self.data]
        if self.scales is not None:
            out.append(self.scales)
        out.extend(self.aux.values())
        return tuple(out)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GroupedPayload:
    """Mixed-precision wire form: one sub-payload per channel group.

    The adaptive wire (ROADMAP item 3) splits the boundary activation's
    channel axis into contiguous groups and quantizes each at its own bit
    width (``QuantConfig.group_widths``).  What crosses the link is the
    concatenation of the groups' ``CommPayload``s — each with its own
    packed codes and scale side-info, each exactly
    ``ceil(n_group * width / 8)`` data bytes thanks to the exact
    bitstream packers.  ``meta`` (static, session-handshake) records the
    group geometry so the server can reassemble the channel axis.

    Like :class:`CommPayload`, ``wire_bytes`` is computed from static
    shapes only, so a grouped wire's byte cost stays a compile-time
    constant (what the HLO collective-permute assertions check).

    Double-quantized scales (``QuantConfig.scale_dq``): the per-group
    fp16 scale side-info is itself quantized to 8-bit codes against one
    shared affine range; ``scale_meta`` is that range — a (2,) fp16
    (lo, hi) pair per payload, counted on the wire like everything else.
    ``None`` when the plan ships fp16 scales directly.
    """

    groups: Tuple[CommPayload, ...]
    scale_meta: Optional[jnp.ndarray] = None
    meta: Dict[str, Any] = dataclasses.field(
        default_factory=dict, metadata=dict(static=True)
    )

    def wire_bytes(self) -> int:
        """Total bytes on the wire: the sum over group payloads, plus the
        double-quant scale range when present."""
        total = sum(g.wire_bytes() for g in self.groups)
        if self.scale_meta is not None:
            n = 1
            for s in self.scale_meta.shape:
                n *= s
            total += n * jnp.dtype(self.scale_meta.dtype).itemsize
        return int(total)

    def arrays(self) -> Tuple[jnp.ndarray, ...]:
        out: Tuple[jnp.ndarray, ...] = ()
        for g in self.groups:
            out += g.arrays()
        if self.scale_meta is not None:
            out += (self.scale_meta,)
        return out

    @property
    def widths(self) -> Tuple[int, ...]:
        return tuple(self.meta.get("widths", ()))


def bits_per_scalar(payload, n_scalars: int) -> float:
    """Average transmitted bits per original activation scalar (Table 2).

    Exact for every payload: packing is a true bitstream at all widths
    1-8 (odd widths no longer pay a power-of-two slot), so this is
    ``bits + side-info`` rather than ``storage-slot + side-info``.
    Accepts :class:`CommPayload` and :class:`GroupedPayload` alike.
    """
    return payload.wire_bytes() * 8.0 / float(n_scalars)
