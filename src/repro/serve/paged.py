"""Device-side pieces of the paged serving engine.

Two jitted entry points, both donating the KV pools so the engine's
resident cache memory is updated in place every call instead of being
copied:

* ``compiled_paged_step`` — one decode tick over the slot batch
  (``transformer.decode_step_paged``), cached per (cfg, window, attention
  backend) exactly like ``serve/decode._compiled_serve_step``.  The
  cache-length BUCKET (the padded page-table width ``npp``) is a runtime
  shape, so jit's own shape cache keys the per-bucket executables under
  the lru entry; the engine quantizes ``npp`` (and the slot/prefill
  batch shapes) to powers of two so that shape cache stays bounded.
* ``insert_prefill`` — scatter a freshly prefilled contiguous ring cache
  (``serve/decode.prefill`` with ``cache_len = npb * page_size``) into
  the paged pools at each request's physical pages.  Cache positions at
  or beyond a row's valid length are forced to -1 (right-padding and the
  not-yet-decoded tail must never be attended), and logical pages beyond
  a row's allocation are routed to the reserved trash page 0.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import attention_ops
from repro.models import transformer as tf

__all__ = ["next_pow2", "init_pools", "make_paged_step",
           "compiled_paged_step", "insert_prefill"]


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (bucket quantizer for compile shapes)."""
    return 1 << max(0, (int(n) - 1).bit_length())


def init_pools(cfg: ArchConfig, n_pages: int, page_size: int) -> Dict:
    """Paged KV pools in the serve compute dtype (same dtype the prefill
    ring caches are collected in, so ``insert_prefill`` is a pure move)."""
    return tf.init_paged_caches(cfg, n_pages, page_size,
                                dtype=tf.cdtype(cfg))


def make_paged_step(cfg: ArchConfig, *,
                    window: Optional[int] = None) -> Callable:
    def paged_step(params, pools, batch: Dict, qpos: jnp.ndarray,
                   page_table: jnp.ndarray):
        return tf.decode_step_paged(params, cfg, pools, batch, qpos,
                                    page_table, window=window)

    return paged_step


@functools.lru_cache(maxsize=32)
def _compiled_paged_step(cfg: ArchConfig, window: Optional[int],
                         attn_impl: str) -> Callable:
    """``pools`` is DONATED — rebind it from the step's return value."""
    del attn_impl  # cache key only; the traced code reads the env var
    return jax.jit(make_paged_step(cfg, window=window), donate_argnums=(1,))


def compiled_paged_step(cfg: ArchConfig, *, window: Optional[int] = None,
                        impl: Optional[str] = None) -> Callable:
    return _compiled_paged_step(cfg, window,
                                attention_ops.resolve_impl(impl))


def _insert_prefill_impl(pools: Dict, caches: Dict,
                         page_rows: jnp.ndarray,
                         valid_len: jnp.ndarray) -> Dict:
    b, npb = page_rows.shape

    def insert_seg(pool_seg: Dict, cache_seg: Dict) -> Dict:
        lb = cache_seg["pos"].shape[2]
        pg = pool_seg["pos"].shape[2]
        assert lb == npb * pg, (lb, npb, pg)
        valid = jnp.arange(lb)[None, :] < valid_len[:, None]  # (B, Lb)
        out = {}
        for key, pool_leaf in pool_seg.items():
            val = cache_seg[key]  # (n, B, Lb, ...)
            if key == "pos":
                val = jnp.where(valid[None], val, -1)
            n = val.shape[0]
            val = val.reshape((n, b, npb, pg) + val.shape[3:])
            # (S, npb) fancy index on the page axis: pool[:, page_rows]
            # is (n, B, npb, pg, ...) — one scatter per leaf moves the
            # whole prefill into place.  Overlapping trash-page writes
            # (page 0) carry pos = -1, so their race is unobservable.
            out[key] = pool_leaf.at[:, page_rows].set(val)
        return out

    return {side: {seg: insert_seg(pools[side][seg], caches[side][seg])
                   for seg in pools[side]}
            for side in pools}


# pools donated: the insert is an in-place scatter into the resident
# pool buffers, not a copy of the whole pool per admission.
insert_prefill = jax.jit(_insert_prefill_impl, donate_argnums=(0,))
