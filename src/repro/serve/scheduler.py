"""Slot-based continuous-batching scheduler.

Requests wait in an arrival queue; a request is admitted when (a) a
decode slot is free and (b) the page pool can reserve EVERY page the
request can ever need (prompt + max_new tokens, rounded up to whole
pages).  Retirement (EOS or max-token) frees the slot and its pages
immediately, so waiting requests fill the hole on the next tick —
admission and retirement never stall the other slots' decodes.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional

from repro.serve.pool import PagePool

__all__ = ["Request", "SlotScheduler"]

WAITING, ACTIVE, DONE = "waiting", "active", "done"


@dataclasses.dataclass
class Request:
    """One generation request plus its in-flight state."""

    rid: int
    tokens: List[int]                      # prompt token ids
    max_new: int
    image_embeds: Optional[Any] = None     # (n_img, d_vision) for VLM cfgs
    arrival_time: float = 0.0

    # runtime state (owned by the scheduler/engine)
    state: str = WAITING
    slot: int = -1
    pages: List[int] = dataclasses.field(default_factory=list)
    out: List[int] = dataclasses.field(default_factory=list)
    qpos: int = 0             # position of the NEXT token to decode
    finish_reason: str = ""
    # per-token wall-clock emission times (benchmark latency accounting)
    emit_times: List[float] = dataclasses.field(default_factory=list)
    prefill_time: float = 0.0

    def prompt_len(self, n_image_tokens: int = 0) -> int:
        n_img = n_image_tokens if self.image_embeds is not None else 0
        return len(self.tokens) + n_img

    def target_len(self, n_image_tokens: int = 0) -> int:
        """Max positions this request can ever occupy."""
        return self.prompt_len(n_image_tokens) + self.max_new


class SlotScheduler:
    """Admission + retirement over ``n_slots`` decode slots."""

    def __init__(self, n_slots: int, pool: PagePool, page_size: int, *,
                 n_image_tokens: int = 0):
        self.n_slots = n_slots
        self.pool = pool
        self.page_size = page_size
        self.n_image_tokens = n_image_tokens
        self.waiting: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.requests: Dict[int, Request] = {}

    # -- queue ----------------------------------------------------------
    def submit(self, req: Request) -> int:
        if req.rid in self.requests:
            raise ValueError(f"duplicate rid {req.rid}")
        self.requests[req.rid] = req
        self.waiting.append(req)
        return req.rid

    @property
    def active(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    @property
    def idle(self) -> bool:
        return not self.waiting and all(s is None for s in self.slots)

    def pages_needed(self, req: Request) -> int:
        t = req.target_len(self.n_image_tokens)
        return -(-t // self.page_size)  # ceil

    # -- admission ------------------------------------------------------
    def admit(self) -> List[Request]:
        """Admit waiting requests into free slots while pages last.

        FIFO head-of-line: if the oldest waiting request cannot reserve
        its pages we stop (no starvation of big requests by later small
        ones).  Returns the newly admitted requests — the engine prefills
        them as one batch, separately from the decode tick.
        """
        admitted: List[Request] = []
        free_slots = [i for i, s in enumerate(self.slots) if s is None]
        while self.waiting and free_slots:
            req = self.waiting[0]
            if not self.pool.can_alloc(self.pages_needed(req)):
                break
            self.waiting.popleft()
            req.pages = self.pool.alloc(self.pages_needed(req), req.rid)
            req.slot = free_slots.pop(0)
            req.state = ACTIVE
            req.qpos = req.prompt_len(self.n_image_tokens)
            self.slots[req.slot] = req
            admitted.append(req)
        return admitted

    # -- retirement -----------------------------------------------------
    def retire(self, req: Request, reason: str) -> None:
        """Free the request's slot and pages immediately."""
        assert req.state == ACTIVE and self.slots[req.slot] is req
        self.slots[req.slot] = None
        self.pool.free_owner(req.rid)
        req.pages = []
        req.slot = -1
        req.state = DONE
        req.finish_reason = reason
