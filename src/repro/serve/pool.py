"""Host-side physical page allocator for the paged KV pool.

The device side (``models/layers/attention.init_paged_kv_pool``) is a flat
(P, pg, ...) buffer per layer; this allocator owns which physical pages
are live and who owns them.  Physical page 0 is RESERVED as the trash
page: inactive-slot writes are routed there and its ``pos`` stays -1, so
it must never be handed to a request.

Allocation is reservation-at-admission: the scheduler asks for every page
a request can ever need (prompt + max_new) before admitting it, so a live
request can never run out of pages mid-flight (no preemption / swapping —
the vLLM failure mode this sidesteps at small scale).
"""
from __future__ import annotations

from typing import Dict, List

__all__ = ["PagePool"]


class PagePool:
    """Free-list allocator over physical pages 1..n_pages-1."""

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self.n_pages = n_pages
        # LIFO free list: retired pages are reused first, which keeps the
        # working set of touched pages small under churn.
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._owner: Dict[int, int] = {}  # physical page -> request id

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return len(self._owner)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int, owner: int) -> List[int]:
        """Hand ``n`` pages to ``owner``; raises if the pool is short."""
        if n < 0:
            raise ValueError(f"negative page count {n}")
        if not self.can_alloc(n):
            raise RuntimeError(
                f"page pool exhausted: want {n}, have {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._owner[p] = owner
        return pages

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if p not in self._owner:
                raise RuntimeError(f"double free / foreign page {p}")
            del self._owner[p]
            self._free.append(p)

    def free_owner(self, owner: int) -> int:
        """Free every page owned by ``owner``; returns the count."""
        pages = [p for p, o in self._owner.items() if o == owner]
        self.free(pages)
        return len(pages)

    def owners(self) -> Dict[int, int]:
        """Snapshot of page -> owner (for invariant checks)."""
        return dict(self._owner)

    def check_invariants(self) -> None:
        """No page both free and live; page 0 never tracked; conservation."""
        free = set(self._free)
        live = set(self._owner)
        assert 0 not in free and 0 not in live, "trash page leaked"
        assert not (free & live), f"aliased pages {free & live}"
        assert len(free) == len(self._free), "duplicate in free list"
        assert free | live == set(range(1, self.n_pages)), "pages lost"
