"""Continuous-batching serving engine over the paged KV pool.

vLLM-style serving loop for the split-deployment server half
(ROADMAP item 1): requests arrive at any time, are admitted into decode
slots as soon as a slot AND their full page reservation are available,
and retire the moment they hit EOS or their token budget — their pages
return to the pool immediately, so a long request never stalls short
ones and short ones never pay the longest request's latency.

One engine ``step()`` is: retire -> admit (+ batched prefill of the
admissions) -> one decode tick over every active slot.  Prefill runs as
its own batched forward (``serve/decode.prefill`` on a bucketed shape),
so admission never recompiles or stalls the in-flight decode step; the
prefilled ring caches are scattered into the paged pools by
``paged.insert_prefill`` (pools donated, in-place).

Split-serve mode (``split_wire=QuantConfig(...)``): the client is assumed
to hold the vision tower + connector; the engine runs the connector
client-side, ships the connector activations through the existing wire
codec (``core/quantizers`` encode -> decode, the PR-3/6 machinery), feeds
the reconstruction to the server prefill via the ``image_features``
bypass, and accounts the payload bytes in ``stats['wire_bytes']`` —
matching ``WireLink.fwd_wire_bytes`` static accounting.  A grouped
``split_wire`` (non-empty ``group_widths``) ships the connector
activations as a mixed-precision ``GroupedPayload``;
``split_wire_budget_bits`` additionally re-plans the widths between
prefills from a per-channel entropy EMA of the connector features.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import quantizers
from repro.core.quantizers import QuantConfig
from repro.models import transformer as tf
from repro.models.layers.mlp import mlp_forward
from repro.serve import decode as sd
from repro.serve import paged
from repro.serve.pool import PagePool
from repro.serve.scheduler import Request, SlotScheduler

__all__ = ["ServeEngine"]


class ServeEngine:
    """Slot-based continuous-batching engine (single host, one model)."""

    def __init__(self, params, cfg: ArchConfig, *, n_slots: int,
                 page_size: int, n_pages: int,
                 window: Optional[int] = None, temperature: float = 0.0,
                 eos_id: Optional[int] = None, seed: int = 0,
                 split_wire: Optional[QuantConfig] = None,
                 split_wire_budget_bits: Optional[float] = None,
                 split_plan_groups: int = 8,
                 impl: Optional[str] = None,
                 lora_adapters=None, lora_scale: float = 1.0,
                 weight_quant: Optional[str] = None, wq_group: int = 128,
                 wq_act_order: bool = False,
                 wq_calib: Optional[Dict] = None):
        if cfg.modality == "audio":
            raise NotImplementedError("engine serves text/vlm configs")
        if lora_adapters is not None:
            # SplitLoRA serving: fold the adapters into the base weights
            # ONCE at construction (merge == apply bit-exactly, so merged
            # decoding is token-exact vs the unmerged forward) — steady
            # state serving pays zero adapter overhead per token.
            from repro.peft import merge_lora
            params = merge_lora(params, lora_adapters, scale=lora_scale)
        self.wq_report = None
        if weight_quant is not None:
            # Weight-only serving quantization (ROADMAP item 5): replace
            # every structural w* matmul site in the stacks with a packed
            # int4/int3 store AFTER the LoRA merge (the adapters must fold
            # into the dense weights before they are frozen into codes).
            # With a calibration batch the quantizer runs GPTQ error
            # compensation off per-site Hessians; without one it falls
            # back to round-to-nearest.
            from repro import wq
            wcfg = wq.parse_weight_quant(weight_quant, group=wq_group,
                                         act_order=wq_act_order)
            hessians = None
            if wq_calib is not None:
                hessians = wq.collect_hessians(params, cfg, wq_calib,
                                               window=window)
            params, self.wq_report = wq.quantize_params(params, wcfg,
                                                        hessians=hessians)
        self.params = params
        self.cfg = cfg
        self.page_size = page_size
        self.window = window
        self.temperature = temperature
        self.eos_id = eos_id
        self.split_wire = split_wire
        # entropy-adaptive split wire: re-plan the connector link's
        # channel order + per-group widths between prefills, budgeted at
        # ``split_wire_budget_bits`` mean code bits per shipped scalar
        # (bucket-size independent — the byte budget scales with the
        # payload).  The plan lives on ``split_wire.group_widths`` /
        # ``.channel_perm``, so the codec and the byte accounting pick
        # it up unchanged.  Sorted grouping matters here: connector
        # channels are strongly heterogeneous, and entropy-ranked groups
        # let the allocator starve the near-dead ones.
        self.split_wire_budget_bits = split_wire_budget_bits
        self.split_plan_groups = split_plan_groups
        self._wire_ema = None
        if split_wire_budget_bits is not None:
            if split_wire is None:
                raise ValueError("split_wire_budget_bits needs split_wire")
            from repro.core import entropy as entropy_mod
            self._wire_ema = entropy_mod.init_entropy_ema(cfg.d_model)
        self.impl = impl
        self.pools = paged.init_pools(cfg, n_pages, page_size)
        self.page_pool = PagePool(n_pages)
        n_img = cfg.n_image_tokens if cfg.modality == "vlm" else 0
        self.n_image_tokens = n_img
        self.scheduler = SlotScheduler(n_slots, self.page_pool, page_size,
                                       n_image_tokens=n_img)
        self._step_fn = paged.compiled_paged_step(cfg, window=window,
                                                  impl=impl)
        self._rng = jax.random.PRNGKey(seed)
        self._next_rid = 0
        self.stats = dict(wire_bytes=0, prefill_batches=0, decode_ticks=0,
                          tokens_emitted=0, admitted=0, retired=0,
                          page_table_buckets=set())
        if self.wq_report is not None:
            self.stats["weight_bytes_dense"] = sum(
                d for d, _ in self.wq_report.values())
            self.stats["weight_bytes_packed"] = sum(
                p for _, p in self.wq_report.values())

    # -- request intake -------------------------------------------------
    def submit(self, tokens: List[int], *, max_new: int,
               image_embeds=None, arrival_time: float = 0.0) -> int:
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if self.cfg.modality == "vlm" and image_embeds is None:
            raise ValueError("vlm configs require image_embeds per request")
        rid = self._next_rid
        self._next_rid += 1
        self.scheduler.submit(Request(rid=rid, tokens=list(tokens),
                                      max_new=max_new,
                                      image_embeds=image_embeds,
                                      arrival_time=arrival_time))
        return rid

    @property
    def idle(self) -> bool:
        return self.scheduler.idle

    def request(self, rid: int) -> Request:
        return self.scheduler.requests[rid]

    # -- sampling -------------------------------------------------------
    def _pick(self, last_logits: np.ndarray) -> np.ndarray:
        """(m, V) -> (m,) token ids (greedy, or temperature sampling)."""
        if self.temperature <= 0.0:
            return np.argmax(last_logits, axis=-1)
        self._rng, sub = jax.random.split(self._rng)
        return np.asarray(jax.random.categorical(
            sub, jnp.asarray(last_logits) / self.temperature, axis=-1))

    def _maybe_finish(self, req: Request, tok: int) -> None:
        if self.eos_id is not None and tok == self.eos_id:
            self.scheduler.retire(req, "eos")
        elif len(req.out) >= req.max_new:
            self.scheduler.retire(req, "length")
        if req.state == "done":
            self.stats["retired"] += 1

    # -- prefill (admission batch) --------------------------------------
    def _ship_image_features(self, image_embeds: jnp.ndarray) -> jnp.ndarray:
        """Client-side connector -> quantized wire -> server-side
        reconstruction, with payload byte accounting.

        With a grouped ``split_wire`` the payload is a
        :class:`~repro.core.payload.GroupedPayload` (per-group codes at
        per-group widths); ``wire_bytes`` stays exact either way.  In
        adaptive mode the connector features first advance the entropy
        EMA and may re-plan the widths for THIS and later shipments.
        """
        import dataclasses

        feats = mlp_forward(self.params["connector"],
                            image_embeds.astype(tf.cdtype(self.cfg)))
        if self.split_wire_budget_bits is not None:
            from repro.core import entropy as entropy_mod
            from repro.launch import schedules

            self._wire_ema = entropy_mod.update_entropy_ema(self._wire_ema,
                                                            feats)
            d = feats.shape[-1]
            perm, plan = schedules.replan_grouped(
                self._wire_ema,
                self.split_wire_budget_bits * feats.size / 8.0,
                n_groups=self.split_plan_groups,
                scalars_per_channel=feats.size // d)
            if (plan != self.split_wire.group_widths
                    or perm != self.split_wire.channel_perm):
                self.split_wire = dataclasses.replace(self.split_wire,
                                                      group_widths=plan,
                                                      channel_perm=perm)
                self.stats["wire_plan"] = plan
        payload = quantizers.encode(self.split_wire, feats)
        self.stats["wire_bytes"] += payload.wire_bytes()
        return quantizers.decode(self.split_wire, payload)

    def _prefill(self, admitted: List[Request]) -> None:
        cfg, pg = self.cfg, self.page_size
        n_img = self.n_image_tokens
        plens = [len(r.tokens) for r in admitted]
        # bucket the prefill shape: pow2 page count for the ring length,
        # pow2 row count — bounded set of compiled prefill shapes.
        npb = paged.next_pow2(-(-(n_img + max(plens)) // pg))
        lb = npb * pg
        rows = paged.next_pow2(len(admitted))
        lp = lb - n_img  # token length such that positions cover exactly lb
        tokens = np.zeros((rows, lp), np.int32)
        for i, r in enumerate(admitted):
            tokens[i, :len(r.tokens)] = r.tokens
        batch: Dict = dict(tokens=jnp.asarray(tokens))
        if cfg.modality == "vlm":
            imgs = np.stack(
                [np.asarray(r.image_embeds) for r in admitted]
                + [np.zeros_like(np.asarray(admitted[0].image_embeds))]
                * (rows - len(admitted)))
            if self.split_wire is not None:
                batch["image_features"] = self._ship_image_features(
                    jnp.asarray(imgs))
            else:
                batch["image_embeds"] = jnp.asarray(imgs)
        self._rng, prefill_rng = jax.random.split(self._rng)
        logits, caches = sd.prefill(self.params, cfg, batch, lb,
                                    window=self.window, rng=prefill_rng)
        # scatter the ring caches into each request's physical pages;
        # logical pages past a row's reservation (and the dummy rows) go
        # to the trash page, right-padding is masked to pos = -1.
        page_rows = np.zeros((rows, npb), np.int32)
        valid_len = np.zeros((rows,), np.int32)
        for i, r in enumerate(admitted):
            row = (r.pages + [0] * npb)[:npb]
            page_rows[i] = row
            valid_len[i] = n_img + plens[i]
        self.pools = paged.insert_prefill(self.pools, caches,
                                          jnp.asarray(page_rows),
                                          jnp.asarray(valid_len))
        # first emitted token: the pick at each row's LAST REAL position
        # (right-padded rows must not read the pad tail's logits).
        lg = np.asarray(logits)
        last = lg[np.arange(len(admitted)),
                  [n_img + p - 1 for p in plens]]
        toks = self._pick(last)
        now = time.perf_counter()
        for r, tok in zip(admitted, toks):
            r.out.append(int(tok))
            r.prefill_time = now
            r.emit_times.append(now)
            self.stats["tokens_emitted"] += 1
            self._maybe_finish(r, int(tok))
        self.stats["prefill_batches"] += 1
        self.stats["admitted"] += len(admitted)

    # -- decode tick ----------------------------------------------------
    def _decode_tick(self, active: List[Request]) -> None:
        pg = self.page_size
        s = self.scheduler.n_slots
        npp = paged.next_pow2(max(r.qpos // pg + 1 for r in active))
        self.stats["page_table_buckets"].add(npp)
        tokens = np.zeros((s, 1), np.int32)
        qpos = np.full((s,), -1, np.int32)
        page_table = np.full((s, npp), -1, np.int32)
        for r in active:
            tokens[r.slot, 0] = r.out[-1]
            qpos[r.slot] = r.qpos
            row = r.pages[:npp]
            page_table[r.slot, :len(row)] = row
        logits, self.pools = self._step_fn(
            self.params, self.pools, dict(tokens=jnp.asarray(tokens)),
            jnp.asarray(qpos), jnp.asarray(page_table))
        last = np.asarray(logits)[:, -1]
        toks = self._pick(last)
        now = time.perf_counter()
        for r in active:
            tok = int(toks[r.slot])
            r.out.append(tok)
            r.qpos += 1
            r.emit_times.append(now)
            self.stats["tokens_emitted"] += 1
            self._maybe_finish(r, tok)
        self.stats["decode_ticks"] += 1

    # -- main loop ------------------------------------------------------
    def step(self) -> None:
        """One engine tick: admit (+ prefill) then decode every slot."""
        admitted = self.scheduler.admit()
        if admitted:
            self._prefill(admitted)
        active = self.scheduler.active
        if active:
            self._decode_tick(active)

    def run(self) -> Dict[int, List[int]]:
        """Drive until every submitted request finished."""
        while not self.idle:
            before = (self.stats["tokens_emitted"], len(self.scheduler.waiting))
            self.step()
            after = (self.stats["tokens_emitted"], len(self.scheduler.waiting))
            if before == after:  # no progress: pool can never fit the head
                head = self.scheduler.waiting[0]
                raise RuntimeError(
                    f"request {head.rid} needs "
                    f"{self.scheduler.pages_needed(head)} pages but the "
                    f"pool only has {self.page_pool.n_pages - 1}")
        return {rid: r.out for rid, r in self.scheduler.requests.items()}
