from repro.serve.decode import (cache_length, compiled_serve_step, generate,
                                make_serve_step, prefill)
from repro.serve.engine import ServeEngine
from repro.serve.paged import (compiled_paged_step, init_pools,
                               insert_prefill, make_paged_step, next_pow2)
from repro.serve.pool import PagePool
from repro.serve.scheduler import Request, SlotScheduler

__all__ = ["cache_length", "compiled_serve_step", "generate",
           "make_serve_step", "prefill", "ServeEngine", "PagePool",
           "Request", "SlotScheduler", "compiled_paged_step", "init_pools",
           "insert_prefill", "make_paged_step", "next_pow2"]
