from repro.serve.decode import (cache_length, generate, make_serve_step,
                                prefill)

__all__ = ["cache_length", "generate", "make_serve_step", "prefill"]
