"""Serving: one-token decode step + batched autoregressive generation.

``make_serve_step(cfg)`` returns the jit-able function lowered by the
decode_32k / long_500k dry-run shapes: ONE new token against a KV/state
cache of the configured length.  ``generate`` drives it autoregressively
(greedy or temperature sampling) for the examples.

Both the prefill (``transformer.forward`` with cache collection) and the
per-token step (``transformer.decode_step``) execute the layer stack
through the unified executor in ``repro.models.stack`` — the serve path
shares one scan implementation with training, so cache layouts stay
structurally identical to the training-time parameter stacking.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import attention_ops
from repro.models import transformer as tf


def cache_length(cfg: ArchConfig, seq_len: int,
                 window: Optional[int]) -> int:
    """Ring-buffer size: full history, or the window for long-context."""
    if window is not None:
        return min(seq_len, window)
    return seq_len


def make_serve_step(cfg: ArchConfig, *,
                    window: Optional[int] = None) -> Callable:
    def serve_step(params, caches, batch: Dict, qpos: jnp.ndarray):
        logits, new_caches = tf.decode_step(params, cfg, caches, batch, qpos,
                                            window=window)
        return logits, new_caches

    return serve_step


@functools.lru_cache(maxsize=32)
def _compiled_serve_step(cfg: ArchConfig, window: Optional[int],
                         attn_impl: str) -> Callable:
    """One jitted serve step per (cfg, window, attention backend).

    ``ArchConfig`` is a frozen (hashable) dataclass, so repeated
    ``generate`` calls — and multiple concurrent generations on the same
    model — reuse a single compiled step instead of re-jitting per call.
    The resolved attention backend is part of the key: REPRO_ATTN_IMPL is
    read at trace time, so flipping it between ``generate`` calls must
    miss the cache rather than silently reuse the other backend's step.

    ``caches`` is DONATED: the per-token step updates the KV ring buffers
    in place (XLA input/output aliasing) instead of materializing a full
    cache copy per token.  Callers must not reuse a caches tree after
    passing it in — rebind it from the step's return value.
    """
    del attn_impl  # cache key only; the traced code reads the env var
    return jax.jit(make_serve_step(cfg, window=window), donate_argnums=(1,))


def compiled_serve_step(cfg: ArchConfig, *, window: Optional[int] = None,
                        impl: Optional[str] = None) -> Callable:
    """Public accessor for the cached jitted step (engine + benches)."""
    return _compiled_serve_step(cfg, window,
                                attention_ops.resolve_impl(impl))


@functools.lru_cache(maxsize=32)
def _compiled_prefill(cfg: ArchConfig, cache_len: int,
                      window: Optional[int], attn_impl: str) -> Callable:
    del attn_impl  # cache key only; the traced code reads the env var

    def _prefill(params, batch, rng):
        logits, _aux, caches = tf.forward(params, cfg, batch, rng=rng,
                                          window=window,
                                          collect_cache=cache_len)
        return logits, caches

    return jax.jit(_prefill)


def prefill(params, cfg: ArchConfig, batch: Dict, cache_len: int, *,
            window: Optional[int] = None,
            rng: Optional[jax.Array] = None):
    """Run the full-sequence pass and return (logits, caches).

    Jitted and cached per (cfg, cache_len, window, backend): the serving
    engine prefills every admission wave through here, so an unjitted
    (op-by-op) forward would dominate its tick time."""
    fn = _compiled_prefill(cfg, cache_len, window,
                           attention_ops.resolve_impl(None))
    return fn(params, batch, rng)


def generate(params, cfg: ArchConfig, batch: Dict, *, n_new: int,
             cache_len: int, window: Optional[int] = None,
             temperature: float = 0.0, rng: Optional[jax.Array] = None,
             eos_id: Optional[int] = None, pad_id: int = 0) -> jnp.ndarray:
    """Prefill + greedy/sampled generation of ``n_new`` tokens.

    ``eos_id`` enables per-sequence early stop: a row that emits EOS is
    frozen — every later position is ``pad_id`` regardless of continued
    stepping — and the decode loop exits as soon as ALL rows finished
    instead of always paying ``n_new`` steps."""
    if rng is None:
        rng = jax.random.PRNGKey(0)
    # Split BEFORE consuming: prefill (dropout / quantizer noise) and the
    # first sampled token must never share a key — reusing ``rng`` for
    # both correlates the first sample with the prefill randomness.
    rng, prefill_rng = jax.random.split(rng)
    logits, caches = prefill(params, cfg, batch, cache_len, window=window,
                             rng=prefill_rng)
    if cfg.modality == "audio":
        prompt_len = batch["codes"].shape[-1]
        bsz = batch["codes"].shape[0]
    elif cfg.modality == "vlm":
        prompt_len = batch["tokens"].shape[1] + cfg.n_image_tokens
        bsz = batch["tokens"].shape[0]
    else:
        prompt_len = batch["tokens"].shape[1]
        bsz = batch["tokens"].shape[0]

    serve_step = _compiled_serve_step(cfg, window,
                                      attention_ops.resolve_impl(None))

    def pick(logits, key):
        # (B, V), or (B, K, V) for audio — argmax/categorical over the
        # trailing vocab axis handles both (per-codebook picks for audio).
        last = logits[:, -1]
        if temperature <= 0.0:
            return jnp.argmax(last, axis=-1)
        return jax.random.categorical(key, last / temperature, axis=-1)

    def freeze(tok, done):
        d = done if tok.ndim == 1 else done[:, None]
        return jnp.where(d, jnp.asarray(pad_id, tok.dtype), tok)

    out = []
    done = jnp.zeros((bsz,), bool)
    rng, first_key = jax.random.split(rng)
    tok = pick(logits, first_key)
    for i in range(n_new):
        if eos_id is not None:
            tok = freeze(tok, done)
            hit = (tok == eos_id) if tok.ndim == 1 \
                else jnp.all(tok == eos_id, axis=-1)
            done = done | hit
        out.append(tok)
        if eos_id is not None and i + 1 < n_new and bool(jnp.all(done)):
            pad = jnp.full_like(tok, pad_id)
            out.extend([pad] * (n_new - i - 1))
            break
        qpos = jnp.full((bsz,), prompt_len + i, jnp.int32)
        if cfg.modality == "audio":
            step_batch = dict(codes=tok[..., None].astype(jnp.int32)
                              if tok.ndim == 2 else
                              jnp.broadcast_to(tok[:, None, None],
                                               (bsz, cfg.n_codebooks, 1)
                                               ).astype(jnp.int32))
        else:
            step_batch = dict(tokens=tok[:, None].astype(jnp.int32))
        rng, sub = jax.random.split(rng)
        logits, caches = serve_step(params, caches, step_batch, qpos)
        tok = pick(logits, sub)
    return jnp.stack(out, axis=1)
