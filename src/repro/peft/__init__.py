"""Parameter-efficient fine-tuning (SplitLoRA subsystem)."""
from repro.peft.lora import (  # noqa: F401
    adapter_bytes,
    adapter_param_count,
    apply_lora,
    init_lora_params,
    is_lora_site,
    lora_delta,
    lora_sites,
    merge_lora,
    unmerge_lora,
)
