"""LoRA adapters over the stack executor's parameter trees.

SplitLoRA (PAPERS.md) composes the split-learning setting with low-rank
adapters: each side of the cut fine-tunes only ``rank``-dimensional
factors ``A @ B`` added to its frozen projection weights, which shrinks
the optimizer state, the checkpoint, and — on the hub's quantized
gradient-return wire — the gradient traffic, the dominant systems cost
of split fine-tuning.

The subsystem is deliberately structural, not per-arch: a **LoRA site**
is any parameter-tree leaf whose dict key starts with ``"w"`` and whose
rank is >= 2, with the last two axes read as ``(d_in, d_out)`` and all
leading axes (layer stacking, stage stacking, MoE experts) treated as
batch.  That single rule covers GQA attention (``wq/wk/wv/wo``), MLA
factored projections (``wq_a/wq_b/wkv_a/wkv_b``), SwiGLU MLPs
(``w_gate/w_up/w_down``) and MoE expert banks, while skipping norms
(``ln1``, ``q_norm``, ...), the fp32 MoE ``router``, and biases — so the
whole arch zoo gets adapters without touching per-arch forward code.

Adapters live in a nested dict *mirroring* the host tree: every site
leaf ``w`` is replaced by ``{"lora_a": A, "lora_b": B}`` with
``A: (*batch, d_in, r)`` (init ~ N(0, 1/d_in)) and ``B: (*batch, r,
d_out)`` (init 0, so step 0 is the base model).  Because the adapter
tree mirrors the host tree's key paths, it scans through
``models/stack.py``'s ``run_stack`` as a sibling pytree — slicing the
tuple ``(blocks, adapters)`` over the layer axis keeps the paths
aligned.

``apply_lora`` and ``merge_lora`` share one code path, so the merged
weights are **bit-identical** to the effective weights the training
forward used — ``ServeEngine``/``generate`` on merged params is
token-exact vs the unmerged adapter forward, with zero runtime
overhead.  ``unmerge_lora`` subtracts the same delta (recovers base to
fp tolerance, not bit-exact).
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.utils.tree import is_weight_site, key_name, weight_sites

Path = Tuple[str, ...]

_key_name = key_name

# The structural site rule is shared with repro.wq (weight-only serving
# quantization selects the exact same ``w*``/ndim>=2 leaves it adapts) —
# one definition in utils.tree, aliased here for the established names.
is_lora_site = is_weight_site


def lora_sites(tree) -> List[Tuple[Path, Any]]:
    """``(path, leaf)`` for every LoRA site in ``tree`` (stable order)."""
    return weight_sites(tree)


def _nest_set(d: Dict, path: Path, value) -> None:
    for name in path[:-1]:
        d = d.setdefault(name, {})
    d[path[-1]] = value


def init_lora_params(key, tree, rank: int, *, b_scale: float = 0.0):
    """Adapter tree mirroring ``tree``'s LoRA sites.

    ``A ~ N(0, 1/d_in)``, ``B = 0`` (or ``b_scale``-scaled normal when a
    test wants a nonzero delta), both in the site leaf's dtype.  Works
    under ``jax.eval_shape`` for spec derivation.
    """
    if rank <= 0:
        raise ValueError(f"lora rank must be positive, got {rank}")
    sites = lora_sites(tree)
    if not sites:
        raise ValueError("no LoRA sites (w*, ndim>=2) in tree")
    keys = jax.random.split(key, 2 * len(sites))
    adapters: Dict = {}
    for i, (path, w) in enumerate(sites):
        d_in = w.shape[-2]
        a = (jax.random.normal(keys[2 * i], w.shape[:-1] + (rank,))
             * d_in ** -0.5).astype(w.dtype)
        if b_scale:
            b = (jax.random.normal(keys[2 * i + 1],
                                   w.shape[:-2] + (rank, w.shape[-1]))
                 * b_scale).astype(w.dtype)
        else:
            b = jnp.zeros(w.shape[:-2] + (rank, w.shape[-1]), w.dtype)
        _nest_set(adapters, path, {"lora_a": a, "lora_b": b})
    return adapters


def lora_delta(site: Dict, scale: float) -> jax.Array:
    """``scale * A @ B`` with leading axes batched (fp32 accumulate)."""
    a, b = site["lora_a"], site["lora_b"]
    d = jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))
    return (scale * d).astype(a.dtype)


def _adapter_map(adapters) -> Dict[Path, Dict]:
    """Site path -> ``{"lora_a", "lora_b"}`` from an adapter tree."""
    flat, _ = jax.tree_util.tree_flatten_with_path(adapters)
    sites: Dict[Path, Dict] = {}
    for path, leaf in flat:
        names = tuple(_key_name(p) for p in path)
        if names[-1] not in ("lora_a", "lora_b"):
            raise ValueError(f"not an adapter tree: leaf {names}")
        sites.setdefault(names[:-1], {})[names[-1]] = leaf
    return sites


def _fold(tree, adapters, scale: float, sign: int):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    sites = _adapter_map(adapters)
    seen = set()
    leaves = []
    for path, w in flat:
        names = tuple(_key_name(p) for p in path)
        site = sites.get(names)
        if site is None:
            leaves.append(w)
        else:
            seen.add(names)
            leaves.append(
                (w + sign * lora_delta(site, scale)).astype(w.dtype))
    missing = set(sites) - seen
    if missing:
        raise ValueError(f"adapter sites missing from tree: {missing}")
    return jax.tree_util.tree_unflatten(treedef, leaves)


def apply_lora(tree, adapters, *, scale: float = 1.0):
    """Effective weights ``w + scale * A @ B`` (same math as merge).

    Used inside the training forward: base leaves stay frozen, gradients
    flow to the adapter factors only.  ``scale`` defaults to 1.0, i.e.
    ``alpha == rank``.
    """
    return _fold(tree, adapters, scale, +1)


def merge_lora(tree, adapters, *, scale: float = 1.0):
    """Fold adapters into the base weights for zero-overhead serving.

    Identical arithmetic to :func:`apply_lora`, so the merged forward is
    bit-exact vs the unmerged (apply-path) forward.
    """
    return _fold(tree, adapters, scale, +1)


def unmerge_lora(tree, adapters, *, scale: float = 1.0):
    """Subtract the adapter delta (recovers base to fp tolerance)."""
    return _fold(tree, adapters, scale, -1)


def adapter_param_count(adapters) -> int:
    import math

    return sum(math.prod(a.shape)
               for a in jax.tree_util.tree_leaves(adapters))


def adapter_bytes(adapters) -> int:
    import math

    return sum(math.prod(a.shape) * jnp.dtype(a.dtype).itemsize
               for a in jax.tree_util.tree_leaves(adapters))
