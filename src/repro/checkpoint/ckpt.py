"""Checkpointing: pytree <-> .npz with path-keyed arrays (no orbax offline).

Paths are '/'-joined key paths; dataclass TrainStates round-trip through
their pytree form.  bfloat16 leaves are stored via a uint16 view (npz has
no native bf16) and restored exactly.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_BF16_TAG = "__bf16__"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(path: str, tree: Any) -> None:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays: Dict[str, np.ndarray] = {}
    for p, leaf in flat:
        key = _path_str(p)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            arrays[key + _BF16_TAG] = arr.view(np.uint16)
        else:
            arrays[key] = arr
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **arrays)


def restore(path: str, template: Any) -> Any:
    """Restore into the structure of ``template`` (same pytree)."""
    with np.load(path) as data:
        stored = {k: data[k] for k in data.files}
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = _path_str(p)
        if key + _BF16_TAG in stored:
            arr = stored[key + _BF16_TAG].view(jnp.bfloat16)
        elif key in stored:
            arr = stored[key]
        else:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"template {leaf.shape}")
        leaves.append(jnp.asarray(arr))
    return treedef.unflatten(leaves)


def save_adapters(path: str, adapters: Any) -> None:
    """Persist a SplitLoRA adapter tree (and nothing else).

    The whole point of the adapter checkpoint is that it is orders of
    magnitude smaller than the full parameter tree, so this validates
    the tree really is adapters-only — every leaf path must end in a
    ``lora_a``/``lora_b`` key (``repro.peft.init_lora_params`` layout)
    — before delegating to :func:`save`.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(adapters)
    if not flat:
        raise ValueError("empty adapter tree")
    for p, _leaf in flat:
        key = _path_str(p)
        if key.rsplit("/", 1)[-1] not in ("lora_a", "lora_b"):
            raise ValueError(
                f"not an adapter tree: leaf {key!r} is not a "
                f"lora_a/lora_b entry")
    save(path, adapters)


def load_adapters(path: str, template: Any) -> Any:
    """Restore an adapter tree saved by :func:`save_adapters`.

    ``template`` is an adapter tree of the target shapes — e.g.
    ``init_lora_params(key, params, rank)`` or ``params["adapters"]`` —
    restored bit-exactly (bf16 via the uint16 view).
    """
    return restore(path, template)
