from repro.checkpoint.ckpt import (load_adapters, restore, save,
                                   save_adapters)

__all__ = ["save", "restore", "save_adapters", "load_adapters"]
