"""Roofline derivation from dry-run artifacts (deliverable g).

Three terms, all in seconds, per (arch, shape, mesh):

    compute    = HLO_FLOPs_per_device / peak_FLOP/s_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = collective_bytes_per_device / ICI_bw_per_chip

cost_analysis() runs on the GSPMD-partitioned per-device module, so its
flops/bytes are already per-chip — dividing by per-chip peaks is exactly
the brief's "global / (chips x peak)".

MODEL_FLOPS = 6 * N * D with N = active non-embedding params (MoE: shared +
top_k routed), D = tokens processed by the step.  The ratio
MODEL_FLOPS / (HLO_FLOPs * chips) measures how much compiled compute is
"useful" — remat recompute, masked attention waste, and MoE capacity
overprovisioning all push it below 1.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models.layers.mamba2 import dims as mamba_dims


def param_counts(cfg: ArchConfig) -> Dict[str, float]:
    """Analytic parameter counts (total, active, embedding)."""
    d = cfg.d_model
    embed = cfg.vocab_size * d * (cfg.n_codebooks or 1)
    head = d * cfg.vocab_size * (cfg.n_codebooks or 1)

    def attn_params() -> float:
        if cfg.attn_type == "mla":
            qk = cfg.qk_nope_dim + cfg.qk_rope_dim
            p = cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim +
                                                  cfg.v_head_dim)
            p += d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
            if cfg.q_lora_rank:
                p += d * cfg.q_lora_rank + \
                    cfg.q_lora_rank * cfg.n_heads * qk
            else:
                p += d * cfg.n_heads * qk
            p += cfg.n_heads * cfg.v_head_dim * d
            return p
        hd = cfg.head_dim
        return d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)

    def swiglu(f):
        return 3 * d * f

    total = 0.0
    active = 0.0
    for t in cfg.block_pattern():
        if t == "dense":
            p = attn_params() + swiglu(cfg.d_ff)
            total += p
            active += p
        elif t == "moe":
            a = attn_params()
            expert = swiglu(cfg.moe_d_ff or cfg.d_ff)
            shared = swiglu(cfg.n_shared_experts * (cfg.moe_d_ff or cfg.d_ff)
                            ) if cfg.n_shared_experts else 0
            dense_res = swiglu(cfg.d_ff) if cfg.dense_residual else 0
            total += a + cfg.n_experts * expert + shared + dense_res
            active += a + cfg.moe_top_k * expert + shared + dense_res
        elif t == "mamba2":
            d_inner, nheads, conv_dim = mamba_dims(
                d, cfg.ssm_expand, cfg.ssm_headdim, cfg.ssm_state)
            p = d * (2 * d_inner + 2 * cfg.ssm_state + nheads) + \
                4 * conv_dim + d_inner * d + d_inner
            total += p
            active += p
        elif t == "rwkv6":
            p = 5 * d * d + d * cfg.d_ff * 2 + d * d  # tmix + cmix
            total += p
            active += p
        elif t == "shared_attn":
            # parameters shared across occurrences: count once in total,
            # every occurrence in active (they all execute)
            p = 2 * d * d + attn_params() + swiglu(cfg.d_ff)
            active += p
    if "shared_attn" in cfg.block_pattern():
        total += 2 * d * d + attn_params() + swiglu(cfg.d_ff)
    return dict(total=total + embed + head, active=active + head,
                embedding=embed, non_embedding_total=total + head)


def weight_stream_bits(bits: int, group: int) -> float:
    """Serve-time HBM bits per weight element for a packed store.

    ``bits`` code bits plus the amortized fp16 scale+min per ``group``
    elements (``repro.wq`` grouped-affine layout).  bf16 is the dense
    baseline: 16 bits, no side info.
    """
    if bits >= 16:
        return float(bits)
    return bits + 2 * 16.0 / group


def decode_weight_bytes(cfg: ArchConfig, bits: int = 16,
                        group: int = 128) -> float:
    """Weight HBM bytes one decode tick streams per chip (batch-free).

    Every decode step reads the whole non-embedding stack once; the
    packable w* matmul sites stream at ``weight_stream_bits`` while the
    head and norms stay at the compute dtype.  This is the roofline's
    memory-term floor for serving — the quantity ``repro.wq`` shrinks.
    """
    counts = param_counts(cfg)
    blocks = counts["non_embedding_total"] - cfg.d_model * cfg.vocab_size * \
        (cfg.n_codebooks or 1)
    head = counts["non_embedding_total"] - blocks
    return blocks * weight_stream_bits(bits, group) / 8.0 + head * 2.0


def model_flops(cfg: ArchConfig, shape) -> float:
    """6 * N_active * D (forward+backward for train; 2*N*D for inference)."""
    counts = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.batch * shape.seq_len
        return 6.0 * counts["active"] * tokens
    if shape.kind == "prefill":
        tokens = shape.batch * shape.seq_len
        return 2.0 * counts["active"] * tokens
    tokens = shape.batch  # one token per sequence
    return 2.0 * counts["active"] * tokens


def derive_roofline(result: Dict) -> Dict:
    cost = result["cost"]
    # loop-aware totals from the HLO walk (cost_analysis counts while
    # bodies once); fall back to raw cost_analysis when absent.
    flops_dev = float(cost.get("flops_loop_aware") or cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes_out_loop_aware")
                      or cost.get("bytes accessed", 0.0))
    coll_dev = float(result["collective_bytes_per_device"])
    chips = result["chips"]
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = dict(compute=compute_s, memory=memory_s, collective=collective_s)
    dominant = max(terms, key=terms.get)
    useful = result.get("model_flops", 0.0) / max(flops_dev * chips, 1.0)
    bound = max(terms.values())
    frac = {k: (v / bound if bound > 0 else 0.0) for k, v in terms.items()}
    return dict(compute_s=compute_s, memory_s=memory_s,
                collective_s=collective_s, dominant=dominant,
                useful_flops_ratio=useful,
                step_lower_bound_s=bound,
                fractions=frac)
