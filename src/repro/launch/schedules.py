"""Schedulers — who ticks when (layer 3 of the split stack).

Layer 1 (``repro.core.split_stage``) defines what one partition computes;
layer 2 (``repro.core.split.WireLink``) defines how activations and
cotangents cross between partitions.  This module composes them into
executable training schedules:

* :func:`build_gpipe_step` / :func:`build_gpipe_grad_step` — the paper's
  lockstep pipeline: ``n_stages`` partitions on the ``pod`` mesh axis,
  GPipe fill/drain over ``n_micro + n_stages - 1`` microbatch ticks, one
  quantized ship per cut group per tick.  This is the former
  ``launch/split_pipeline.build_pipeline_step`` re-expressed over stage
  programs + wire links (``launch/split_pipeline`` is now a thin
  composition that delegates here).

* :func:`build_hub_step` / :func:`build_hub_grad_step` — the many-client
  hub (ROADMAP item 2, BEYOND-PAPER): N client stages share ONE server
  stage.  Clients embed + run their bottom halves in parallel pods; each
  ships across its own :class:`~repro.core.split.WireLink` (per-client
  quantizers — ppermute forbids grouping links into one collective when
  the destination repeats, so hub ships are per-link by construction);
  the server executes its half ONCE, batched over the N arrivals
  ``(N*B, S, D)``, and computes a per-client CE.  The backward pass
  returns each client's cotangent across its link (optionally quantized:
  gradient aggregation across clients crosses the backward wire in wire
  form), while the shared server parameters accumulate gradients from
  all clients' batched execution.

* :func:`arrival_mask` + :func:`build_async_update` — the
  staleness-tolerant async mode: clients tick at different rates
  (``HubConfig.tick_rates``); at every global tick the server applies
  gradients for exactly the clients that arrived (mask-gated, so one
  compiled update serves every arrival pattern).  Client bottom halves
  only update when their own gradient returns, so slow clients train
  against a server that moved on — the staleness the scheduler must
  tolerate.  The transport here is the *in-graph* wire form (STE
  roundtrip forward, :func:`~repro.core.split.quantize_cotangent`
  backward) because client and server are co-located in one program; the
  lockstep schedulers above exercise the real collective-permute wire,
  and their per-link bytes are asserted against the lowered HLO.

Wire-byte accounting contract (the heterogeneous-quant fix): every
helper here reports bytes PER LINK, each link counted exactly once on
the devices that execute it.  ``fwd_tick``/``bwd_tick`` are per-device
per-tick bytes — the MAX over links of the device's payload slice (a
device sources at most one link per tick), NOT the old sum over distinct
cut configs, which overcounted whenever ``stage_quants`` mixed widths.
``links[(src, dst)]`` carries each link's full per-tick traffic (slice x
data shards) — the quantity asserted against the HLO collective-permute
bytes via :func:`pod_link_bytes`.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import entropy as entropy_mod
from repro.core import quantizers
from repro.core.quantizers import QuantConfig
from repro.core.split import (HubConfig, SplitConfig, WireLink, group_links,
                              init_wire_calib, pipeline_links,
                              quantize_cotangent, quantized_ship,
                              update_wire_calib)
from repro.core.split_stage import (embed_tokens, head_ce, init_stage_params,
                                    run_blocks, stage_param_specs)
from repro.models import transformer as tf
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.train.losses import IGNORE, cross_entropy


# ---------------------------------------------------------------------------
# per-link wire accounting
# ---------------------------------------------------------------------------

def _link_bytes(links: Tuple[WireLink, ...], x_sds,
                data_shards: int, grad_sds=None) -> Dict:
    """The per-link byte table shared by chain and hub topologies.

    ``x_sds`` is ONE device's activation slice (micro_batch/data_shards).
    ``grad_sds`` (SplitLoRA) is one stage's adapter-grad slice *tree*:
    each link then carries a ``grad`` entry — ONE direction of the
    adapter-grad return payload, crossed once per step (up and back, not
    per tick).  Full fine-tuning has no gradient-return collective
    (parameters update in place on their own pods), so ``grad`` is 0.
    """
    table = {}
    fwd_slice = []
    bwd_slice = []
    for link in links:
        f = link.fwd_wire_bytes(x_sds)
        b = link.bwd_wire_bytes(x_sds)
        g = link.grad_wire_bytes(grad_sds) if grad_sds is not None else 0
        # grouped plans report their widths tuple (the per-group bit
        # allocation); static links report the single width — both render
        # in the dry-run link tables and key the byte assertions
        table[(link.src, link.dst)] = dict(
            fwd=f * data_shards, bwd=b * data_shards,
            grad=g * data_shards,
            quant=link.quant.method,
            bits=(link.plan if link.quant.grouped else link.quant.bits))
        fwd_slice.append(f)
        bwd_slice.append(b)
    return dict(
        links=table,
        # per-device per-tick: a device sources at most one link per tick,
        # so its wire load is the largest single link slice — NOT the sum
        # over distinct configs (the old heterogeneous-quant overcount)
        fwd_tick=max(fwd_slice),
        bwd_tick=max(bwd_slice),
        # whole-topology traffic per tick, each link counted exactly once
        fwd_total=sum(v["fwd"] for v in table.values()),
        bwd_total=sum(v["bwd"] for v in table.values()),
        # whole-topology adapter-grad return per STEP, one direction
        grad_total=sum(v["grad"] for v in table.values()),
    )


def chain_wire_bytes(cfg: ArchConfig, split: SplitConfig, micro_batch: int,
                     seq: int, bwd_qcfg: Optional[QuantConfig] = None,
                     data_shards: int = 1) -> Dict:
    """Per-link static wire bytes of the lockstep chain pipeline."""
    assert micro_batch % data_shards == 0, (micro_batch, data_shards)
    x_sds = jax.ShapeDtypeStruct(
        (micro_batch // data_shards, seq, cfg.d_model), tf.cdtype(cfg))
    return _link_bytes(pipeline_links(split, bwd_qcfg), x_sds, data_shards)


def hub_wire_bytes(cfg: ArchConfig, hub: HubConfig, micro_batch: int,
                   seq: int, data_shards: int = 1,
                   lora_rank: int = 0) -> Dict:
    """Per-link static wire bytes of the N-client hub.

    With ``lora_rank > 0`` each link additionally reports its SplitLoRA
    adapter-grad return payload (``grad``): the quantized adapter-grad
    tree of ONE stage, crossed up + back once per step.
    """
    assert micro_batch % data_shards == 0, (micro_batch, data_shards)
    x_sds = jax.ShapeDtypeStruct(
        (micro_batch // data_shards, seq, cfg.d_model), tf.cdtype(cfg))
    grad_sds = None
    if lora_rank > 0:
        ad = jax.eval_shape(
            lambda: init_stage_params(jax.random.PRNGKey(0), cfg,
                                      hub.n_clients + 1, cfg.n_layers // 2,
                                      lora_rank=lora_rank))["adapters"]
        # one stage's slice of the stage-stacked adapter tree — what a
        # single client link actually returns
        grad_sds = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), ad)
    return _link_bytes(hub.links(), x_sds, data_shards, grad_sds=grad_sds)


def pod_link_bytes(pair_bytes: Dict[Tuple[int, int], int], mesh,
                   axis: str = "pod") -> Dict[Tuple[int, int], int]:
    """Aggregate HLO per-device-pair collective-permute bytes into
    per-stage-link bytes.

    ``pair_bytes`` comes from ``hlo_analysis.collective_permute_pairs``
    (device ids); the mesh maps each device to its ``axis`` coordinate.
    Summing the data-shard pairs of one stage link recovers that link's
    full traffic — comparable to ``links[(src, dst)]`` in the static
    tables above.  Assumes HLO partition ids coincide with the mesh's
    device ids (true for the fake-device meshes the dry-runs build, where
    ``make_mesh`` lays devices out in id order).
    """
    ax = mesh.axis_names.index(axis)
    devs = np.moveaxis(mesh.devices, ax, 0)
    pod_of = {}
    for pod in range(devs.shape[0]):
        for d in devs[pod].reshape(-1):
            pod_of[d.id] = pod
    out: Dict[Tuple[int, int], int] = {}
    for (a, b), v in pair_bytes.items():
        key = (pod_of[a], pod_of[b])
        out[key] = out.get(key, 0) + v
    return out


# ---------------------------------------------------------------------------
# entropy-adaptive re-planning (between compiled steps)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnums=(0, 3))
def boundary_probe(cfg: ArchConfig, params: Dict, tokens: jnp.ndarray,
                   stage: int = 0) -> jnp.ndarray:
    """Host-side probe of one stage's boundary activation (what its
    outgoing wire link ships): embed + that stage's block stack on a
    (B, S) token microbatch.  Runs OUTSIDE the shard_map schedules, on
    replicated parameters, between compiled steps — the adaptive wire's
    entropy signal is a statistic, so a single-microbatch replicated
    probe is enough (and keeps the compiled step plan-static).
    """
    blocks = jax.tree_util.tree_map(lambda a: a[stage], params["blocks"])
    x = embed_tokens(cfg, params, tokens, tf.cdtype(cfg))
    positions = jnp.arange(tokens.shape[-1], dtype=jnp.int32)
    return run_blocks(cfg, blocks, x, positions)


def replan_widths(ema_state: Dict, budget_bytes: float, *, n_groups: int,
                  scalars_per_channel: int,
                  min_bits: int = 1) -> Tuple[int, ...]:
    """One re-planning decision: EMA entropy readout -> greedy allocation.

    ``budget_bytes`` budgets the CODE bytes of one shipped activation
    slice (scale side-info rides on top — it is identical across plans
    of the same group count, so it cancels out of plan comparisons).
    Deterministic for a given state, so repeated calls with an unchanged
    signal return the same plan and the jit caches keyed on it hit.
    """
    ent = entropy_mod.entropy_ema_bits(ema_state)
    group_size = ent.shape[0] // n_groups
    return entropy_mod.allocate_bits(
        ent, budget_bytes, group_size=group_size,
        scalars_per_channel=scalars_per_channel, min_bits=min_bits)


def replan_grouped(ema_state: Dict, budget_bytes: float, *, n_groups: int,
                   scalars_per_channel: int, min_bits: int = 1
                   ) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Sorted-grouping re-plan: ``(channel_perm, group_widths)``.

    Like :func:`replan_widths` but channels are gathered into ascending
    entropy order before grouping (``QuantConfig.channel_perm``), which
    keeps the per-channel spread visible to the allocator instead of
    averaging it into near-uniform group means.  Use this on boundaries
    with real channel heterogeneity (e.g. the VLM connector wire).
    """
    ent = entropy_mod.entropy_ema_bits(ema_state)
    group_size = ent.shape[0] // n_groups
    return entropy_mod.plan_grouped(
        ent, budget_bytes, group_size=group_size,
        scalars_per_channel=scalars_per_channel, min_bits=min_bits)


# ---------------------------------------------------------------------------
# lockstep GPipe chain (the paper's pipeline, re-expressed over the layers)
# ---------------------------------------------------------------------------

def build_gpipe_step(cfg: ArchConfig, mesh, split: SplitConfig,
                     n_micro: int, micro_batch: int, seq: int,
                     bwd_qcfg: Optional[QuantConfig] = None,
                     lora_rank: int = 0):
    """Lockstep fill/drain pipeline step over stage programs + wire links.

    Returns fn(params, tokens, labels) -> (loss, wire_bytes) with
    ``tokens``/``labels`` (n_micro, B, S) int32 and ``wire_bytes`` the
    per-device per-tick forward payload (compile-time constant; see the
    module docstring for the per-link contract).

    ``lora_rank > 0`` (SplitLoRA): ``params`` carries an ``"adapters"``
    stack mirroring ``"blocks"``; every stage runs on the effective
    weights ``w + A @ B`` while the base leaves stay frozen.
    """
    n_stages = split.n_stages
    assert cfg.n_layers % n_stages == 0
    assert mesh.shape["pod"] == n_stages, \
        f"mesh pod axis {mesh.shape['pod']} != n_stages {n_stages}"
    dtype = tf.cdtype(cfg)
    links = pipeline_links(split, bwd_qcfg)
    # chain cuts with identical configs share ONE multi-pair collective
    groups = group_links(links)
    wire = chain_wire_bytes(cfg, split, micro_batch, seq, bwd_qcfg,
                            data_shards=mesh.shape["data"])
    last = n_stages - 1

    param_specs = stage_param_specs(cfg, n_stages, lora_rank=lora_rank)
    tok_spec = P(None, "data", None)  # (n_micro, B, S)

    @partial(shard_map, mesh=mesh,
             in_specs=(param_specs, tok_spec, tok_spec),
             out_specs=(P(), P()),
             check_rep=False)
    def step(params, tokens, labels):
        stage = jax.lax.axis_index("pod")
        my_blocks = jax.tree_util.tree_map(lambda a: a[0],
                                           params["blocks"])
        my_adapters = None if lora_rank == 0 else \
            jax.tree_util.tree_map(lambda a: a[0], params["adapters"])
        positions = jnp.arange(seq, dtype=jnp.int32)

        def tick(carry, xs):
            recv = carry  # activation received on the previous tick
            tok, lab = xs
            x_emb = embed_tokens(cfg, params, tok, dtype)
            x_in = jnp.where(stage == 0, x_emb, recv.astype(x_emb.dtype))
            h = run_blocks(cfg, my_blocks, x_in, positions,
                           adapters=my_adapters)
            # ship across every cut; a stage keeps the payload arriving
            # from its own upstream cut (cut c feeds stage c+1)
            recv_new = jnp.zeros_like(h)
            for qcfg, bq, glinks in groups:
                perm = tuple((lk.src, lk.dst) for lk in glinks)
                out_q = quantized_ship(qcfg, h, "pod", perm, bq)
                is_dst = jnp.zeros((), jnp.bool_)
                for lk in glinks:
                    is_dst = is_dst | (stage == lk.dst)
                recv_new = jnp.where(is_dst, out_q.astype(h.dtype),
                                     recv_new)
            # last-stage head + next-token CE on this tick's microbatch.
            # lax.cond, not a computed-then-masked jnp.where: the vocab
            # projection is the widest matmul in the model and only 1/N
            # of the stages needs it — the branch keeps the SPMD program
            # identical while sparing the other stages the work.
            ce = jax.lax.cond(stage == last,
                              lambda hh: head_ce(cfg, params, hh, lab),
                              lambda hh: jnp.zeros((), jnp.float32), h)
            return recv_new, ce

        # GPipe fill/drain: microbatch j enters stage 0 at tick j and
        # reaches the last stage at tick j + (n_stages - 1), so the scan
        # runs n_micro + n_stages - 1 ticks; stage 0 consumes dummy
        # tokens while draining and the last stage sees IGNORE labels
        # while filling (masked to CE = 0 by cross_entropy).
        pad_tok = jnp.zeros((last,) + tokens.shape[1:], tokens.dtype)
        tok_feed = jnp.concatenate([tokens, pad_tok], axis=0)
        pad_lab = jnp.full((last,) + labels.shape[1:], IGNORE, labels.dtype)
        lab_feed = jnp.concatenate([pad_lab, labels], axis=0)

        init = jnp.zeros((tokens.shape[1], seq, cfg.d_model), dtype)
        _, ces = jax.lax.scan(tick, init, (tok_feed, lab_feed))
        # sum over pod (only the last stage contributes), mean over the
        # data shards (each computed CE on its local microbatch slice)
        loss = jax.lax.pmean(jax.lax.psum(jnp.sum(ces), "pod"),
                             "data") / n_micro
        return loss, jnp.asarray(wire["fwd_tick"], jnp.float32)

    return step


def build_gpipe_grad_step(cfg: ArchConfig, mesh, split: SplitConfig,
                          bwd_qcfg: Optional[QuantConfig], n_micro: int,
                          micro_batch: int, seq: int, lora_rank: int = 0):
    """Differentiates the chain pipeline loss wrt the stage parameters,
    exercising the gradient-return wire.  Returns
    fn(params, tokens, labels) -> (loss, grads, wire_bytes).

    ``lora_rank > 0``: differentiates wrt ``params["adapters"]`` ONLY —
    ``grads`` mirrors the adapter tree, base weights are never touched by
    autodiff (frozen by construction, not by masking)."""
    step = build_gpipe_step(cfg, mesh, split, n_micro, micro_batch, seq,
                            bwd_qcfg=bwd_qcfg, lora_rank=lora_rank)
    wire = chain_wire_bytes(cfg, split, micro_batch, seq, bwd_qcfg,
                            data_shards=mesh.shape["data"])
    tick_bytes = float(wire["fwd_tick"] + wire["bwd_tick"])

    def grad_step(params, tokens, labels):
        if lora_rank > 0:
            base = {k: v for k, v in params.items() if k != "adapters"}

            def loss_fn_ad(ad):
                loss, _ = step(dict(base, adapters=ad), tokens, labels)
                return loss

            loss, grads = jax.value_and_grad(loss_fn_ad)(
                params["adapters"])
            return loss, grads, jnp.asarray(tick_bytes, jnp.float32)

        def loss_fn(p):
            loss, _ = step(p, tokens, labels)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return loss, grads, jnp.asarray(tick_bytes, jnp.float32)

    return grad_step


# ---------------------------------------------------------------------------
# lockstep hub: N clients + 1 shared server stage
# ---------------------------------------------------------------------------

def build_hub_step(cfg: ArchConfig, mesh, hub: HubConfig, n_micro: int,
                   micro_batch: int, seq: int, lora_rank: int = 0):
    """Lockstep hub step: pods 0..N-1 run client stages, pod N the server.

    Returns fn(params, tokens, labels) -> (loss, per_client_ce, wire_bytes)
    with ``tokens``/``labels`` (n_micro, n_clients, B, S) int32,
    ``per_client_ce`` (n_clients,) microbatch-averaged CE per client and
    ``wire_bytes`` the per-device per-tick forward payload constant.

    Schedule: at tick t every client embeds + runs microbatch t and ships
    across its own link; the server runs its half ONCE over the N
    payloads that arrived at tick t-1 — batched ``(N*B, S, D)`` stage
    execution — and computes each client's CE.  ``n_micro + 1`` ticks
    (1-tick fill/drain, the 2-stage GPipe special case per client).  With
    ``n_clients == 1`` this is exactly the paper's 2-partition pipeline
    and reproduces its loss (parity-tested to 3e-6).
    """
    n_clients = hub.n_clients
    assert cfg.n_layers % 2 == 0, cfg.n_layers
    per_stage = cfg.n_layers // 2
    assert mesh.shape["pod"] == n_clients + 1, \
        f"mesh pod axis {mesh.shape['pod']} != n_clients+1 {n_clients + 1}"
    dtype = tf.cdtype(cfg)
    links = hub.links()
    wire = hub_wire_bytes(cfg, hub, micro_batch, seq,
                          data_shards=mesh.shape["data"],
                          lora_rank=lora_rank)

    param_specs = stage_param_specs(cfg, n_clients + 1, per_stage,
                                    lora_rank=lora_rank)
    tok_spec = P(None, None, "data", None)  # (n_micro, N, B, S)

    @partial(shard_map, mesh=mesh,
             in_specs=(param_specs, tok_spec, tok_spec),
             out_specs=(P(), P(), P()),
             check_rep=False)
    def step(params, tokens, labels):
        pod = jax.lax.axis_index("pod")
        is_server = pod == n_clients
        my_blocks = jax.tree_util.tree_map(lambda a: a[0],
                                           params["blocks"])
        my_adapters = None if lora_rank == 0 else \
            jax.tree_util.tree_map(lambda a: a[0], params["adapters"])
        positions = jnp.arange(seq, dtype=jnp.int32)
        b_local = tokens.shape[2]

        def tick(recv, xs):
            # recv: (N, B, S, D) — the payloads the server received on the
            # previous tick (zeros on client pods, which ignore it)
            tok, lab = xs  # (N, B, S) replicated over pod
            my_tok = tok[jnp.clip(pod, 0, n_clients - 1)]

            def client_fwd(r):
                x = embed_tokens(cfg, params, my_tok, dtype)
                h = run_blocks(cfg, my_blocks, x, positions,
                               adapters=my_adapters)
                # slot 0 carries this client's payload to the ship ops
                out = jnp.zeros_like(r)
                return out.at[0].set(h)

            def server_fwd(r):
                # batched stage execution over the N arrivals
                hs = r.reshape((n_clients * b_local, seq, cfg.d_model))
                hs = run_blocks(cfg, my_blocks, hs, positions,
                                adapters=my_adapters)
                return hs.reshape(r.shape)

            h_all = jax.lax.cond(is_server, server_fwd, client_fwd, recv)

            # one ship per link (a shared destination cannot be grouped
            # into one ppermute); link c moves pod c's slot-0 activation
            # to the server, which files it under arrival slot c
            recv_new = jnp.zeros_like(recv)
            for link in links:
                y = link.ship(h_all[0], "pod")
                recv_new = recv_new.at[link.client].set(
                    jnp.where(is_server, y.astype(recv.dtype),
                              recv_new[link.client]))

            def server_ce(hh):
                return jax.vmap(lambda h, l: head_ce(cfg, params, h, l))(
                    hh, lab)

            ces = jax.lax.cond(
                is_server, server_ce,
                lambda hh: jnp.zeros((n_clients,), jnp.float32), h_all)
            return recv_new, ces

        # 1-tick fill: microbatch t ships at tick t, is served at t+1
        pad_tok = jnp.zeros((1,) + tokens.shape[1:], tokens.dtype)
        tok_feed = jnp.concatenate([tokens, pad_tok], axis=0)
        pad_lab = jnp.full((1,) + labels.shape[1:], IGNORE, labels.dtype)
        lab_feed = jnp.concatenate([pad_lab, labels], axis=0)

        init = jnp.zeros((n_clients, b_local, seq, cfg.d_model), dtype)
        _, ces = jax.lax.scan(tick, init, (tok_feed, lab_feed))
        per_client = jax.lax.pmean(
            jax.lax.psum(jnp.sum(ces, axis=0), "pod"), "data") / n_micro
        loss = jnp.mean(per_client)
        return (loss, per_client,
                jnp.asarray(wire["fwd_tick"], jnp.float32))

    return step


def build_hub_grad_step(cfg: ArchConfig, mesh, hub: HubConfig,
                        n_micro: int, micro_batch: int, seq: int,
                        lora_rank: int = 0):
    """Differentiates the hub loss wrt the stage parameters.  The shared
    server stage accumulates gradients from every client's batched
    execution; each client's cotangent returns across its own link
    (quantized when ``hub.bwd_quant`` is set).  Returns
    fn(params, tokens, labels) -> (loss, per_client_ce, grads, bytes).

    ``lora_rank > 0`` (SplitLoRA): differentiates wrt
    ``params["adapters"]`` only, and the returned/applied gradient
    traffic shrinks to the adapter-grad payload: each client link
    round-trips its stage's quantized adapter-grad tree across the wire
    (``hub.grad_quant`` codec; see ``core.split.grad_return_trip``) and
    the DECODED gradients are what the optimizer applies — the traffic is
    real collective-permutes, asserted against HLO by the extended
    ``assert_links_match_hlo``.
    """
    step = build_hub_step(cfg, mesh, hub, n_micro, micro_batch, seq,
                          lora_rank=lora_rank)
    wire = hub_wire_bytes(cfg, hub, micro_batch, seq,
                          data_shards=mesh.shape["data"],
                          lora_rank=lora_rank)
    tick_bytes = float(wire["fwd_tick"] + wire["bwd_tick"])

    if lora_rank == 0:
        def grad_step(params, tokens, labels):
            def loss_fn(p):
                loss, per_client, _ = step(p, tokens, labels)
                return loss, per_client

            (loss, per_client), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            return loss, per_client, grads, jnp.asarray(tick_bytes,
                                                        jnp.float32)

        return grad_step

    # -- SplitLoRA: adapter-grad-only gradient return over the real wire
    n_clients = hub.n_clients
    links = hub.links()
    ad_specs = stage_param_specs(cfg, n_clients + 1, cfg.n_layers // 2,
                                 lora_rank=lora_rank)["adapters"]

    @partial(shard_map, mesh=mesh, in_specs=(ad_specs,),
             out_specs=ad_specs, check_rep=False)
    def grad_return(g):
        # every pod holds its own stage's adapter-grad slice; each client
        # link round-trips that slice (encode -> ship to server -> server
        # returns the accepted payload -> decode) so the grads the
        # optimizer sees have honestly crossed the codec both ways.  The
        # server's own adapter grads are local (no wire).
        pod = jax.lax.axis_index("pod")
        g0 = jax.tree_util.tree_map(lambda a: a[0], g)
        out = g0
        for link in links:
            trip = link.grad_trip(g0, "pod")
            out = jax.tree_util.tree_map(
                lambda t, o: jnp.where(pod == link.src, t, o), trip, out)
        return jax.tree_util.tree_map(lambda a: a[None], out)

    def grad_step(params, tokens, labels):
        base = {k: v for k, v in params.items() if k != "adapters"}

        def loss_fn(ad):
            loss, per_client, _ = step(dict(base, adapters=ad),
                                       tokens, labels)
            return loss, per_client

        (loss, per_client), g_ad = jax.value_and_grad(
            loss_fn, has_aux=True)(params["adapters"])
        g_ad = grad_return(g_ad)
        return loss, per_client, g_ad, jnp.asarray(tick_bytes,
                                                   jnp.float32)

    return grad_step


# ---------------------------------------------------------------------------
# async mode: per-arrival server updates, staleness-tolerant clients
# ---------------------------------------------------------------------------

def arrival_mask(tick_rates: Tuple[int, ...],
                 n_ticks: int) -> np.ndarray:
    """(n_ticks, n_clients) bool: client c arrives when t % rate_c == 0."""
    t = np.arange(n_ticks)[:, None]
    rates = np.asarray(tick_rates)[None, :]
    return (t % rates) == 0


def init_hub_state(key, cfg: ArchConfig, hub: HubConfig,
                   opt_cfg: AdamWConfig, lora_rank: int = 0) -> Dict:
    """Async-hub training state.

    ``server``: the shared pieces (server blocks, embed table, head, final
    norm) with one optimizer, stepped per arrival.  ``client``: the
    per-client bottom-half block stacks (N, L/2, ...) with per-client
    AdamW moments and step counts — a client's state only advances when
    its own gradient arrives.  ``calib``: per-client wire calibration
    EMAs (N-stacked :func:`~repro.core.split.init_wire_calib`), isolated
    per client.

    ``lora_rank > 0`` (SplitLoRA): every block stack is frozen; the state
    instead carries ``client_adapters`` (N-stacked LoRA trees) and the
    server params gain an ``"adapters"`` entry, with BOTH optimizers
    sized by the adapter trees only.
    """
    from repro.train.loop import TrainState

    n = hub.n_clients
    params = init_stage_params(key, cfg, n + 1, cfg.n_layers // 2,
                               lora_rank=lora_rank)
    client_blocks = jax.tree_util.tree_map(lambda a: a[:n],
                                           params["blocks"])
    server_params = dict(
        blocks=jax.tree_util.tree_map(lambda a: a[n], params["blocks"]),
        embed=params["embed"], head=params["head"],
        final_norm=params["final_norm"])
    calib = jax.tree_util.tree_map(
        lambda z: jnp.zeros((n,) + z.shape, z.dtype), init_wire_calib())
    if lora_rank > 0:
        client_adapters = jax.tree_util.tree_map(lambda a: a[:n],
                                                 params["adapters"])
        server_params["adapters"] = jax.tree_util.tree_map(
            lambda a: a[n], params["adapters"])
        client_opt = init_opt_state(client_adapters, opt_cfg)
        client_opt["step"] = jnp.zeros((n,), jnp.int32)
        return dict(
            server=TrainState(
                params=server_params,
                opt=init_opt_state(server_params["adapters"], opt_cfg),
                step=jnp.zeros((), jnp.int32)),
            client_params=client_blocks,
            client_adapters=client_adapters,
            client_opt=client_opt,
            calib=calib,
        )
    client_opt = init_opt_state(client_blocks, opt_cfg)
    client_opt["step"] = jnp.zeros((n,), jnp.int32)
    return dict(
        server=TrainState(params=server_params,
                          opt=init_opt_state(server_params, opt_cfg),
                          step=jnp.zeros((), jnp.int32)),
        client_params=client_blocks,
        client_opt=client_opt,
        calib=calib,
    )


def build_async_update(cfg: ArchConfig, hub: HubConfig,
                       opt_cfg: AdamWConfig, micro_batch: int, seq: int,
                       calib_decay: float = 0.9, lora_rank: int = 0):
    """One global tick of the async hub, mask-gated per arrival.

    Returns fn(state, tokens, labels, mask) -> (state, metrics) with
    ``tokens``/``labels`` (N, B, S) int32 and ``mask`` (N,) float32 — 1
    for clients whose microbatch arrives this tick.  The mask is a traced
    operand, so ONE compiled update serves every arrival pattern (no
    recompile as tick rates interleave).

    Per tick: every client's bottom half runs on its (possibly stale)
    parameters against the CURRENT server; arrivals cross the in-graph
    wire (STE roundtrip forward, ``quantize_cotangent`` backward when
    ``hub.bwd_quant`` is set); the server executes ONCE batched over all
    N slots and applies the mask-aggregated gradient immediately
    (per-arrival update); each arriving client then applies its returned
    gradient and advances its calibration EMA.  Non-arriving clients are
    fully gated: zero loss weight, no parameter/moment/step/calib change.
    """
    from repro.train.loop import TrainState, apply_gradients

    n = hub.n_clients
    links = hub.links()
    positions = jnp.arange(seq, dtype=jnp.int32)
    dtype = tf.cdtype(cfg)

    if lora_rank > 0:
        return _build_async_lora_update(cfg, hub, opt_cfg, micro_batch,
                                        seq, calib_decay)

    def update(state, tokens, labels, mask):
        def loss_fn(server_params, client_blocks):
            x = embed_tokens(cfg, server_params, tokens, dtype)  # (N,B,S,D)
            h_pre, h_q = [], []
            for c, link in enumerate(links):
                blocks_c = jax.tree_util.tree_map(lambda a: a[c],
                                                  client_blocks)
                hc = run_blocks(cfg, blocks_c, x[c], positions)
                h_hat, _ = quantizers.roundtrip(link.quant, hc)
                if link.bwd_quant is not None:
                    h_hat = quantize_cotangent(link.bwd_quant, h_hat)
                h_pre.append(hc)
                h_q.append(h_hat)
            h_pre = jnp.stack(h_pre)
            h_q = jnp.stack(h_q)
            # batched shared-server stage execution over all N slots
            hs = h_q.reshape((n * micro_batch, seq, cfg.d_model))
            hs = run_blocks(cfg, server_params["blocks"], hs, positions)
            h_out = hs.reshape((n, micro_batch, seq, cfg.d_model))
            ces = jnp.stack([head_ce(cfg, server_params, h_out[c],
                                     labels[c]) for c in range(n)])
            loss = jnp.sum(ces * mask) / jnp.maximum(jnp.sum(mask), 1.0)
            return loss, (ces, h_pre, h_q)

        (loss, (ces, h_pre, h_q)), grads = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(
                state["server"].params, state["client_params"])
        g_server, g_client = grads

        # per-arrival server update: the shared stack aggregates exactly
        # this tick's arrivals (the mask already zeroed everyone else);
        # with no arrivals at all, the server holds still
        server_new, opt_metrics = apply_gradients(state["server"],
                                                  g_server, opt_cfg)
        any_arrival = jnp.sum(mask) > 0.0
        server = jax.tree_util.tree_map(
            lambda a, b: jnp.where(any_arrival, a, b),
            server_new, state["server"])

        # per-client updates, gated: a non-arriving client's params,
        # moments, step count and calibration are bit-identical before
        # and after (AdamW with a zero grad would still decay weights
        # and moments — that would leak training into idle clients)
        def one_client(p, g, m, v, s):
            newp, news, _ = adamw_update(p, g, dict(m=m, v=v, step=s),
                                         opt_cfg, 1.0)
            return newp, news["m"], news["v"], news["step"]

        newp, newm, newv, news = jax.vmap(one_client)(
            state["client_params"], g_client, state["client_opt"]["m"],
            state["client_opt"]["v"], state["client_opt"]["step"])

        def gate(new, old):
            m = mask.reshape((n,) + (1,) * (new.ndim - 1))
            return jnp.where(m > 0.0, new, old)

        client_params = jax.tree_util.tree_map(gate, newp,
                                               state["client_params"])
        client_opt = dict(
            m=jax.tree_util.tree_map(gate, newm, state["client_opt"]["m"]),
            v=jax.tree_util.tree_map(gate, newv, state["client_opt"]["v"]),
            step=gate(news, state["client_opt"]["step"]),
        )

        calib_new = jax.vmap(partial(update_wire_calib,
                                     decay=calib_decay))(state["calib"],
                                                         h_pre)
        calib = jax.tree_util.tree_map(gate, calib_new, state["calib"])

        # per-client relative reconstruction error of the forward wire —
        # the calibration-isolation tests compare this against solo runs
        num = jnp.mean(jnp.square(h_pre - h_q), axis=(1, 2, 3))
        den = jnp.mean(jnp.square(h_pre), axis=(1, 2, 3)) + 1e-12
        metrics = dict(loss=loss, ces=ces, quant_rel_err=num / den,
                       mask=mask, grad_norm=opt_metrics["grad_norm"])
        return (dict(server=server, client_params=client_params,
                     client_opt=client_opt, calib=calib), metrics)

    return jax.jit(update)


def _build_async_lora_update(cfg: ArchConfig, hub: HubConfig,
                             opt_cfg: AdamWConfig, micro_batch: int,
                             seq: int, calib_decay: float = 0.9):
    """SplitLoRA async tick: the adapter-only twin of
    :func:`build_async_update`.

    Base block stacks (client AND server) plus embed/head/norm are
    frozen by construction — autodiff runs wrt the adapter trees only,
    so the state's optimizers are sized by adapter params.  When
    ``hub.grad_quant`` is set, every client's adapter gradient crosses
    the codec (encode -> decode, the in-graph twin of the lockstep
    schedulers' collective grad-return wire) before it is applied.
    """
    from repro.train.loop import apply_adapter_gradients

    n = hub.n_clients
    links = hub.links()
    positions = jnp.arange(seq, dtype=jnp.int32)
    dtype = tf.cdtype(cfg)

    def _grad_roundtrip(g_client):
        if hub.grad_quant is None:
            return g_client
        q = hub.grad_quant

        def one(leaf):  # leading axis = client
            return jax.vmap(lambda v: quantizers.decode(
                q, quantizers.encode(q, v)).astype(v.dtype))(leaf)

        return jax.tree_util.tree_map(one, g_client)

    def update(state, tokens, labels, mask):
        client_blocks = state["client_params"]  # frozen base halves
        server_base = state["server"].params    # frozen base + adapters

        def loss_fn(server_adapters, client_adapters):
            x = embed_tokens(cfg, server_base, tokens, dtype)  # (N,B,S,D)
            h_pre, h_q = [], []
            for c, link in enumerate(links):
                blocks_c = jax.tree_util.tree_map(lambda a: a[c],
                                                  client_blocks)
                ad_c = jax.tree_util.tree_map(lambda a: a[c],
                                              client_adapters)
                hc = run_blocks(cfg, blocks_c, x[c], positions,
                                adapters=ad_c)
                h_hat, _ = quantizers.roundtrip(link.quant, hc)
                if link.bwd_quant is not None:
                    h_hat = quantize_cotangent(link.bwd_quant, h_hat)
                h_pre.append(hc)
                h_q.append(h_hat)
            h_pre = jnp.stack(h_pre)
            h_q = jnp.stack(h_q)
            hs = h_q.reshape((n * micro_batch, seq, cfg.d_model))
            hs = run_blocks(cfg, server_base["blocks"], hs, positions,
                            adapters=server_adapters)
            h_out = hs.reshape((n, micro_batch, seq, cfg.d_model))
            ces = jnp.stack([head_ce(cfg, server_base, h_out[c],
                                     labels[c]) for c in range(n)])
            loss = jnp.sum(ces * mask) / jnp.maximum(jnp.sum(mask), 1.0)
            return loss, (ces, h_pre, h_q)

        (loss, (ces, h_pre, h_q)), grads = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(
                server_base["adapters"], state["client_adapters"])
        g_server_ad, g_client_ad = grads
        # the quantized gradient return: adapter grads only
        g_client_ad = _grad_roundtrip(g_client_ad)

        server_new, opt_metrics = apply_adapter_gradients(
            state["server"], g_server_ad, opt_cfg)
        any_arrival = jnp.sum(mask) > 0.0
        server = jax.tree_util.tree_map(
            lambda a, b: jnp.where(any_arrival, a, b),
            server_new, state["server"])

        def one_client(p, g, m, v, s):
            newp, news, _ = adamw_update(p, g, dict(m=m, v=v, step=s),
                                         opt_cfg, 1.0)
            return newp, news["m"], news["v"], news["step"]

        newp, newm, newv, news = jax.vmap(one_client)(
            state["client_adapters"], g_client_ad,
            state["client_opt"]["m"], state["client_opt"]["v"],
            state["client_opt"]["step"])

        def gate(new, old):
            m = mask.reshape((n,) + (1,) * (new.ndim - 1))
            return jnp.where(m > 0.0, new, old)

        client_adapters = jax.tree_util.tree_map(
            gate, newp, state["client_adapters"])
        client_opt = dict(
            m=jax.tree_util.tree_map(gate, newm, state["client_opt"]["m"]),
            v=jax.tree_util.tree_map(gate, newv, state["client_opt"]["v"]),
            step=gate(news, state["client_opt"]["step"]),
        )

        calib_new = jax.vmap(partial(update_wire_calib,
                                     decay=calib_decay))(state["calib"],
                                                         h_pre)
        calib = jax.tree_util.tree_map(gate, calib_new, state["calib"])

        num = jnp.mean(jnp.square(h_pre - h_q), axis=(1, 2, 3))
        den = jnp.mean(jnp.square(h_pre), axis=(1, 2, 3)) + 1e-12
        metrics = dict(loss=loss, ces=ces, quant_rel_err=num / den,
                       mask=mask, grad_norm=opt_metrics["grad_norm"])
        return (dict(server=server, client_params=client_blocks,
                     client_adapters=client_adapters,
                     client_opt=client_opt, calib=calib), metrics)

    return jax.jit(update)


def async_tick_stream(batches: Iterable, tick_rates: Tuple[int, ...],
                      n_ticks: int):
    """Host-side arrival schedule: yields (tick, mask, (tokens, labels)).

    ``batches`` yields (tokens, labels) of shape (N, B, S) — one
    candidate microbatch per client per global tick; the mask says whose
    actually arrives (non-arriving clients' slots are computed but fully
    gated in :func:`build_async_update`).
    """
    pattern = arrival_mask(tick_rates, n_ticks)
    it = iter(batches)
    for t in range(n_ticks):
        yield t, pattern[t].astype(np.float32), next(it)
