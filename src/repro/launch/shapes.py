"""Assigned input shapes + ShapeDtypeStruct input specs per (arch, shape).

The four assigned shapes:

    train_4k     seq=4096    global_batch=256   (training -> train_step)
    prefill_32k  seq=32768   global_batch=32    (inference prefill)
    decode_32k   seq=32768   global_batch=128   (1 token vs 32k KV cache)
    long_500k    seq=524288  global_batch=1     (1 token, sub-quadratic)

Decode shapes lower ``serve_step`` — ONE new token against a cache of
``seq_len`` — never ``train_step``.  long_500k engages each architecture's
sub-quadratic path: SSM/hybrid state recurrence, or the sliding-window
ring-buffer cache for full-attention architectures (window = config's
sliding_window, cache length = window).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tf


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    batch: int


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}


def window_for(cfg: ArchConfig, shape: InputShape) -> Optional[int]:
    """Sliding window is engaged only for the long-context decode shape."""
    if shape.name == "long_500k":
        return cfg.sliding_window
    return None


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _seq_batch_specs(cfg: ArchConfig, b: int, s: int,
                     with_labels: bool) -> Dict:
    """ShapeDtypeStructs for a full-sequence batch of one modality."""
    if cfg.modality == "vlm":
        text = max(8, s - cfg.n_image_tokens)
        out = dict(
            image_embeds=sds((b, cfg.n_image_tokens, cfg.d_vision),
                             jnp.bfloat16),
            tokens=sds((b, text), jnp.int32),
            positions=sds((cfg.n_image_tokens + text,), jnp.int32),
        )
        if with_labels:
            out["labels"] = sds((b, cfg.n_image_tokens + text), jnp.int32)
        return out
    if cfg.modality == "audio":
        out = dict(codes=sds((b, cfg.n_codebooks, s), jnp.int32),
                   positions=sds((s,), jnp.int32))
        if with_labels:
            out["labels_codes"] = sds((b, cfg.n_codebooks, s), jnp.int32)
        return out
    out = dict(tokens=sds((b, s), jnp.int32), positions=sds((s,), jnp.int32))
    if with_labels:
        out["labels"] = sds((b, s), jnp.int32)
    return out


def _decode_batch_specs(cfg: ArchConfig, b: int) -> Dict:
    if cfg.modality == "audio":
        return dict(codes=sds((b, cfg.n_codebooks, 1), jnp.int32))
    return dict(tokens=sds((b, 1), jnp.int32))


def cache_specs(cfg: ArchConfig, batch: int, cache_len: int):
    """Cache ShapeDtypeStructs without allocating anything."""
    return jax.eval_shape(
        lambda: tf.init_caches(cfg, batch, cache_len, jnp.bfloat16))


def input_specs(cfg: ArchConfig, shape: InputShape) -> Dict:
    """Step-function input ShapeDtypeStructs for one (arch, shape) pair."""
    win = window_for(cfg, shape)
    if shape.kind == "train":
        return dict(batch=_seq_batch_specs(cfg, shape.batch, shape.seq_len,
                                           with_labels=True))
    if shape.kind == "prefill":
        return dict(batch=_seq_batch_specs(cfg, shape.batch, shape.seq_len,
                                           with_labels=False))
    if shape.kind == "decode":
        clen = shape.seq_len if win is None else min(shape.seq_len, win)
        return dict(
            caches=cache_specs(cfg, shape.batch, clen),
            batch=_decode_batch_specs(cfg, shape.batch),
            qpos=sds((shape.batch,), jnp.int32),
        )
    raise ValueError(shape.kind)
