"""Cross-pod split learning: the paper's deployment, TPU-native.

The paper runs the client on one GPU box and the server on another,
shipping pickled activations over TCP.  The TPU-idiomatic equivalent
(DESIGN.md SS3) maps the partitions onto the ``pod`` mesh axis and
streams microbatches GPipe-style.  For the paper's 2-partition case:

  pod 0 (client): embed + layers[:L/2] -> quantize -> pack -> ppermute
  pod 1 (server): dequantize -> layers[L/2:] -> head -> next-token CE

This module is now a thin composition of the three split-stack layers
(the monolith it used to be was refactored apart, ROADMAP item 2):

  * stage programs — ``repro.core.split_stage`` (what a partition runs)
  * wire links     — ``repro.core.split.WireLink`` (how cuts ship)
  * schedulers     — ``repro.launch.schedules`` (who ticks when)

The public API is unchanged: ``build_pipeline_step`` /
``build_pipeline_grad_step`` build the N-stage lockstep GPipe schedule
(``SplitConfig.n_stages`` equal partitions, per-cut ``stage_quants``),
``train_pipeline`` runs AdamW over it, and the __main__ dry-run asserts
the static wire accounting against the lowered HLO.  The paper's
2-partition case is also exactly ``launch/split_hub.py`` with one
client (loss parity is tested to 3e-6).

``pipeline_wire_bytes`` now reports PER-LINK bytes (each link counted
once, on the devices that execute it) instead of summing one payload
per distinct cut config over every device — the SPMD accounting fix
for heterogeneous ``stage_quants``.  Accordingly the dry-run asserts
each link's bytes against the HLO collective-permute traffic of that
link's device pairs (``hlo_analysis.collective_permute_pairs``), which
also lets it cover mixed 2-bit/4-bit topologies.

Run the dry-run (512 fake devices, multi-pod mesh):
    PYTHONPATH=src python -m repro.launch.split_pipeline
Fast CI variant (8 fake devices, reduced config, 4-stage topology):
    PYTHONPATH=src python -m repro.launch.split_pipeline --smoke
"""
import os
import sys

if __name__ == "__main__":  # must run before any jax import
    _n_dev = 8 if "--smoke" in sys.argv else 512
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={_n_dev}"

# ruff: noqa: E402
import dataclasses
import functools
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.core.quantizers import QuantConfig
from repro.core.split import SplitConfig
from repro.core.split_stage import init_stage_params, stage_param_specs
from repro.launch import schedules
from repro.optim import AdamWConfig, init_opt_state


def _as_split(q) -> SplitConfig:
    """Accept a bare QuantConfig (the paper's 2-stage case) or a full
    SplitConfig describing an N-stage topology."""
    if isinstance(q, SplitConfig):
        return q
    return SplitConfig(quant=q, learnable_codec=False)


def _homogeneous_cfg(arch: str = "llama3_2_3b", reduced: bool = False,
                     n_stages: int = 2) -> ArchConfig:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
        if cfg.n_layers % n_stages:
            # reduced() pins 2 layers; deeper-than-2-stage smoke
            # topologies need one layer per stage
            cfg = dataclasses.replace(cfg, n_layers=n_stages)
    assert all(t == "dense" for t in cfg.block_pattern()), \
        "pipeline stages must be structurally identical"
    assert cfg.n_layers % n_stages == 0, \
        f"{cfg.n_layers} layers do not divide into {n_stages} stages"
    return cfg


def init_pipeline_params(key, cfg: ArchConfig, n_stages: int = 2,
                         lora_rank: int = 0) -> Dict:
    """Stage-stacked parameters: blocks (N, L/N, ...); embed/head shared.
    ``lora_rank > 0`` adds the stage-stacked ``"adapters"`` LoRA tree."""
    return init_stage_params(key, cfg, n_stages, lora_rank=lora_rank)


def pipeline_specs(cfg: ArchConfig, n_stages: int = 2,
                   lora_rank: int = 0) -> Dict:
    """shard_map in_specs for the parameter tree."""
    return stage_param_specs(cfg, n_stages, lora_rank=lora_rank)


def pipeline_wire_bytes(cfg: ArchConfig, split, micro_batch: int, seq: int,
                        bwd_qcfg: Optional[QuantConfig] = None,
                        data_shards: int = 1) -> Dict:
    """Per-link static wire bytes of the pipeline, from payload shapes.

    ``data_shards`` is the mesh's data-axis size: the microbatch is
    sharded over it, so each device encodes and ships a
    ``micro_batch / data_shards`` slice.  Returns the
    ``schedules.chain_wire_bytes`` table: ``links[(src, dst)]`` is each
    cut's FULL per-tick traffic (slice x data shards — the quantity the
    dry-run asserts against the HLO collective-permute bytes of that
    link's device pairs); ``fwd_tick`` / ``bwd_tick`` are the per-device
    per-tick bytes — the MAX over links of the device's payload slice,
    since a device sources at most one cut per tick.  The old sum over
    distinct cut configs charged every device with every cut's payload,
    overcounting heterogeneous ``stage_quants`` topologies.
    """
    return schedules.chain_wire_bytes(cfg, _as_split(split), micro_batch,
                                      seq, bwd_qcfg,
                                      data_shards=data_shards)


def build_pipeline_step(cfg: ArchConfig, mesh, split, n_micro: int,
                        micro_batch: int, seq: int,
                        bwd_qcfg: Optional[QuantConfig] = None,
                        lora_rank: int = 0):
    """Returns a jit-able fn(params, tokens, labels) -> (loss, wire_bytes).

    ``tokens``/``labels`` are (n_micro, B, S) int32; ``loss`` is the
    next-token cross-entropy computed by the last stage, averaged over
    the ``n_micro`` microbatches; ``wire_bytes`` is the per-device
    per-tick forward wire payload in bytes — a compile-time constant
    derived from the static ``CommPayload`` shapes (NOT a measured
    quantity; the dry-run asserts it against the lowered HLO).
    """
    return schedules.build_gpipe_step(cfg, mesh, _as_split(split), n_micro,
                                      micro_batch, seq, bwd_qcfg=bwd_qcfg,
                                      lora_rank=lora_rank)


def build_pipeline_grad_step(cfg, mesh, split, bwd_qcfg, n_micro,
                             micro_batch, seq, lora_rank: int = 0):
    """Like build_pipeline_step but differentiates the pipeline loss wrt
    the stage parameters, exercising the gradient-return wire.

    Returns fn(params, tokens, labels) -> (loss, grads, wire_bytes) with
    ``wire_bytes`` the per-device per-tick forward + backward payload
    (compile-time constant, same contract as build_pipeline_step).
    ``lora_rank > 0`` differentiates wrt the adapter tree only (``grads``
    mirrors ``params["adapters"]``).
    """
    return schedules.build_gpipe_grad_step(cfg, mesh, _as_split(split),
                                           bwd_qcfg, n_micro, micro_batch,
                                           seq, lora_rank=lora_rank)


@functools.lru_cache(maxsize=16)
def _cached_pipeline_update(cfg: ArchConfig, mesh, split: SplitConfig,
                            bwd_qcfg: Optional[QuantConfig],
                            opt_cfg: AdamWConfig, n_micro: int,
                            micro_batch: int, seq: int, warmup_steps: int,
                            total_steps: int, lora_rank: int = 0):
    """One jitted (grad step + AdamW apply) per pipeline configuration.

    Same pattern as ``serve/decode._compiled_serve_step``: every config
    in the key is a frozen (hashable) dataclass and ``jax.Mesh`` hashes
    by value, so repeated ``train_pipeline`` calls — resumed runs, sweep
    loops — reuse one traced update instead of rebuilding the shard_map
    closure and re-jitting per call (the recompile cost noted in ROADMAP
    item 1).  ``lora_rank`` joins the cache key: the SplitLoRA update
    differentiates and steps the adapter tree only.
    """
    from repro.train.loop import apply_adapter_gradients, apply_gradients

    grad_step = build_pipeline_grad_step(cfg, mesh, split, bwd_qcfg,
                                         n_micro, micro_batch, seq,
                                         lora_rank=lora_rank)

    @jax.jit
    def update(state, tokens, labels):
        loss, grads, wire_b = grad_step(state.params, tokens, labels)
        if lora_rank > 0:
            state, _ = apply_adapter_gradients(state, grads, opt_cfg,
                                               warmup_steps=warmup_steps,
                                               total_steps=total_steps)
        else:
            state, _ = apply_gradients(state, grads, opt_cfg,
                                       warmup_steps=warmup_steps,
                                       total_steps=total_steps)
        return state, loss, wire_b

    return update


def train_pipeline(cfg: ArchConfig, mesh, split, opt_cfg: AdamWConfig,
                   batches: Iterable[Tuple[jnp.ndarray, jnp.ndarray]], *,
                   n_micro: int, micro_batch: int, seq: int,
                   bwd_qcfg: Optional[QuantConfig] = None,
                   params: Optional[Dict] = None,
                   warmup_steps: int = 0, total_steps: int = 0,
                   seed: int = 0,
                   wire_budget_bytes: Optional[float] = None,
                   plan_groups: int = 8, replan_every: int = 1,
                   entropy_decay: float = 0.9,
                   plan_log: Optional[List] = None,
                   lora_rank: int = 0
                   ) -> Tuple[Dict, Dict, List[float], float]:
    """AdamW training loop over the N-stage quantized pipeline.

    Each element of ``batches`` is a (tokens, labels) pair of shape
    (n_micro, B, S); one optimizer step consumes one element, with the
    pipeline scan playing the role of microbatch gradient accumulation
    (the per-tick CE terms sum into one loss before differentiation).
    The update is ``train.loop.apply_gradients`` — the same scheduled
    AdamW the monolithic trainer uses (``total_steps == 0`` = constant
    lr) — compiled once per configuration via the lru cache above.
    Returns (params, opt_state, per-step losses, wire bytes/tick).

    Entropy-adaptive wire (ROADMAP item 3): passing ``wire_budget_bytes``
    turns on re-planning BETWEEN compiled steps.  Every ``replan_every``
    steps the stage-0 boundary activation is probed on the incoming
    microbatch (``schedules.boundary_probe``), a per-channel EMA entropy
    estimate advances, and the greedy allocator turns it into a
    ``plan_groups``-group width plan under the per-device code-byte
    budget.  The plan rides on the cuts' ``QuantConfig.group_widths``
    (hashable), so the lru cache above compiles once per DISTINCT plan
    and re-planning to a previously seen plan is a cache hit, not a
    recompile.  ``plan_log`` (optional list) receives (step, plan)
    tuples whenever the plan changes.

    SplitLoRA (ROADMAP item 4): ``lora_rank > 0`` freezes the base stage
    weights and trains only the LoRA adapter tree — the gradient step
    differentiates wrt ``params["adapters"]`` alone and the optimizer
    moments are sized by the adapter params (``init_adapter_state``).
    """
    from repro.core import entropy as entropy_mod
    from repro.train.loop import TrainState, init_adapter_state

    split = _as_split(split)
    adaptive = wire_budget_bytes is not None
    if adaptive and split.quant.method not in ("fsq", "rdfsq", "nf"):
        raise ValueError(
            f"adaptive wire needs a grouped-capable codec, not "
            f"{split.quant.method!r}")
    update = _cached_pipeline_update(cfg, mesh, split, bwd_qcfg, opt_cfg,
                                     n_micro, micro_batch, seq,
                                     warmup_steps, total_steps, lora_rank)
    if params is None:
        params = init_pipeline_params(jax.random.PRNGKey(seed), cfg,
                                      split.n_stages, lora_rank=lora_rank)
    if lora_rank > 0:
        state = init_adapter_state(params, opt_cfg)
    else:
        state = TrainState(params=params,
                           opt=init_opt_state(params, opt_cfg),
                           step=jnp.zeros((), jnp.int32))

    ema = entropy_mod.init_entropy_ema(cfg.d_model) if adaptive else None
    scalars_per_ch = (micro_batch // mesh.shape["data"]) * seq
    n_cuts = split.n_stages - 1
    plan: Tuple[int, ...] = ()

    history: List[float] = []
    wire_b = 0.0
    with mesh:
        for step_i, (tokens, labels) in enumerate(batches):
            if adaptive and step_i % max(replan_every, 1) == 0:
                h = schedules.boundary_probe(cfg, state.params, tokens[0])
                ema = entropy_mod.update_entropy_ema(ema, h,
                                                     decay=entropy_decay)
                new_plan = schedules.replan_widths(
                    ema, wire_budget_bytes, n_groups=plan_groups,
                    scalars_per_channel=scalars_per_ch)
                if new_plan != plan:
                    plan = new_plan
                    if plan_log is not None:
                        plan_log.append((step_i, plan))
                    split = split.with_plans((plan,) * n_cuts)
                    update = _cached_pipeline_update(
                        cfg, mesh, split, bwd_qcfg, opt_cfg, n_micro,
                        micro_batch, seq, warmup_steps, total_steps,
                        lora_rank)
            state, loss, wb = update(state, tokens, labels)
            history.append(float(loss))
            wire_b = float(wb)
    return state.params, state.opt, history, wire_b


# ---------------------------------------------------------------------------
# dry-runs
# ---------------------------------------------------------------------------

def _pipeline_mesh(n_stages: int, smoke: bool = False):
    """(pod, data[, model]) mesh with a pod axis of n_stages."""
    if smoke:
        return jax.make_mesh((n_stages, 2), ("pod", "data"))
    n_dev = len(jax.devices())
    model = max(1, n_dev // (n_stages * 16))
    return jax.make_mesh((n_stages, 16, model), ("pod", "data", "model"))


def _micro_batch_sds(n_micro, micro_batch, seq):
    tok = jax.ShapeDtypeStruct((n_micro, micro_batch, seq), jnp.int32)
    return tok, tok


def assert_links_match_hlo(name: str, hlo_text: str, mesh, wire: Dict,
                           n_ticks: int, check_bwd: bool = False,
                           check_grad: bool = False) -> None:
    """Per-link wire assertion: for every link the static CommPayload
    bytes (x scan ticks) must match the HLO collective-permute bytes
    attributed to that link's device pairs, within 1%.  ``check_bwd``
    additionally asserts the gradient-return direction (dst -> src).
    ``check_grad`` adds each link's quantized adapter-grad return trip
    (SplitLoRA) — one round trip per STEP, not per tick, so the grad
    payload is added once to each direction's expected total."""
    from repro.launch.hlo_analysis import collective_permute_pairs

    by_link = schedules.pod_link_bytes(
        collective_permute_pairs(hlo_text), mesh)
    for (src, dst), entry in sorted(wire["links"].items()):
        grad_b = entry.get("grad", 0) if check_grad else 0
        checks = [("fwd", (src, dst), entry["fwd"] * n_ticks + grad_b)]
        if check_bwd:
            checks.append(("bwd", (dst, src),
                           entry["bwd"] * n_ticks + grad_b))
        for direction, key, expected in checks:
            got = by_link.get(key, 0)
            rel = abs(got - expected) / max(expected, 1)
            print(f"[split-pipeline {name}] link {key[0]}->{key[1]} "
                  f"({direction}, {entry['quant']}-{entry['bits']}bit): "
                  f"HLO {got / 2 ** 20:.3f} MiB vs static "
                  f"{expected / 2 ** 20:.3f} MiB (rel err {rel:.4f})")
            assert rel < 0.01, (
                f"{name} link {key}: HLO collective-permute bytes {got} "
                f"disagree with static accounting {expected} "
                f"(rel err {rel:.3f})")


def dryrun(arch: str = "llama3_2_3b", n_micro: int = 4,
           micro_batch: int = 32, seq: int = 1024,
           bits_list=(16, 4, 2), n_stages: int = 2,
           reduced: bool = False, smoke: bool = False) -> Dict:
    """Lower + compile the N-stage pipeline on the multi-pod mesh, measure
    the collective-permute bytes per bit-width, and assert every link
    matches the static CommPayload wire accounting."""
    from repro.launch.hlo_analysis import analyze

    mesh = _pipeline_mesh(n_stages, smoke=smoke)
    cfg = _homogeneous_cfg(arch, reduced=reduced, n_stages=n_stages)
    params_sds = jax.eval_shape(
        lambda: init_pipeline_params(jax.random.PRNGKey(0), cfg, n_stages))
    tok_sds, lab_sds = _micro_batch_sds(n_micro, micro_batch, seq)
    n_ticks = n_micro + n_stages - 1

    results = {}
    for bits in bits_list:
        method = "identity" if bits == 16 else "rdfsq"
        split = SplitConfig(quant=QuantConfig(method=method,
                                              bits=min(bits, 8)),
                            learnable_codec=False, n_stages=n_stages)
        step = build_pipeline_step(cfg, mesh, split, n_micro, micro_batch,
                                   seq)
        with mesh:
            compiled = jax.jit(step).lower(params_sds, tok_sds,
                                           lab_sds).compile()
        hlo = compiled.as_text()
        hl = analyze(hlo)
        cp = hl["collective_by_op"].get("collective-permute", 0)
        wire = pipeline_wire_bytes(cfg, split, micro_batch, seq,
                                   data_shards=mesh.shape["data"])
        assert_links_match_hlo(f"{arch} {method}-{bits}bit N={n_stages}",
                               hlo, mesh, wire, n_ticks)
        results[bits] = dict(
            collective_permute_bytes=cp,
            wire_bytes_per_tick=wire["fwd_tick"],
            wire_links={f"{s}->{d}": v["fwd"]
                        for (s, d), v in wire["links"].items()},
            total_collective_bytes=hl["collective_bytes"],
            peak_gib=compiled.memory_analysis().temp_size_in_bytes / 2 ** 30,
        )
        print(f"[split-pipeline {arch} {method}-{bits}bit N={n_stages}] "
              f"collective-permute/dev = {cp / 2 ** 20:.2f} MiB "
              f"(total coll {hl['collective_bytes'] / 2 ** 20:.1f} MiB)")
    if 16 in results and 2 in results:
        r = 1 - results[2]["collective_permute_bytes"] / \
            max(results[16]["collective_permute_bytes"], 1)
        print(f"[split-pipeline] 2-bit wire reduction vs 16-bit: {r:.4f} "
              f"(paper claims 0.875)")
        results["reduction_2bit"] = r
    return results


def dryrun_heterogeneous(arch: str = "llama3_2_3b", n_micro: int = 3,
                         micro_batch: int = 4, seq: int = 16,
                         smoke: bool = True) -> Dict:
    """Mixed 2-bit/4-bit 4-stage topology with per-link HLO assertions.

    The satellite the per-link refactor unlocks: the old per-device sum
    could not be asserted against heterogeneous ``stage_quants`` (every
    device was charged with every cut group's payload), so only
    homogeneous configs were HLO-checked.  Each link now carries its own
    quant config and its own assertion.
    """
    n_stages = 4
    mesh = _pipeline_mesh(n_stages, smoke=smoke)
    cfg = _homogeneous_cfg(arch, reduced=smoke, n_stages=n_stages)
    quants = (QuantConfig(method="rdfsq", bits=2),
              QuantConfig(method="nf", bits=4),
              QuantConfig(method="rdfsq", bits=2))
    split = SplitConfig(quant=quants[0], learnable_codec=False,
                        n_stages=n_stages, stage_quants=quants)
    params_sds = jax.eval_shape(
        lambda: init_pipeline_params(jax.random.PRNGKey(0), cfg, n_stages))
    tok_sds, lab_sds = _micro_batch_sds(n_micro, micro_batch, seq)
    n_ticks = n_micro + n_stages - 1

    step = build_pipeline_step(cfg, mesh, split, n_micro, micro_batch, seq)
    with mesh:
        compiled = jax.jit(step).lower(params_sds, tok_sds,
                                       lab_sds).compile()
    wire = pipeline_wire_bytes(cfg, split, micro_batch, seq,
                               data_shards=mesh.shape["data"])
    assert_links_match_hlo(f"{arch} mixed-2/4bit N={n_stages}",
                           compiled.as_text(), mesh, wire, n_ticks)
    return dict(wire_links={f"{s}->{d}": v["fwd"]
                            for (s, d), v in wire["links"].items()},
                wire_bytes_per_tick=wire["fwd_tick"])


def dryrun_grouped(arch: str = "llama3_2_3b", n_micro: int = 3,
                   micro_batch: int = 4, seq: int = 16,
                   smoke: bool = True) -> Dict:
    """Grouped mixed-precision wire with per-link HLO assertions.

    Two checks the exact bitstream packers unlock:

    1. **3/16 exactness** — a uniform 3-bit grouped FSQ plan (FSQ ships
       no scale side-info, so the payload is pure code bytes) must cost
       exactly 3/16 of the identity bf16 wire.  Under the old
       power-of-two slot packing it cost 4/16; the static accounting AND
       the lowered HLO collective-permute bytes now both sit at 3/16.
    2. **mixed widths** — an adaptive-shaped plan (1/2/3/8 bits across
       channel groups) lowers to a collective whose bytes match the
       static ``GroupedPayload`` accounting per link, within 1%.
    """
    from repro.launch.hlo_analysis import analyze

    n_stages = 2
    mesh = _pipeline_mesh(n_stages, smoke=smoke)
    cfg = _homogeneous_cfg(arch, reduced=smoke, n_stages=n_stages)
    params_sds = jax.eval_shape(
        lambda: init_pipeline_params(jax.random.PRNGKey(0), cfg, n_stages))
    tok_sds, lab_sds = _micro_batch_sds(n_micro, micro_batch, seq)
    n_ticks = n_micro + n_stages - 1
    assert cfg.d_model % 8 == 0, cfg.d_model

    plans = {
        "identity-bf16": QuantConfig(method="identity"),
        "fsq-3bit-grouped": QuantConfig(method="fsq",
                                        group_widths=(3,) * 8),
        "rdfsq-mixed-1238": QuantConfig(
            method="rdfsq", group_widths=(1, 2, 3, 8)),
    }
    results: Dict = {}
    for name, q in plans.items():
        split = SplitConfig(quant=q, learnable_codec=False,
                            n_stages=n_stages)
        step = build_pipeline_step(cfg, mesh, split, n_micro, micro_batch,
                                   seq)
        with mesh:
            compiled = jax.jit(step).lower(params_sds, tok_sds,
                                           lab_sds).compile()
        hlo = compiled.as_text()
        wire = pipeline_wire_bytes(cfg, split, micro_batch, seq,
                                   data_shards=mesh.shape["data"])
        assert_links_match_hlo(f"{arch} grouped {name}", hlo, mesh, wire,
                               n_ticks)
        hl = analyze(hlo)
        results[name] = dict(
            wire_bytes_per_tick=wire["fwd_tick"],
            collective_permute_bytes=hl["collective_by_op"].get(
                "collective-permute", 0),
        )

    # the exactness claim: 3-bit costs 3/16 of bf16, not the 4/16 a
    # power-of-two storage slot would charge — in the static accounting
    # AND in the compiled collective bytes
    for field in ("wire_bytes_per_tick", "collective_permute_bytes"):
        got = results["fsq-3bit-grouped"][field]
        full = results["identity-bf16"][field]
        ratio = got / max(full, 1)
        print(f"[split-pipeline grouped] 3-bit/bf16 {field} ratio "
              f"{ratio:.6f} (exact 3/16 = {3 / 16:.6f})")
        assert abs(ratio - 3.0 / 16.0) < 0.01 * (3.0 / 16.0), (
            f"3-bit grouped wire is not 3/16 of bf16 ({field}): "
            f"{got} / {full} = {ratio:.6f}")
    results["ratio_3bit"] = (results["fsq-3bit-grouped"]
                             ["collective_permute_bytes"]
                             / max(results["identity-bf16"]
                                   ["collective_permute_bytes"], 1))
    return results


def dryrun_train_adaptive(arch: str = "llama3_2_3b", n_steps: int = 6,
                          n_micro: int = 2, micro_batch: int = 4,
                          seq: int = 32, lr: float = 5e-3) -> Dict:
    """Execute the re-planning trainer end to end on the reduced config.

    Budgets the wire at ~2 bits/scalar of code bytes; the allocator
    spends them per channel group by entropy.  Asserts the loss
    decreases, at least one plan was adopted, and the adopted plans
    respect the budget (mean width <= 2 bits over 8 equal groups).
    """
    from repro.data.pipeline import make_pipeline

    n_stages = 2
    cfg = _homogeneous_cfg(arch, reduced=True, n_stages=n_stages)
    mesh = jax.make_mesh((n_stages, 2), ("pod", "data"))
    split = SplitConfig(quant=QuantConfig(method="rdfsq", bits=2),
                        learnable_codec=False, n_stages=n_stages)
    pipe = make_pipeline(cfg, n_micro * micro_batch, seq, seed=0)

    def batches():
        for _ in range(n_steps):
            b = next(pipe)
            yield (b["tokens"].reshape(n_micro, micro_batch, seq),
                   b["labels"].reshape(n_micro, micro_batch, seq))

    # 2-bit-average code budget for one device's activation slice
    budget = (micro_batch // 2) * seq * cfg.d_model * 2 / 8
    plan_log: List = []
    opt = AdamWConfig(lr=lr, weight_decay=0.0)
    _, _, history, wire_b = train_pipeline(
        cfg, mesh, split, opt, batches(), n_micro=n_micro,
        micro_batch=micro_batch, seq=seq, wire_budget_bytes=budget,
        plan_groups=8, plan_log=plan_log)
    plans = [p for _, p in plan_log]
    print(f"[split-pipeline adaptive N={n_stages}] loss "
          + " -> ".join(f"{v:.4f}" for v in history)
          + f" (wire {wire_b / 1024:.1f} KiB/tick; plans {plans})")
    assert history[-1] < history[0], \
        f"adaptive pipeline loss did not decrease: {history}"
    assert plans, "adaptive trainer never adopted a plan"
    for p in plans:
        assert len(p) == 8 and all(1 <= w <= 8 for w in p), p
        assert sum(p) / len(p) <= 2.0 + 1e-9, f"plan over budget: {p}"
    return dict(loss_history=history, wire_bytes_per_tick=wire_b,
                plans=[list(p) for p in plans])


def dryrun_backward(arch: str = "llama3_2_3b", n_micro: int = 4,
                    micro_batch: int = 32, seq: int = 1024,
                    n_stages: int = 2, reduced: bool = False,
                    smoke: bool = False) -> Dict:
    """BEYOND-PAPER: quantize the gradient-return wire too.

    The paper compresses only the forward activations (its Table 4 scope);
    the cotangent crossing back client<-server stays bf16.  Measuring the
    pipeline's total collective-permute bytes with and without 2-bit
    RD-FSQ gradient compression shows the remaining half of the wire."""
    from repro.launch.hlo_analysis import analyze

    mesh = _pipeline_mesh(n_stages, smoke=smoke)
    cfg = _homogeneous_cfg(arch, reduced=reduced, n_stages=n_stages)
    params_sds = jax.eval_shape(
        lambda: init_pipeline_params(jax.random.PRNGKey(0), cfg, n_stages))
    tok_sds, lab_sds = _micro_batch_sds(n_micro, micro_batch, seq)
    fwd_split = SplitConfig(quant=QuantConfig(method="rdfsq", bits=2),
                            learnable_codec=False, n_stages=n_stages)
    n_ticks = n_micro + n_stages - 1

    results = {}
    for name, bwd_q in (("paper_fwd_only", None),
                        ("beyond_fwd_bwd", QuantConfig(method="rdfsq",
                                                       bits=2))):
        step = build_pipeline_grad_step(cfg, mesh, fwd_split, bwd_q,
                                        n_micro, micro_batch, seq)
        with mesh:
            compiled = jax.jit(step).lower(params_sds, tok_sds,
                                           lab_sds).compile()
        hlo = compiled.as_text()
        hl = analyze(hlo)
        cp = hl["collective_by_op"].get("collective-permute", 0)
        wire = pipeline_wire_bytes(cfg, fwd_split, micro_batch, seq, bwd_q,
                                   data_shards=mesh.shape["data"])
        assert_links_match_hlo(f"train {name} N={n_stages}", hlo, mesh,
                               wire, n_ticks, check_bwd=True)
        results[name] = cp
        print(f"[split-pipeline-train {name}] collective-permute/dev = "
              f"{cp / 2 ** 20:.2f} MiB")
    red = 1 - results["beyond_fwd_bwd"] / max(results["paper_fwd_only"], 1)
    print(f"[split-pipeline-train] beyond-paper bwd compression saves "
          f"{red:.4f} of wire bytes vs paper (fwd-only) baseline")
    results["reduction"] = red
    return results


def dryrun_train(arch: str = "llama3_2_3b", n_steps: int = 6,
                 n_micro: int = 4, micro_batch: int = 8, seq: int = 32,
                 n_stages: int = 2, lr: float = 5e-3) -> Dict:
    """Actually train the reduced-config pipeline for a few AdamW steps.

    Executes (not just lowers) the quantized 2-bit wire end to end on a
    small (n_stages x 2) fake-device mesh and checks the loss decreases —
    the acceptance gate for 'the deployment path trains'."""
    from repro.data.pipeline import make_pipeline

    cfg = _homogeneous_cfg(arch, reduced=True, n_stages=n_stages)
    mesh = jax.make_mesh((n_stages, 2), ("pod", "data"))
    split = SplitConfig(quant=QuantConfig(method="rdfsq", bits=2),
                        learnable_codec=False, n_stages=n_stages)
    pipe = make_pipeline(cfg, n_micro * micro_batch, seq, seed=0)

    def batches():
        for _ in range(n_steps):
            b = next(pipe)
            yield (b["tokens"].reshape(n_micro, micro_batch, seq),
                   b["labels"].reshape(n_micro, micro_batch, seq))

    opt = AdamWConfig(lr=lr, weight_decay=0.0)
    _, _, history, wire_b = train_pipeline(
        cfg, mesh, split, opt, batches(), n_micro=n_micro,
        micro_batch=micro_batch, seq=seq)
    print(f"[split-pipeline-train reduced N={n_stages}] loss "
          + " -> ".join(f"{v:.4f}" for v in history)
          + f" (wire {wire_b / 1024:.1f} KiB/tick)")
    assert wire_b > 0, "pipeline reported zero wire bytes"
    assert history[-1] < history[0], \
        f"pipeline loss did not decrease: {history}"
    return dict(loss_history=history, wire_bytes_per_tick=wire_b)


def dryrun_lora_train(arch: str = "llama3_2_3b", n_steps: int = 6,
                      n_micro: int = 2, micro_batch: int = 4, seq: int = 32,
                      n_stages: int = 2, lora_rank: int = 4,
                      lr: float = 3e-2) -> Dict:
    """SplitLoRA pipeline acceptance gate (ROADMAP item 4).

    Trains the reduced pipeline with ``lora_rank`` adapters over the
    quantized wire and asserts the three SplitLoRA invariants:

    1. the loss decreases while every BASE weight stays bit-frozen
       (host-side snapshot compare over all non-adapter leaves);
    2. the AdamW moments are sized by the adapter params only —
       ``param_bytes(opt["m"]) == adapter_bytes(adapters)``;
    3. only the adapter leaves moved.
    """
    from repro.data.pipeline import make_pipeline
    from repro.optim import param_bytes
    from repro.peft import adapter_bytes, adapter_param_count

    cfg = _homogeneous_cfg(arch, reduced=True, n_stages=n_stages)
    mesh = jax.make_mesh((n_stages, 2), ("pod", "data"))
    split = SplitConfig(quant=QuantConfig(method="rdfsq", bits=2),
                        learnable_codec=False, n_stages=n_stages)
    params0 = init_pipeline_params(jax.random.PRNGKey(0), cfg, n_stages,
                                   lora_rank=lora_rank)
    base0 = jax.tree_util.tree_map(
        jnp.copy, {k: v for k, v in params0.items() if k != "adapters"})
    pipe = make_pipeline(cfg, n_micro * micro_batch, seq, seed=0)

    def batches():
        for _ in range(n_steps):
            b = next(pipe)
            yield (b["tokens"].reshape(n_micro, micro_batch, seq),
                   b["labels"].reshape(n_micro, micro_batch, seq))

    opt_cfg = AdamWConfig(lr=lr, weight_decay=0.0)
    params, opt, history, wire_b = train_pipeline(
        cfg, mesh, split, opt_cfg, batches(), n_micro=n_micro,
        micro_batch=micro_batch, seq=seq, params=params0,
        lora_rank=lora_rank)

    # 1. loss decreases over the quantized wire
    assert history[-1] < history[0], \
        f"LoRA pipeline loss did not decrease: {history}"
    # 2. base weights bit-frozen
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(base0),
            jax.tree_util.tree_leaves_with_path(
                {k: v for k, v in params.items() if k != "adapters"})):
        assert bool(jnp.array_equal(a, b)), \
            f"base weight changed during LoRA training: {pa}"
    # 3. moments sized by the adapters, not the base
    ad_bytes = adapter_bytes(params["adapters"])
    m_bytes = param_bytes(opt["m"])
    assert m_bytes == ad_bytes, (
        f"optimizer moments ({m_bytes} B) not sized by adapter params "
        f"({ad_bytes} B)")
    full_bytes = param_bytes(params0)
    print(f"[split-pipeline-lora N={n_stages} r={lora_rank}] loss "
          + " -> ".join(f"{v:.4f}" for v in history)
          + f" | adapters {adapter_param_count(params['adapters'])} params"
          f" ({ad_bytes / 1024:.1f} KiB), moments {m_bytes / 1024:.1f} KiB"
          f" vs full-param {full_bytes / 1024:.1f} KiB"
          f" ({full_bytes / max(ad_bytes, 1):.1f}x smaller opt state)")
    return dict(loss_history=history, wire_bytes_per_tick=wire_b,
                adapter_bytes=ad_bytes, opt_moment_bytes=m_bytes,
                full_param_bytes=full_bytes)


def main(smoke: bool = False) -> Dict:
    out: Dict = {}
    if smoke:
        # CI: reduced config, 4-stage topology, 8 fake devices
        cfg_kw = dict(reduced=True, smoke=True, n_stages=4,
                      n_micro=3, micro_batch=4, seq=16)
        out = dryrun(bits_list=(16, 2), **cfg_kw)
        out["heterogeneous"] = dryrun_heterogeneous()
        out["grouped"] = dryrun_grouped()
        out["train"] = dryrun_train(n_steps=4, n_micro=2, micro_batch=4,
                                    seq=32, n_stages=2)
        out["adaptive"] = dryrun_train_adaptive(n_steps=4)
        out["lora"] = dryrun_lora_train(n_steps=4)
        return out
    out = dryrun()
    out["heterogeneous"] = dryrun_heterogeneous(smoke=False, n_micro=4,
                                                micro_batch=32, seq=1024)
    out["grouped"] = dryrun_grouped(smoke=False, n_micro=4,
                                    micro_batch=32, seq=1024)
    out["backward"] = dryrun_backward()
    out["train"] = dryrun_train()
    out["adaptive"] = dryrun_train_adaptive()
    out["lora"] = dryrun_lora_train()
    return out


if __name__ == "__main__":
    import json

    out = main(smoke="--smoke" in sys.argv)
    os.makedirs(os.path.join(os.path.dirname(__file__), "..", "..", "..",
                             "results"), exist_ok=True)
    path = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "results", "split_pipeline.json")
    with open(path, "w") as f:
        json.dump({str(k): v for k, v in out.items()}, f, indent=1)
    print("saved", path)
