"""Cross-pod split learning: the paper's deployment, TPU-native.

The paper runs the client on one GPU box and the server on another,
shipping pickled activations over TCP.  The TPU-idiomatic equivalent
(DESIGN.md SS3) maps the partitions onto the ``pod`` mesh axis and
streams microbatches GPipe-style.  For the paper's 2-partition case:

  pod 0 (client): embed + layers[:L/2] -> quantize -> pack -> ppermute
  pod 1 (server): dequantize -> layers[L/2:] -> head -> next-token CE

Generalized here to ``SplitConfig.n_stages`` equal partitions (the paper's
deployment is N=2): stage s runs layers [s*L/N, (s+1)*L/N); every cut
s -> s+1 is a quantized wire, optionally with a per-cut ``QuantConfig``
(``SplitConfig.stage_quants``).  All pods execute the same SPMD program —
a ``lax.scan`` over ``n_micro + n_stages - 1`` microbatch ticks: the first
``n_stages - 1`` ticks fill the pipeline, the last ``n_stages - 1`` drain
it, and every stage stays busy in between.  Labels travel with the
tokens; the last stage computes the next-token cross-entropy, so
``build_pipeline_grad_step`` really trains — gradients return across the
(optionally quantized, BEYOND-PAPER) backward wire — and
``train_pipeline`` runs AdamW on the accumulated microbatch gradients.

The wire is ``core.split.quantized_ship``: the collective-permute moves
the *bit-packed uint8 codes + fp16 scales*, so the ICI traffic shrinks by
~16/bits vs shipping bf16.  Payload shapes are static, so the per-tick
wire bytes returned by the step functions are compile-time constants —
the __main__ dry-run asserts them against the collective-permute bytes
measured from the lowered HLO (within 1%).

Run the dry-run (512 fake devices, multi-pod mesh):
    PYTHONPATH=src python -m repro.launch.split_pipeline
Fast CI variant (8 fake devices, reduced config, 4-stage topology):
    PYTHONPATH=src python -m repro.launch.split_pipeline --smoke
"""
import os
import sys

if __name__ == "__main__":  # must run before any jax import
    _n_dev = 8 if "--smoke" in sys.argv else 512
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={_n_dev}"

# ruff: noqa: E402
import dataclasses
import math
from functools import partial
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.core import quantizers
from repro.core.quantizers import QuantConfig
from repro.core.split import SplitConfig, quantized_ship
from repro.models import stack as stack_mod
from repro.models import transformer as tf
from repro.models.layers import embedding as emb_mod
from repro.models.layers.norms import rms_norm
from repro.optim import AdamWConfig, init_opt_state
from repro.train.losses import IGNORE, cross_entropy


def _as_split(q) -> SplitConfig:
    """Accept a bare QuantConfig (the paper's 2-stage case) or a full
    SplitConfig describing an N-stage topology."""
    if isinstance(q, SplitConfig):
        return q
    return SplitConfig(quant=q, learnable_codec=False)


def _homogeneous_cfg(arch: str = "llama3_2_3b", reduced: bool = False,
                     n_stages: int = 2) -> ArchConfig:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
        if cfg.n_layers % n_stages:
            # reduced() pins 2 layers; deeper-than-2-stage smoke
            # topologies need one layer per stage
            cfg = dataclasses.replace(cfg, n_layers=n_stages)
    assert all(t == "dense" for t in cfg.block_pattern()), \
        "pipeline stages must be structurally identical"
    assert cfg.n_layers % n_stages == 0, \
        f"{cfg.n_layers} layers do not divide into {n_stages} stages"
    return cfg


def init_pipeline_params(key, cfg: ArchConfig, n_stages: int = 2) -> Dict:
    """Stage-stacked parameters: blocks (N, L/N, ...); embed/head shared."""
    per_stage = cfg.n_layers // n_stages
    k1, k2, k3, k4 = jax.random.split(key, 4)
    lkeys = jax.random.split(k1, n_stages * per_stage).reshape(
        n_stages, per_stage, -1)
    blocks = jax.vmap(jax.vmap(
        lambda k: tf.init_block_params(k, cfg, "dense")))(lkeys)
    return dict(
        embed=emb_mod.init_embedding(k2, cfg.vocab_size, cfg.d_model,
                                     tf.pdtype(cfg)),
        head=emb_mod.init_head(k3, cfg.d_model, cfg.vocab_size,
                               dtype=tf.pdtype(cfg)),
        final_norm=jnp.ones((cfg.d_model,), tf.pdtype(cfg)),
        blocks=blocks,
    )


def pipeline_specs(cfg: ArchConfig, n_stages: int = 2) -> Dict:
    """shard_map in_specs for the parameter tree."""
    blocks_spec = jax.tree_util.tree_map(
        lambda _: P("pod"), jax.eval_shape(
            lambda: init_pipeline_params(jax.random.PRNGKey(0), cfg,
                                         n_stages)
        )["blocks"])
    return dict(
        embed=jax.tree_util.tree_map(lambda _: P(), dict(emb=0)),
        head=jax.tree_util.tree_map(lambda _: P(), dict(w=0)),
        final_norm=P(),
        blocks=blocks_spec,
    )


# ---------------------------------------------------------------------------
# static wire accounting
# ---------------------------------------------------------------------------

def _cut_groups(quants: Tuple[QuantConfig, ...]
                ) -> List[Tuple[QuantConfig, Tuple[int, ...]]]:
    """Cuts grouped by identical QuantConfig (one ship op per group)."""
    groups: List[Tuple[QuantConfig, Tuple[int, ...]]] = []
    for c, q in enumerate(quants):
        for i, (gq, cuts) in enumerate(groups):
            if gq == q:
                groups[i] = (gq, cuts + (c,))
                break
        else:
            groups.append((q, (c,)))
    return groups


def pipeline_wire_bytes(cfg: ArchConfig, split, micro_batch: int, seq: int,
                        bwd_qcfg: Optional[QuantConfig] = None,
                        data_shards: int = 1) -> Dict:
    """Per-tick, per-device wire bytes, from the static payload shapes.

    ``data_shards`` is the mesh's data-axis size: the microbatch is
    sharded over it, so each device encodes and ships a
    ``micro_batch / data_shards`` slice — the quantity the partitioned
    HLO's collective-permute bytes measure.  Every device executes every
    cut group's ship op (SPMD), so the per-device bytes per tick are the
    SUM over distinct cut configs of that group's payload — for the
    homogeneous (single-config) topology this is exactly one payload.
    ``bwd_tick`` is the gradient-return wire crossed once per tick by
    the backward scan of the grad step (0 for the forward-only step).
    """
    split = _as_split(split)
    assert micro_batch % data_shards == 0, (micro_batch, data_shards)
    x_sds = jax.ShapeDtypeStruct(
        (micro_batch // data_shards, seq, cfg.d_model), tf.cdtype(cfg))
    fwd = 0
    groups = _cut_groups(split.resolve_stage_quants())
    for qcfg, _cuts in groups:
        payload = jax.eval_shape(partial(quantizers.encode, qcfg), x_sds)
        fwd += payload.wire_bytes()
    if bwd_qcfg is None:
        # paper scope: the cotangent returns uncompressed, once per group
        bwd = len(groups) * math.prod(x_sds.shape) * x_sds.dtype.itemsize
    else:
        payload = jax.eval_shape(partial(quantizers.encode, bwd_qcfg),
                                 x_sds)
        bwd = len(groups) * payload.wire_bytes()
    return dict(fwd_tick=fwd, bwd_tick=bwd)


# ---------------------------------------------------------------------------
# pipeline step builders
# ---------------------------------------------------------------------------

def build_pipeline_step(cfg: ArchConfig, mesh, split, n_micro: int,
                        micro_batch: int, seq: int,
                        bwd_qcfg: Optional[QuantConfig] = None):
    """Returns a jit-able fn(params, tokens, labels) -> (loss, wire_bytes).

    ``tokens``/``labels`` are (n_micro, B, S) int32; ``loss`` is the
    next-token cross-entropy computed by the last stage, averaged over
    the ``n_micro`` microbatches; ``wire_bytes`` is the per-tick forward
    wire payload in bytes — a compile-time constant derived from the
    static ``CommPayload`` shapes (NOT a measured quantity; the dry-run
    asserts it against the lowered HLO's collective-permute bytes).
    """
    split = _as_split(split)
    n_stages = split.n_stages
    assert cfg.n_layers % n_stages == 0
    assert mesh.shape["pod"] == n_stages, \
        f"mesh pod axis {mesh.shape['pod']} != n_stages {n_stages}"
    dtype = tf.cdtype(cfg)
    groups = _cut_groups(split.resolve_stage_quants())
    wire = pipeline_wire_bytes(cfg, split, micro_batch, seq, bwd_qcfg,
                               data_shards=mesh.shape["data"])
    last = n_stages - 1

    param_specs = pipeline_specs(cfg, n_stages)
    tok_spec = P(None, "data", None)  # (n_micro, B, S)

    @partial(shard_map, mesh=mesh,
             in_specs=(param_specs, tok_spec, tok_spec),
             out_specs=(P(), P()),
             check_rep=False)
    def step(params, tokens, labels):
        stage = jax.lax.axis_index("pod")
        my_blocks = jax.tree_util.tree_map(lambda a: a[0],
                                           params["blocks"])
        positions = jnp.arange(seq, dtype=jnp.int32)

        def run_stage(x):
            def body(h, p):
                h, _, _ = tf.block_forward(cfg, "dense", p, h,
                                           positions=positions, window=None)
                return h, ({}, None)

            x, _, _ = stack_mod.run_stack(body, x, my_blocks,
                                          remat=cfg.remat,
                                          remat_group=cfg.remat_group)
            return x

        def tick(carry, xs):
            recv = carry  # activation received on the previous tick
            tok, lab = xs
            x_emb = emb_mod.embed(params["embed"], tok, dtype)
            x_in = jnp.where(stage == 0, x_emb, recv.astype(x_emb.dtype))
            h = run_stage(x_in)
            # ship across every cut; a stage keeps the payload arriving
            # from its own upstream cut (cut c feeds stage c+1)
            recv_new = jnp.zeros_like(h)
            for qcfg, cuts in groups:
                perm = tuple((c, c + 1) for c in cuts)
                out_q = quantized_ship(qcfg, h, "pod", perm, bwd_qcfg)
                is_dst = jnp.zeros((), jnp.bool_)
                for c in cuts:
                    is_dst = is_dst | (stage == c + 1)
                recv_new = jnp.where(is_dst, out_q.astype(h.dtype),
                                     recv_new)
            # last-stage head + next-token CE on this tick's microbatch.
            # lax.cond, not a computed-then-masked jnp.where: the vocab
            # projection is the widest matmul in the model and only 1/N
            # of the stages needs it — the branch keeps the SPMD program
            # identical while sparing the other stages the work.
            def head_ce(hh):
                out = rms_norm(hh, params["final_norm"], cfg.norm_eps)
                logits = emb_mod.head_logits(params["head"], out)
                return cross_entropy(logits, lab)

            ce = jax.lax.cond(stage == last, head_ce,
                              lambda hh: jnp.zeros((), jnp.float32), h)
            return recv_new, ce

        # GPipe fill/drain: microbatch j enters stage 0 at tick j and
        # reaches the last stage at tick j + (n_stages - 1), so the scan
        # runs n_micro + n_stages - 1 ticks; stage 0 consumes dummy
        # tokens while draining and the last stage sees IGNORE labels
        # while filling (masked to CE = 0 by cross_entropy).
        pad_tok = jnp.zeros((last,) + tokens.shape[1:], tokens.dtype)
        tok_feed = jnp.concatenate([tokens, pad_tok], axis=0)
        pad_lab = jnp.full((last,) + labels.shape[1:], IGNORE, labels.dtype)
        lab_feed = jnp.concatenate([pad_lab, labels], axis=0)

        init = jnp.zeros((tokens.shape[1], seq, cfg.d_model), dtype)
        _, ces = jax.lax.scan(tick, init, (tok_feed, lab_feed))
        # sum over pod (only the last stage contributes), mean over the
        # data shards (each computed CE on its local microbatch slice)
        loss = jax.lax.pmean(jax.lax.psum(jnp.sum(ces), "pod"),
                             "data") / n_micro
        return loss, jnp.asarray(wire["fwd_tick"], jnp.float32)

    return step


def build_pipeline_grad_step(cfg, mesh, split, bwd_qcfg, n_micro,
                             micro_batch, seq):
    """Like build_pipeline_step but differentiates the pipeline loss wrt
    the stage parameters, exercising the gradient-return wire.

    Returns fn(params, tokens, labels) -> (loss, grads, wire_bytes) with
    ``wire_bytes`` the per-tick forward + backward payload (compile-time
    constant, same contract as build_pipeline_step).
    """
    split = _as_split(split)
    step = build_pipeline_step(cfg, mesh, split, n_micro, micro_batch, seq,
                               bwd_qcfg=bwd_qcfg)
    wire = pipeline_wire_bytes(cfg, split, micro_batch, seq, bwd_qcfg,
                               data_shards=mesh.shape["data"])
    tick_bytes = float(wire["fwd_tick"] + wire["bwd_tick"])

    def grad_step(params, tokens, labels):
        def loss_fn(p):
            loss, _ = step(p, tokens, labels)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return loss, grads, jnp.asarray(tick_bytes, jnp.float32)

    return grad_step


def train_pipeline(cfg: ArchConfig, mesh, split, opt_cfg: AdamWConfig,
                   batches: Iterable[Tuple[jnp.ndarray, jnp.ndarray]], *,
                   n_micro: int, micro_batch: int, seq: int,
                   bwd_qcfg: Optional[QuantConfig] = None,
                   params: Optional[Dict] = None,
                   warmup_steps: int = 0, total_steps: int = 0,
                   seed: int = 0) -> Tuple[Dict, Dict, List[float], float]:
    """AdamW training loop over the N-stage quantized pipeline.

    Each element of ``batches`` is a (tokens, labels) pair of shape
    (n_micro, B, S); one optimizer step consumes one element, with the
    pipeline scan playing the role of microbatch gradient accumulation
    (the per-tick CE terms sum into one loss before differentiation).
    The update is ``train.loop.apply_gradients`` — the same scheduled
    AdamW the monolithic trainer uses (``total_steps == 0`` = constant
    lr).  Returns (params, opt_state, per-step losses, wire bytes/tick).
    """
    from repro.train.loop import TrainState, apply_gradients

    split = _as_split(split)
    grad_step = build_pipeline_grad_step(cfg, mesh, split, bwd_qcfg,
                                         n_micro, micro_batch, seq)
    if params is None:
        params = init_pipeline_params(jax.random.PRNGKey(seed), cfg,
                                      split.n_stages)
    state = TrainState(params=params,
                       opt=init_opt_state(params, opt_cfg),
                       step=jnp.zeros((), jnp.int32))

    @jax.jit
    def update(state, tokens, labels):
        loss, grads, wire_b = grad_step(state.params, tokens, labels)
        state, _ = apply_gradients(state, grads, opt_cfg,
                                   warmup_steps=warmup_steps,
                                   total_steps=total_steps)
        return state, loss, wire_b

    history: List[float] = []
    wire_b = 0.0
    with mesh:
        for tokens, labels in batches:
            state, loss, wb = update(state, tokens, labels)
            history.append(float(loss))
            wire_b = float(wb)
    return state.params, state.opt, history, wire_b


# ---------------------------------------------------------------------------
# dry-runs
# ---------------------------------------------------------------------------

def _pipeline_mesh(n_stages: int, smoke: bool = False):
    """(pod, data[, model]) mesh with a pod axis of n_stages."""
    if smoke:
        return jax.make_mesh((n_stages, 2), ("pod", "data"))
    n_dev = len(jax.devices())
    model = max(1, n_dev // (n_stages * 16))
    return jax.make_mesh((n_stages, 16, model), ("pod", "data", "model"))


def _micro_batch_sds(n_micro, micro_batch, seq):
    tok = jax.ShapeDtypeStruct((n_micro, micro_batch, seq), jnp.int32)
    return tok, tok


def _assert_wire_matches_hlo(name: str, cp_bytes: int, tick_bytes: int,
                             n_ticks: int) -> None:
    expected = tick_bytes * n_ticks
    rel = abs(cp_bytes - expected) / max(expected, 1)
    print(f"[split-pipeline {name}] wire accounting: HLO "
          f"{cp_bytes / 2 ** 20:.3f} MiB vs static "
          f"{expected / 2 ** 20:.3f} MiB (rel err {rel:.4f})")
    assert rel < 0.01, (
        f"{name}: HLO collective-permute bytes {cp_bytes} disagree with "
        f"static CommPayload accounting {expected} (rel err {rel:.3f})")


def dryrun(arch: str = "llama3_2_3b", n_micro: int = 4,
           micro_batch: int = 32, seq: int = 1024,
           bits_list=(16, 4, 2), n_stages: int = 2,
           reduced: bool = False, smoke: bool = False) -> Dict:
    """Lower + compile the N-stage pipeline on the multi-pod mesh, measure
    the collective-permute bytes per bit-width, and assert they match the
    static CommPayload wire accounting."""
    from repro.launch.hlo_analysis import analyze

    mesh = _pipeline_mesh(n_stages, smoke=smoke)
    cfg = _homogeneous_cfg(arch, reduced=reduced, n_stages=n_stages)
    params_sds = jax.eval_shape(
        lambda: init_pipeline_params(jax.random.PRNGKey(0), cfg, n_stages))
    tok_sds, lab_sds = _micro_batch_sds(n_micro, micro_batch, seq)
    n_ticks = n_micro + n_stages - 1

    results = {}
    for bits in bits_list:
        method = "identity" if bits == 16 else "rdfsq"
        split = SplitConfig(quant=QuantConfig(method=method,
                                              bits=min(bits, 8)),
                            learnable_codec=False, n_stages=n_stages)
        step = build_pipeline_step(cfg, mesh, split, n_micro, micro_batch,
                                   seq)
        with mesh:
            compiled = jax.jit(step).lower(params_sds, tok_sds,
                                           lab_sds).compile()
        hl = analyze(compiled.as_text())
        cp = hl["collective_by_op"].get("collective-permute", 0)
        wire = pipeline_wire_bytes(cfg, split, micro_batch, seq,
                                   data_shards=mesh.shape["data"])
        _assert_wire_matches_hlo(f"{arch} {method}-{bits}bit N={n_stages}",
                                 cp, wire["fwd_tick"], n_ticks)
        results[bits] = dict(
            collective_permute_bytes=cp,
            wire_bytes_per_tick=wire["fwd_tick"],
            total_collective_bytes=hl["collective_bytes"],
            peak_gib=compiled.memory_analysis().temp_size_in_bytes / 2 ** 30,
        )
        print(f"[split-pipeline {arch} {method}-{bits}bit N={n_stages}] "
              f"collective-permute/dev = {cp / 2 ** 20:.2f} MiB "
              f"(total coll {hl['collective_bytes'] / 2 ** 20:.1f} MiB)")
    if 16 in results and 2 in results:
        r = 1 - results[2]["collective_permute_bytes"] / \
            max(results[16]["collective_permute_bytes"], 1)
        print(f"[split-pipeline] 2-bit wire reduction vs 16-bit: {r:.4f} "
              f"(paper claims 0.875)")
        results["reduction_2bit"] = r
    return results


def dryrun_backward(arch: str = "llama3_2_3b", n_micro: int = 4,
                    micro_batch: int = 32, seq: int = 1024,
                    n_stages: int = 2, reduced: bool = False,
                    smoke: bool = False) -> Dict:
    """BEYOND-PAPER: quantize the gradient-return wire too.

    The paper compresses only the forward activations (its Table 4 scope);
    the cotangent crossing back client<-server stays bf16.  Measuring the
    pipeline's total collective-permute bytes with and without 2-bit
    RD-FSQ gradient compression shows the remaining half of the wire."""
    from repro.launch.hlo_analysis import analyze

    mesh = _pipeline_mesh(n_stages, smoke=smoke)
    cfg = _homogeneous_cfg(arch, reduced=reduced, n_stages=n_stages)
    params_sds = jax.eval_shape(
        lambda: init_pipeline_params(jax.random.PRNGKey(0), cfg, n_stages))
    tok_sds, lab_sds = _micro_batch_sds(n_micro, micro_batch, seq)
    fwd_split = SplitConfig(quant=QuantConfig(method="rdfsq", bits=2),
                            learnable_codec=False, n_stages=n_stages)
    n_ticks = n_micro + n_stages - 1

    results = {}
    for name, bwd_q in (("paper_fwd_only", None),
                        ("beyond_fwd_bwd", QuantConfig(method="rdfsq",
                                                       bits=2))):
        step = build_pipeline_grad_step(cfg, mesh, fwd_split, bwd_q,
                                        n_micro, micro_batch, seq)
        with mesh:
            compiled = jax.jit(step).lower(params_sds, tok_sds,
                                           lab_sds).compile()
        hl = analyze(compiled.as_text())
        cp = hl["collective_by_op"].get("collective-permute", 0)
        wire = pipeline_wire_bytes(cfg, fwd_split, micro_batch, seq, bwd_q,
                                   data_shards=mesh.shape["data"])
        _assert_wire_matches_hlo(f"train {name} N={n_stages}", cp,
                                 wire["fwd_tick"] + wire["bwd_tick"],
                                 n_ticks)
        results[name] = cp
        print(f"[split-pipeline-train {name}] collective-permute/dev = "
              f"{cp / 2 ** 20:.2f} MiB")
    red = 1 - results["beyond_fwd_bwd"] / max(results["paper_fwd_only"], 1)
    print(f"[split-pipeline-train] beyond-paper bwd compression saves "
          f"{red:.4f} of wire bytes vs paper (fwd-only) baseline")
    results["reduction"] = red
    return results


def dryrun_train(arch: str = "llama3_2_3b", n_steps: int = 6,
                 n_micro: int = 4, micro_batch: int = 8, seq: int = 32,
                 n_stages: int = 2, lr: float = 5e-3) -> Dict:
    """Actually train the reduced-config pipeline for a few AdamW steps.

    Executes (not just lowers) the quantized 2-bit wire end to end on a
    small (n_stages x 2) fake-device mesh and checks the loss decreases —
    the acceptance gate for 'the deployment path trains'."""
    from repro.data.pipeline import make_pipeline

    cfg = _homogeneous_cfg(arch, reduced=True, n_stages=n_stages)
    mesh = jax.make_mesh((n_stages, 2), ("pod", "data"))
    split = SplitConfig(quant=QuantConfig(method="rdfsq", bits=2),
                        learnable_codec=False, n_stages=n_stages)
    pipe = make_pipeline(cfg, n_micro * micro_batch, seq, seed=0)

    def batches():
        for _ in range(n_steps):
            b = next(pipe)
            yield (b["tokens"].reshape(n_micro, micro_batch, seq),
                   b["labels"].reshape(n_micro, micro_batch, seq))

    opt = AdamWConfig(lr=lr, weight_decay=0.0)
    _, _, history, wire_b = train_pipeline(
        cfg, mesh, split, opt, batches(), n_micro=n_micro,
        micro_batch=micro_batch, seq=seq)
    print(f"[split-pipeline-train reduced N={n_stages}] loss "
          + " -> ".join(f"{v:.4f}" for v in history)
          + f" (wire {wire_b / 1024:.1f} KiB/tick)")
    assert wire_b > 0, "pipeline reported zero wire bytes"
    assert history[-1] < history[0], \
        f"pipeline loss did not decrease: {history}"
    return dict(loss_history=history, wire_bytes_per_tick=wire_b)


def main(smoke: bool = False) -> Dict:
    out: Dict = {}
    if smoke:
        # CI: reduced config, 4-stage topology, 8 fake devices
        cfg_kw = dict(reduced=True, smoke=True, n_stages=4,
                      n_micro=3, micro_batch=4, seq=16)
        out = dryrun(bits_list=(16, 2), **cfg_kw)
        out["train"] = dryrun_train(n_steps=4, n_micro=2, micro_batch=4,
                                    seq=32, n_stages=2)
        return out
    out = dryrun()
    out["backward"] = dryrun_backward()
    out["train"] = dryrun_train()
    return out


if __name__ == "__main__":
    import json

    out = main(smoke="--smoke" in sys.argv)
    os.makedirs(os.path.join(os.path.dirname(__file__), "..", "..", "..",
                             "results"), exist_ok=True)
    path = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "results", "split_pipeline.json")
    with open(path, "w") as f:
        json.dump({str(k): v for k, v in out.items()}, f, indent=1)
    print("saved", path)
