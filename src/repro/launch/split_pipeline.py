"""Cross-pod split learning: the paper's deployment, TPU-native.

The paper runs the client on one GPU box and the server on another,
shipping pickled activations over TCP.  The TPU-idiomatic equivalent
(DESIGN.md SS3) maps the two partitions onto the ``pod`` mesh axis and
streams microbatches GPipe-style:

  pod 0 (client): embed + layers[:L/2] -> quantize -> pack -> ppermute
  pod 1 (server): dequantize -> layers[L/2:] -> head

Both pods execute the same SPMD program (a lax.scan over microbatch
ticks); at every tick pod 0 ingests a fresh microbatch while pod 1
consumes the payload received on the previous tick, so both stages stay
busy after a 1-tick fill.  The wire is ``core.split.quantized_ship``: the
collective-permute moves the *bit-packed uint8 codes + fp16 scales*, so
the ICI traffic shrinks by ~16/bits vs shipping bf16 — measured from the
lowered HLO by the __main__ dry-run below.

Run the dry-run (512 fake devices, multi-pod mesh):
    PYTHONPATH=src python -m repro.launch.split_pipeline
"""
import os

if __name__ == "__main__":  # must run before any jax import
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.core.quantizers import QuantConfig
from repro.core.split import quantized_ship
from repro.models import stack as stack_mod
from repro.models import transformer as tf
from repro.models.layers import embedding as emb_mod
from repro.models.layers.norms import rms_norm


def _homogeneous_cfg(arch: str = "llama3_2_3b",
                     reduced: bool = False) -> ArchConfig:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    assert all(t == "dense" for t in cfg.block_pattern()), \
        "pipeline stages must be structurally identical"
    assert cfg.n_layers % 2 == 0
    return cfg


def init_pipeline_params(key, cfg: ArchConfig) -> Dict:
    """Stage-stacked parameters: blocks (2, L/2, ...); embed/head shared."""
    half = cfg.n_layers // 2
    k1, k2, k3, k4 = jax.random.split(key, 4)
    lkeys = jax.random.split(k1, 2 * half).reshape(2, half, -1)
    blocks = jax.vmap(jax.vmap(
        lambda k: tf.init_block_params(k, cfg, "dense")))(lkeys)
    return dict(
        embed=emb_mod.init_embedding(k2, cfg.vocab_size, cfg.d_model,
                                     tf.pdtype(cfg)),
        head=emb_mod.init_head(k3, cfg.d_model, cfg.vocab_size,
                               dtype=tf.pdtype(cfg)),
        final_norm=jnp.ones((cfg.d_model,), tf.pdtype(cfg)),
        blocks=blocks,
    )


def pipeline_specs(cfg: ArchConfig) -> Dict:
    """shard_map in_specs for the parameter tree."""
    blocks_spec = jax.tree_util.tree_map(
        lambda _: P("pod"), jax.eval_shape(
            lambda: init_pipeline_params(jax.random.PRNGKey(0), cfg)
        )["blocks"])
    return dict(
        embed=jax.tree_util.tree_map(lambda _: P(), dict(emb=0)),
        head=jax.tree_util.tree_map(lambda _: P(), dict(w=0)),
        final_norm=P(),
        blocks=blocks_spec,
    )


def build_pipeline_step(cfg: ArchConfig, mesh, qcfg: QuantConfig,
                        n_micro: int, micro_batch: int, seq: int,
                        bwd_qcfg: Optional[QuantConfig] = None):
    """Returns a jit-able fn(params, tokens) -> (mean server logit-norm,
    payload bytes per tick) executing the 2-stage quantized pipeline."""
    half = cfg.n_layers // 2
    dtype = tf.cdtype(cfg)
    perm = ((0, 1),)  # client -> server only (paper: forward-path wire)

    param_specs = pipeline_specs(cfg)
    tok_spec = P(None, "data", None)  # (n_micro, B, S)

    @partial(shard_map, mesh=mesh,
             in_specs=(param_specs, tok_spec),
             out_specs=(P(), P()),
             check_rep=False)
    def step(params, tokens):
        stage = jax.lax.axis_index("pod")
        my_blocks = jax.tree_util.tree_map(lambda a: a[0],
                                           params["blocks"])
        positions = jnp.arange(seq, dtype=jnp.int32)

        def run_stage(x):
            def body(h, p):
                h, _, _ = tf.block_forward(cfg, "dense", p, h,
                                           positions=positions, window=None)
                return h, ({}, None)

            x, _, _ = stack_mod.run_stack(body, x, my_blocks,
                                          remat=cfg.remat,
                                          remat_group=cfg.remat_group)
            return x

        def tick(carry, tok):
            recv = carry  # activation received on the previous tick
            x_emb = emb_mod.embed(params["embed"], tok, dtype)
            x_in = jnp.where(stage == 0, x_emb, recv.astype(x_emb.dtype))
            h = run_stage(x_in)
            shipped = quantized_ship(qcfg, h, "pod", perm, bwd_qcfg)
            # server-side head on this tick's output (valid on pod 1)
            out = rms_norm(h, params["final_norm"], cfg.norm_eps)
            logits = emb_mod.head_logits(params["head"], out)
            metric = jnp.where(stage == 1,
                               jnp.mean(jnp.abs(logits.astype(jnp.float32))),
                               0.0)
            return shipped, metric

        init = jnp.zeros((tokens.shape[1], seq, cfg.d_model), dtype)
        _, metrics = jax.lax.scan(tick, init, tokens)
        # mean over the pipeline (skip the fill tick on the server)
        metric = jnp.mean(metrics[1:])
        return (jax.lax.pmean(metric, "pod"),
                jnp.zeros((), jnp.float32))

    return step


def build_pipeline_grad_step(cfg, mesh, qcfg, bwd_qcfg, n_micro,
                             micro_batch, seq):
    """Like build_pipeline_step but differentiates the pipeline wrt the
    stage parameters — exercising the gradient-return wire."""
    step = build_pipeline_step(cfg, mesh, qcfg, n_micro, micro_batch, seq,
                               bwd_qcfg=bwd_qcfg)

    def grad_step(params, tokens):
        def loss(p):
            m, _ = step(p, tokens)
            return m

        return jax.grad(lambda p: loss(p))(params)

    return grad_step


def dryrun_backward(arch: str = "llama3_2_3b", n_micro: int = 4,
                    micro_batch: int = 32, seq: int = 1024) -> Dict:
    """BEYOND-PAPER: quantize the gradient-return wire too.

    The paper compresses only the forward activations (its Table 4 scope);
    the cotangent crossing back client<-server stays bf16.  Measuring the
    pipeline's total collective-permute bytes with and without 2-bit
    RD-FSQ gradient compression shows the remaining half of the wire."""
    from repro.launch.hlo_analysis import analyze
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=True)
    cfg = _homogeneous_cfg(arch)
    params_sds = jax.eval_shape(
        lambda: init_pipeline_params(jax.random.PRNGKey(0), cfg))
    tok_sds = jax.ShapeDtypeStruct((n_micro, micro_batch, seq), jnp.int32)
    fwd_q = QuantConfig(method="rdfsq", bits=2)

    results = {}
    for name, bwd_q in (("paper_fwd_only", None),
                        ("beyond_fwd_bwd", QuantConfig(method="rdfsq",
                                                       bits=2))):
        step = build_pipeline_grad_step(cfg, mesh, fwd_q, bwd_q, n_micro,
                                        micro_batch, seq)
        with mesh:
            compiled = jax.jit(step).lower(params_sds, tok_sds).compile()
        hl = analyze(compiled.as_text())
        cp = hl["collective_by_op"].get("collective-permute", 0)
        results[name] = cp
        print(f"[split-pipeline-train {name}] collective-permute/dev = "
              f"{cp / 2 ** 20:.2f} MiB")
    red = 1 - results["beyond_fwd_bwd"] / max(results["paper_fwd_only"], 1)
    print(f"[split-pipeline-train] beyond-paper bwd compression saves "
          f"{red:.4f} of wire bytes vs paper (fwd-only) baseline")
    results["reduction"] = red
    return results


def dryrun(arch: str = "llama3_2_3b", n_micro: int = 4,
           micro_batch: int = 32, seq: int = 1024,
           bits_list=(16, 4, 2)) -> Dict:
    """Lower + compile the pipeline on the (2, 16, 16) multi-pod mesh and
    measure the collective-permute bytes per bit-width."""
    from repro.launch.hlo_analysis import analyze
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=True)
    cfg = _homogeneous_cfg(arch)
    params_sds = jax.eval_shape(
        lambda: init_pipeline_params(jax.random.PRNGKey(0), cfg))
    tok_sds = jax.ShapeDtypeStruct((n_micro, micro_batch, seq), jnp.int32)

    results = {}
    for bits in bits_list:
        method = "identity" if bits == 16 else "rdfsq"
        qcfg = QuantConfig(method=method, bits=min(bits, 8))
        step = build_pipeline_step(cfg, mesh, qcfg, n_micro, micro_batch,
                                   seq)
        with mesh:
            compiled = jax.jit(step).lower(params_sds, tok_sds).compile()
        hl = analyze(compiled.as_text())
        cp = hl["collective_by_op"].get("collective-permute", 0)
        results[bits] = dict(
            collective_permute_bytes=cp,
            total_collective_bytes=hl["collective_bytes"],
            peak_gib=compiled.memory_analysis().temp_size_in_bytes / 2 ** 30,
        )
        print(f"[split-pipeline {arch} {method}-{bits}bit] "
              f"collective-permute/dev = {cp / 2 ** 20:.2f} MiB "
              f"(total coll {hl['collective_bytes'] / 2 ** 20:.1f} MiB)")
    if 16 in results and 2 in results:
        r = 1 - results[2]["collective_permute_bytes"] / \
            max(results[16]["collective_permute_bytes"], 1)
        print(f"[split-pipeline] 2-bit wire reduction vs 16-bit: {r:.4f} "
              f"(paper claims 0.875)")
        results["reduction_2bit"] = r
    return results


if __name__ == "__main__":
    import json

    out = dryrun()
    out["backward"] = dryrun_backward()
    os.makedirs(os.path.join(os.path.dirname(__file__), "..", "..", "..",
                             "results"), exist_ok=True)
    path = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "results", "split_pipeline.json")
    with open(path, "w") as f:
        json.dump({str(k): v for k, v in out.items()}, f, indent=1)
    print("saved", path)
