"""Training launcher.

Single-host (CPU/dev) by default; ``--mesh`` runs the sharded step on a
fake-device mesh (the production entry point on a real pod is identical —
jax.distributed.initialize + make_production_mesh).

    PYTHONPATH=src python -m repro.launch.train --arch tinyllava \
        --steps 200 --batch 8 --seq 64 [--method rdfsq --bits 2]
"""
from __future__ import annotations

import argparse
import dataclasses
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllava")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--method", default=None,
                    help="compressor method: any registered quantizer "
                         "(fsq|rdfsq|nf|topk|identity) or 'none' to "
                         "disable the cut")
    ap.add_argument("--bits", type=int, default=None)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--remat", dest="remat", action="store_true",
                    default=None, help="force layer remat on")
    ap.add_argument("--no-remat", dest="remat", action="store_false",
                    help="force layer remat off")
    ap.add_argument("--remat-group", type=int, default=None,
                    help=">1 enables two-level (sqrt-L) checkpointing "
                         "with this group size")
    ap.add_argument("--mesh", default=None,
                    help="DxM fake-device mesh, e.g. 4x2")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.mesh:
        d, m = (int(v) for v in args.mesh.split("x"))
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={d * m}"

    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import checkpoint
    from repro.configs import get_config
    from repro.core.quantizers import QuantConfig
    from repro.data.pipeline import make_pipeline
    from repro.optim import AdamWConfig
    from repro.sharding import batch_pspecs, mesh_axes, state_pspecs
    from repro.sharding import ctx as shard_ctx
    from repro.train.loop import init_state, make_train_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.method:
        from repro.core.quantizers import methods
        known = sorted(set(methods()) | {"none"})
        if args.method not in known:
            ap.error(f"--method {args.method!r} is not a registered "
                     f"quantizer (choose from {', '.join(known)})")
        split = dataclasses.replace(
            cfg.split, quant=QuantConfig(method=args.method,
                                         bits=args.bits or 2),
            enabled=args.method != "identity")
        cfg = dataclasses.replace(cfg, split=split)

    opt_cfg = AdamWConfig(lr=args.lr)
    key = jax.random.PRNGKey(0)
    state = init_state(key, cfg, opt_cfg)
    step = make_train_step(cfg, opt_cfg, total_steps=args.steps,
                           grad_accum=args.grad_accum, remat=args.remat,
                           remat_group=args.remat_group)
    data = make_pipeline(cfg, args.batch, args.seq)

    if args.mesh:
        d, m = (int(v) for v in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        axes = mesh_axes(mesh)
        shard_ctx.install(("data",), axes=axes)
        st_specs = state_pspecs(state, axes, fsdp=True)
        named = lambda t: jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
        sample = next(data)
        # out_shardings must pin the returned state to the SAME specs as
        # the input state: left to the compiler, step N's output sharding
        # can differ from the declared in_shardings and the step N+1 call
        # fails with a sharding mismatch.
        step_fn = jax.jit(step, in_shardings=(
            named(st_specs),
            named(batch_pspecs(sample, ("data",), axes)),
            NamedSharding(mesh, P())),
            out_shardings=(named(st_specs), NamedSharding(mesh, P())))
        ctx = mesh
    else:
        step_fn = jax.jit(step)
        import contextlib
        ctx = contextlib.nullcontext()

    with ctx:
        for i in range(args.steps):
            batch = next(data)
            key, sub = jax.random.split(key)
            state, metrics = step_fn(state, batch, sub)
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"step {i:5d}  loss={float(metrics['loss']):.4f}  "
                      f"ce={float(metrics['ce']):.4f}  "
                      f"commit={float(metrics['commit']):.4f}  "
                      f"gnorm={float(metrics['grad_norm']):.3f}")
    if args.ckpt:
        checkpoint.save(args.ckpt, state)
        print(f"saved checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
