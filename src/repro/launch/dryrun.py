"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

MUST be imported/executed before any other jax usage: the first two lines
force 512 host platform devices so ``jax.make_mesh`` can build the
production meshes (2x16x16 multi-pod, 16x16 single-pod) on this CPU-only
container.

Per combo it records:
  * compiled.memory_analysis()    (proves the program fits per-device HBM)
  * compiled.cost_analysis()      (HLO FLOPs / bytes for the roofline)
  * collective bytes parsed from the partitioned HLO (hlo_analysis)
  * derived roofline terms (launch/roofline.py)

Results land in results/dryrun/<arch>__<shape>__<mesh>.json; the
benchmarks/roofline harness and EXPERIMENTS.md tables read from there.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_2_3b \
        --shape train_4k [--multi-pod] [--fsdp {auto,on,off}]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.launch import shapes as shp
from repro.launch.hlo_analysis import analyze as hlo_analyze
from repro.launch.hlo_analysis import count_op
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.launch.roofline import derive_roofline, model_flops
from repro.models import transformer as tf
from repro.optim import AdamWConfig
from repro.serve.decode import make_serve_step
from repro.sharding import (batch_pspecs, cache_pspecs, mesh_axes,
                            param_pspecs, state_pspecs)
from repro.sharding import ctx as shard_ctx
from repro.train.loop import init_state, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# Per-arch memory policy (the "fits in HBM" knobs; see EXPERIMENTS.md §Perf)
BF16_MOMENTS = {"arctic_480b", "deepseek_v2_236b"}
SERVE_FSDP = {"arctic_480b", "deepseek_v2_236b"}
TRAIN_ACCUM = 8  # microbatches per step (global 256 -> 8 x 32)
# giant MoE configs trade collective traffic (more FSDP regathers) for
# activation memory (EXPERIMENTS.md SSPerf A7)
TRAIN_ACCUM_OVERRIDE = {"deepseek_v2_236b": 16, "arctic_480b": 16}
# two-level remat only where per-device activation memory binds (it costs
# collective traffic; EXPERIMENTS.md SSPerf A8/C2)
REMAT_GROUP = {"llava_next_34b": 8, "deepseek_coder_33b": 8,
               "arctic_480b": 6, "rwkv6_7b": 8, "minicpm3_4b": 8,
               "deepseek_v2_236b": 8}
# int8 KV cache (beyond-paper, SSPerf D5) where the decode cache footprint
# exceeds per-device HBM at bf16
KV8 = {"deepseek_coder_33b", "llava_next_34b", "musicgen_large",
       "arctic_480b"}


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def build_lowerable(arch: str, shape_name: str, mesh, *,
                    fsdp: Optional[bool] = None):
    """Returns (jitted_fn, arg ShapeDtypeStructs tuple)."""
    cfg = get_config(arch)
    shape = shp.SHAPES[shape_name]
    win = shp.window_for(cfg, shape)
    axes = mesh_axes(mesh)
    dp = dp_axes(mesh)
    shard_ctx.install(dp, axes=axes)
    specs = shp.input_specs(cfg, shape)

    if shape.kind == "train":
        if arch in REMAT_GROUP:
            cfg = dataclasses.replace(cfg, remat_group=REMAT_GROUP[arch])
        use_fsdp = True if fsdp is None else fsdp
        opt_cfg = AdamWConfig(
            moment_dtype="bfloat16" if arch in BF16_MOMENTS else "float32")
        accum = TRAIN_ACCUM_OVERRIDE.get(arch, TRAIN_ACCUM)
        step = make_train_step(cfg, opt_cfg, window=win,
                               grad_accum=accum,
                               accum_dtype="bfloat16")
        state_sds = jax.eval_shape(
            lambda: init_state(jax.random.PRNGKey(0), cfg, opt_cfg))
        rng_sds = jax.eval_shape(lambda: jax.random.PRNGKey(0))
        st_specs = state_pspecs(state_sds, axes, fsdp=use_fsdp)
        shard_ctx.set_param_specs(st_specs.params)
        in_sh = (_named(mesh, st_specs),
                 _named(mesh, batch_pspecs(specs["batch"], dp, axes)),
                 NamedSharding(mesh, P()))
        fn = jax.jit(step, in_shardings=in_sh, donate_argnums=(0,))
        donated = sum(x.size * x.dtype.itemsize for x in
                      jax.tree_util.tree_leaves(state_sds))
        return fn, (state_sds, specs["batch"], rng_sds), donated

    use_fsdp = (arch in SERVE_FSDP) if fsdp is None else fsdp
    if shape.kind in ("decode", "prefill") and arch in KV8 \
            and cfg.attn_type == "gqa":
        cfg = dataclasses.replace(cfg, kv_cache_bits=8)
        specs = shp.input_specs(cfg, shape)  # rebuild with int8 cache
    params_sds = jax.eval_shape(
        lambda: tf.init_params(jax.random.PRNGKey(0), cfg))
    p_specs = param_pspecs(params_sds, axes, fsdp=use_fsdp)

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            logits, aux, caches = tf.forward(
                params, cfg, batch, window=win,
                collect_cache=shape.seq_len)
            return logits[:, -1], caches

        in_sh = (_named(mesh, p_specs),
                 _named(mesh, batch_pspecs(specs["batch"], dp, axes)))
        fn = jax.jit(prefill_step, in_shardings=in_sh)
        return fn, (params_sds, specs["batch"]), 0

    # decode
    serve = make_serve_step(cfg, window=win)
    c_specs = cache_pspecs(specs["caches"], dp, axes)
    from repro.sharding.specs import _dp_or_none
    in_sh = (_named(mesh, p_specs),
             _named(mesh, c_specs),
             _named(mesh, batch_pspecs(specs["batch"], dp, axes)),
             NamedSharding(mesh, P(_dp_or_none(axes, dp, shape.batch))))
    fn = jax.jit(serve, in_shardings=in_sh, donate_argnums=(1,))
    donated = sum(x.size * x.dtype.itemsize for x in
                  jax.tree_util.tree_leaves(specs["caches"]))
    return fn, (params_sds, specs["caches"], specs["batch"],
                specs["qpos"]), donated


def _donated_per_device(compiled, donated_global: int, chips: int) -> int:
    """Estimate per-device donated bytes (global / chips; the donated
    buffers — train state and decode caches — are sharded by our specs)."""
    return donated_global // max(chips, 1)


def _mem_dict(mem) -> Dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        out[k] = int(getattr(mem, k, -1))
    out["peak_bytes_per_device"] = (
        out["argument_size_in_bytes"] + out["output_size_in_bytes"] +
        out["temp_size_in_bytes"] - max(out["alias_size_in_bytes"], 0))
    return out


def _cost_dict(cost) -> Dict:
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return {k: float(v) for k, v in dict(cost).items()
            if isinstance(v, (int, float))}


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool,
               fsdp: Optional[bool] = None, save: bool = True,
               verbose: bool = True) -> Dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    shape = shp.SHAPES[shape_name]
    fn, args, donated_global = build_lowerable(arch, shape_name, mesh,
                                               fsdp=fsdp)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = _mem_dict(compiled.memory_analysis())
    # XLA:CPU does not implement buffer donation; on TPU the donated input
    # (train state / decode caches) aliases the matching output.  Report the
    # donation-adjusted peak alongside the raw one.
    n_chips_tmp = mesh.devices.size
    donated_per_dev = donated_global and _donated_per_device(
        compiled, donated_global, n_chips_tmp)
    mem["donated_bytes_per_device_est"] = int(donated_per_dev or 0)
    mem["peak_adjusted_per_device"] = (
        mem["peak_bytes_per_device"] - int(donated_per_dev or 0))
    cost = _cost_dict(compiled.cost_analysis())
    hlo = compiled.as_text()
    hl = hlo_analyze(hlo)  # loop-aware per-device totals
    cost["flops_loop_aware"] = hl["dot_flops"]
    cost["bytes_out_loop_aware"] = hl["bytes_out"]
    n_chips = mesh.devices.size
    result = dict(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=n_chips,
        kind=shape.kind,
        fsdp=bool(fsdp) if fsdp is not None else None,
        memory=mem, cost=cost,
        collective_bytes_per_device=hl["collective_bytes"],
        collective_by_op=hl["collective_by_op"],
        collective_op_counts=hl["collective_counts"],
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        hlo_bytes=len(hlo),
    )
    result["model_flops"] = model_flops(cfg, shape)
    result["roofline"] = derive_roofline(result)
    if verbose:
        rl = result["roofline"]
        print(f"[{arch} x {shape_name} x {mesh_name}] "
              f"peak/dev={mem['peak_bytes_per_device']/2**30:.2f}GiB "
              f"flops/dev={cost.get('flops', 0):.3e} "
              f"coll/dev={hl['collective_bytes']/2**20:.1f}MiB "
              f"dominant={rl['dominant']} "
              f"(compile {t_compile:.1f}s)")
        print("  memory_analysis:", json.dumps(mem))
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(
            RESULTS_DIR, f"{arch}__{shape_name}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(shp.SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all 10 assigned archs x 4 shapes")
    ap.add_argument("--fsdp", default="auto", choices=["auto", "on", "off"])
    args = ap.parse_args()

    fsdp = None if args.fsdp == "auto" else (args.fsdp == "on")
    assigned = [a for a in ARCHS if a != "tinyllava"]
    archs = assigned if args.all or args.arch is None else [args.arch]
    shapes = list(shp.SHAPES) if args.all or args.shape is None \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                try:
                    dryrun_one(arch, shape_name, multi_pod=mp, fsdp=fsdp)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape_name, mp, repr(e)))
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
