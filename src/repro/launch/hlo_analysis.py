"""Post-compile HLO analysis: loop-aware FLOPs, bytes, collective traffic.

``compiled.cost_analysis()`` counts every while-loop body ONCE — with
lax.scan over layers and microbatches that under-counts by the product of
trip counts (measured 32x on llama3.2-3b train_4k).  This module walks the
partitioned HLO text instead:

 * computations are parsed into blocks; ``while`` instructions are mapped
   to their condition/body computations, and the loop trip count is
   recovered from the largest integer constant in the condition,
 * per computation we count: dot FLOPs (2 * prod(out dims) * prod(lhs
   contracting dims)), output bytes of top-level instructions (an HBM
   write-traffic proxy), and collective result bytes per op kind,
 * totals are accumulated through the call graph (while/call/fusion
   edges), multiplying by trip counts.

Shapes in the partitioned module are per-device, so all totals are
per-chip — exactly what the roofline terms need.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_WHILE_RE = re.compile(
    r"condition=%?([\w\.\-]+)\s*,\s*body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
# conditional( branch computations: 2-way true/false form and the N-way
# branch_computations={...} form
_COND_TF_RE = re.compile(
    r"true_computation=%?([\w\.\-]+)\s*,\s*false_computation=%?([\w\.\-]+)")
_COND_BR_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DOT_OUT_RE = re.compile(r"=\s*((?:\([^=]*?\))|(?:[\w\[\],{}]+))\s+dot\(")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"dot\(\s*%?([\w\.\-]+)\s*,")
_DOT_ARGS_RE = re.compile(r"dot\(([^)]*)\)")
_TRIP_BC_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    """(total elements, total bytes) over all dtype[...] shapes in text."""
    elems = 0
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


def split_computations(hlo: str) -> Tuple[Dict[str, List[str]], str]:
    """computation name -> instruction lines; plus the ENTRY name.

    HLO text puts computation headers at column 0 and instructions
    indented, so we key on indentation rather than parsing signatures
    (whose tuple types contain nested parens).
    """
    comps: Dict[str, List[str]] = {}
    entry = None
    current = None
    for line in hlo.splitlines():
        if not line:
            continue
        if not line[0].isspace():
            token = line.split("(")[0].strip()
            if token.startswith("ENTRY"):
                token = token[len("ENTRY"):].strip()
                name = token.lstrip("%").strip()
                entry = name
                current = name
                comps[current] = []
            elif "{" in line and "(" in line and "->" in line:
                name = token.lstrip("%").strip()
                current = name
                comps[current] = []
            else:
                current = None
            continue
        if current is not None:
            if line.strip() == "}":
                current = None
            else:
                comps[current].append(line)
    return comps, entry


def _trip_count(cond_lines: List[str]) -> int:
    best = 1
    for line in cond_lines:
        for c in _CONST_RE.findall(line):
            best = max(best, int(c))
    return best


def _dot_flops(line: str, out_shapes: Dict[str, str]) -> float:
    m_out = _DOT_OUT_RE.search(line)
    if not m_out:
        return 0.0
    out_elems, _ = _shape_elems_bytes(m_out.group(1))
    contract = 1
    lhs_dims = None
    # modern HLO prints operands with inline shapes:
    #   dot(f32[32,64]{1,0} %lhs, f32[64,64]{1,0} %rhs), ...
    # so the first shape inside the call IS the lhs shape.
    m_args = _DOT_ARGS_RE.search(line)
    if m_args:
        sm = _SHAPE_RE.search(m_args.group(1))
        if sm:
            lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
    if lhs_dims is None:
        # older shape-less operand format: dot(%lhs, %rhs) — resolve the
        # operand's shape through the per-module result map.
        m_lhs = _OPERAND_RE.search(line)
        if m_lhs:
            dims_txt = _SHAPE_RE.search(out_shapes.get(m_lhs.group(1), ""))
            if dims_txt:
                lhs_dims = [int(d) for d in dims_txt.group(2).split(",")
                            if d]
    m_dims = _LHS_CONTRACT_RE.search(line)
    if lhs_dims and m_dims:
        for idx in m_dims.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


_RESULT_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")

# ops that do not write HBM (aliases, metadata, control flow — their bodies
# are walked separately)
_FREE_OPS = {
    "get-tuple-element", "tuple", "parameter", "bitcast", "constant",
    "while", "conditional", "call", "after-all", "opt-barrier",
    "reshape", "partition-id", "replica-id", "add-dependency",
}
_OP_NAME_RE = re.compile(r"\)?\s*([a-z][a-z0-9\-]*)\(")


def _build_shape_map(comps: Dict[str, List[str]]) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for lines in comps.values():
        for line in lines:
            m = _RESULT_RE.match(line)
            if m:
                rhs = m.group(2)
                # shape text is everything before the op name's '('
                out[m.group(1)] = rhs.split("(")[0]
    return out


def _call_edges(comps: Dict[str, List[str]]):
    """Call-graph edges and conditional-branch groups of an HLO module.

    Returns ``(edges, cond_groups)``: ``edges[name]`` is a list of
    ``(child, multiplier, counts_bytes)`` — loop bodies/conditions carry
    their trip count, fusion/call bodies multiplier 1 (their interior ops
    do not write HBM, hence ``counts_bytes=False``); ``cond_groups[name]``
    lists the branch-computation groups of each ``conditional`` (exactly
    one branch runs per execution).
    """
    edges: Dict[str, List[Tuple[str, int, bool]]] = defaultdict(list)
    cond_groups: Dict[str, List[List[str]]] = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            m_while = _WHILE_RE.search(line)
            if m_while:
                cond, body = m_while.groups()
                # XLA annotates resolved loops with known_trip_count in
                # the while's backend_config; fall back to the largest
                # integer constant in the condition computation.
                m_bc = _TRIP_BC_RE.search(line)
                trips = int(m_bc.group(1)) if m_bc else \
                    _trip_count(comps.get(cond, []))
                edges[name].append((body, trips, True))
                edges[name].append((cond, trips, True))
            m_tf = _COND_TF_RE.search(line)
            if m_tf:
                cond_groups[name].append([m_tf.group(1), m_tf.group(2)])
            else:
                m_br = _COND_BR_RE.search(line)
                if m_br:
                    cond_groups[name].append(
                        [b.strip().lstrip("%")
                         for b in m_br.group(1).split(",") if b.strip()])
        text = "\n".join(lines)
        for child in _CALL_RE.findall(text):
            edges[name].append((child, 1, False))
        for child in _CALLS_RE.findall(text):
            if child not in [c for c, _, _ in edges[name]]:
                edges[name].append((child, 1, False))
    return edges, cond_groups


_CP_PAIRS_RE = re.compile(
    r"source_target_pairs=\{(\{\d+,\d+\}(?:,\{\d+,\d+\})*)\}")
_PAIR_RE = re.compile(r"\{(\d+),(\d+)\}")


def collective_permute_pairs(hlo: str) -> Dict[Tuple[int, int], int]:
    """Loop-aware collective-permute bytes per directed DEVICE pair.

    ``analyze()['collective_by_op']`` charges the whole module with every
    collective-permute instruction's full result bytes — the right number
    for "what does the SPMD program execute per chip", but an overcount of
    what any single link actually carries: a device appearing in none of
    an instruction's ``source_target_pairs`` transmits nothing for it.
    This walk attributes each instruction's result bytes (x loop trips) to
    each of its (src, dst) pairs individually, so callers can aggregate
    true per-link traffic (``repro.launch.split_hub.hlo_link_bytes`` maps
    device ids back to pod stages via the mesh).
    """
    comps, entry = split_computations(hlo)
    per_comp: Dict[str, List[Tuple[List[Tuple[int, int]], int]]] = {}
    for name, lines in comps.items():
        items = []
        for line in lines:
            m = _RESULT_RE.match(line.strip())
            if not m:
                continue
            rhs = m.group(2)
            if not re.search(r"\bcollective-permute(?:-start)?\(", rhs):
                continue
            _, out_b = _shape_elems_bytes(rhs.split("(")[0])
            pm = _CP_PAIRS_RE.search(rhs)
            if not pm:
                continue
            pairs = [(int(a), int(b)) for a, b in
                     _PAIR_RE.findall(pm.group(1))]
            items.append((pairs, out_b))
        if items:
            per_comp[name] = items

    edges, cond_groups = _call_edges(comps)
    out: Dict[Tuple[int, int], int] = defaultdict(int)
    visiting = set()

    def walk(name: str, mult: int) -> None:
        if name not in comps or name in visiting or mult <= 0:
            return
        visiting.add(name)
        for pairs, b in per_comp.get(name, []):
            for p in pairs:
                out[p] += b * mult
        for child, m, _cb in edges.get(name, []):
            walk(child, mult * m)
        # a conditional runs one branch per execution; a ship op lives in
        # at most one branch in our programs, so charging each branch at
        # the parent multiplier attributes it correctly
        for branches in cond_groups.get(name, []):
            for br in branches:
                walk(br, mult)
        visiting.discard(name)

    if entry:
        walk(entry, 1)
    return dict(out)


def analyze(hlo: str) -> Dict:
    """Loop-aware per-device totals: dot FLOPs, output bytes, collectives."""
    comps, entry = split_computations(hlo)
    shape_map = _build_shape_map(comps)

    per_comp = {}
    for name, lines in comps.items():
        flops = 0.0
        bytes_out = 0
        coll: Dict[str, int] = defaultdict(int)
        coll_counts: Dict[str, int] = defaultdict(int)
        for line in lines:
            stripped = line.strip()
            m = _RESULT_RE.match(stripped)
            if not m:
                continue
            rhs = m.group(2)
            head = rhs.split("(")[0]
            opm = _OP_NAME_RE.search(rhs)
            op_name = opm.group(1) if opm else ""
            _, out_b = _shape_elems_bytes(head)
            if op_name not in _FREE_OPS:
                bytes_out += out_b
            if " dot(" in rhs or rhs.startswith("dot("):
                flops += _dot_flops(stripped, shape_map)
            for op in COLLECTIVE_OPS:
                if re.search(rf"\b{op}(?:-start)?\(", rhs):
                    coll[op] += out_b
                    coll_counts[op] += 1
                    break
        per_comp[name] = (flops, bytes_out, dict(coll), dict(coll_counts))

    # call-graph edges: (child, multiplier, counts_bytes) — see
    # _call_edges.  conditional( branches are NOT plain edges: exactly one
    # branch runs per execution, so each conditional contributes the
    # elementwise MAX over its branch subtrees, once — not the sum
    # ("always-taken").
    edges, cond_groups = _call_edges(comps)

    def _zero():
        return dict(flops=0.0, bytes=0, coll=defaultdict(int),
                    coll_n=defaultdict(int))

    memo: Dict[Tuple[str, bool], Dict] = {}
    visiting = set()
    truncations = [0]  # bumped whenever a back-edge is skipped

    def subtree(name: str, count_bytes: bool) -> Dict:
        """Per-execution totals of ``name`` including everything it calls.

        The call graph of valid HLO is a DAG, so memoization makes the
        walk linear; ``visiting`` breaks cycles a malformed module could
        contain, and any subtree that hit a back-edge is NOT memoized
        (nor are its ancestors), so truncated totals never poison the
        cache.
        """
        if name not in per_comp:
            return _zero()
        if name in visiting:
            truncations[0] += 1
            return _zero()
        key = (name, count_bytes)
        if key in memo:
            return memo[key]
        trunc_before = truncations[0]
        visiting.add(name)
        flops, bytes_out, coll, coll_counts = per_comp[name]
        tot = _zero()
        tot["flops"] = flops
        tot["bytes"] = bytes_out if count_bytes else 0
        tot["coll"].update(coll)
        tot["coll_n"].update(coll_counts)
        for child, mult, cb in edges.get(name, []):
            sub = subtree(child, count_bytes and cb)
            tot["flops"] += sub["flops"] * mult
            tot["bytes"] += sub["bytes"] * mult
            for k, v in sub["coll"].items():
                tot["coll"][k] += v * mult
            for k, v in sub["coll_n"].items():
                tot["coll_n"][k] += v * mult
        for branches in cond_groups.get(name, []):
            subs = [subtree(b, count_bytes) for b in branches]
            if not subs:
                continue
            tot["flops"] += max(s["flops"] for s in subs)
            tot["bytes"] += max(s["bytes"] for s in subs)
            for field in ("coll", "coll_n"):
                for k in set().union(*[s[field].keys() for s in subs]):
                    tot[field][k] += max(s[field].get(k, 0) for s in subs)
        visiting.discard(name)
        if truncations[0] == trunc_before:
            memo[key] = tot
        return tot

    tot = subtree(entry, True) if entry else _zero()
    return dict(
        dot_flops=tot["flops"],
        bytes_out=float(tot["bytes"]),
        collective_bytes=int(sum(tot["coll"].values())),
        collective_by_op={k: int(v) for k, v in tot["coll"].items()},
        collective_counts={k: int(v) for k, v in tot["coll_n"].items()},
        n_computations=len(comps),
    )


def entry_parameter_bytes(hlo: str) -> int:
    """Total bytes of the ENTRY computation's parameter instructions.

    For a jitted function this is what the executable streams in per call
    — for a weights-consuming forward, the weight HBM read floor.  The
    wq benchmark compares this between the dense and the packed stacks to
    assert the int4 weight-byte cut survives compilation (codes stay u8,
    scales f16 — nothing silently widened by XLA).
    """
    comps, entry = split_computations(hlo)
    total = 0
    for line in comps.get(entry, []):
        m = _RESULT_RE.match(line.strip())
        if not m:
            continue
        rhs = m.group(2)
        opm = _OP_NAME_RE.search(rhs)
        if not opm or opm.group(1) != "parameter":
            continue
        _, b = _shape_elems_bytes(rhs.split("(")[0])
        total += b
    return total


def collective_bytes(hlo: str) -> Tuple[int, Dict[str, int]]:
    res = analyze(hlo)
    return res["collective_bytes"], res["collective_by_op"]


def count_op(hlo: str, opname: str) -> int:
    return len(re.findall(rf"\s{opname}(?:-start)?\(", hlo))
