"""Production mesh definitions (TPU v5e pods).

Functions, not module-level constants, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS *before* any jax
initialization).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12  # per chip, FLOP/s
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU tests (requires host-device override)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axis group: ('pod','data') on multi-pod meshes."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
