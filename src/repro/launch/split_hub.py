"""Many-client split-learning hub: N clients sharing one server stack.

BEYOND-PAPER (ROADMAP item 2): the paper deploys exactly one client and
one server; the SL-for-LLM survey and VFLAIR-LLM (PAPERS.md) frame the
real setting as N clients — each with its own data distribution,
quantizer calibration and tick rate — sharing one server.  Topology:

  pod 0 (client 0): embed + layers[:L/2] -> quantize -> ship  \\
  pod 1 (client 1): embed + layers[:L/2] -> quantize -> ship   > star
  ...                                                         /
  pod N (server): dequantize x N -> layers[L/2:] -> head -> CE/client

Each client->server edge is its own ``core.split.WireLink`` with its own
``QuantConfig`` (heterogeneous clients exercise the per-link byte
accounting) — and its own collective: ppermute forbids one destination
receiving from two sources, so hub ships are per-link by construction.
The server runs its half ONCE per tick, batched over the N arrivals.

Two schedules (``repro.launch.schedules``):

* **lockstep** — every client ships every tick; GPipe-style 1-tick
  fill/drain.  With ``n_clients == 1`` this is exactly the paper's
  2-partition pipeline (``launch/split_pipeline``) and reproduces its
  loss to 3e-6 (asserted by the parity dry-run below).
* **async** — clients tick at different rates (``HubConfig.tick_rates``);
  the server applies the aggregated gradient per arrival while each
  client updates only when its own gradient returns, tolerating the
  staleness.  Per-client NF/RD-FSQ calibration EMAs stay isolated.

The __main__ dry-run lowers the lockstep hub on a fake-device mesh and
asserts every link's static CommPayload bytes against the lowered HLO's
collective-permute traffic for that link's device pairs, runs the N=1
parity check, and trains the async hub for a few ticks:

    PYTHONPATH=src python -m repro.launch.split_hub --smoke
      (3 clients + 1 server on 8 fake devices, heterogeneous 2/4-bit)
"""
import os
import sys

if __name__ == "__main__":  # must run before any jax import
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

# ruff: noqa: E402
import functools
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.quantizers import QuantConfig
from repro.core.split import HubConfig
from repro.core.split_stage import init_stage_params
from repro.launch import schedules
from repro.optim import AdamWConfig, init_opt_state


def hub_mesh(n_clients: int, data_shards: int = 2):
    """(pod, data) mesh with one pod per client plus one for the server."""
    return jax.make_mesh((n_clients + 1, data_shards), ("pod", "data"))


def init_hub_params(key, cfg: ArchConfig, hub: HubConfig,
                    lora_rank: int = 0) -> Dict:
    """Stage-stacked hub parameters: blocks (N+1, L/2, ...) — N client
    bottom halves + 1 server top half; embed/head/final norm shared.
    ``lora_rank > 0`` adds the stage-stacked ``"adapters"`` LoRA tree."""
    assert cfg.n_layers % 2 == 0, cfg.n_layers
    return init_stage_params(key, cfg, hub.n_clients + 1, cfg.n_layers // 2,
                             lora_rank=lora_rank)


def hub_wire_bytes(cfg: ArchConfig, hub: HubConfig, micro_batch: int,
                   seq: int, data_shards: int = 1,
                   lora_rank: int = 0) -> Dict:
    """Per-link static wire bytes of the hub (see schedules.hub_wire_bytes)."""
    return schedules.hub_wire_bytes(cfg, hub, micro_batch, seq,
                                    data_shards=data_shards,
                                    lora_rank=lora_rank)


def hlo_link_bytes(hlo_text: str, mesh, axis: str = "pod"
                   ) -> Dict[Tuple[int, int], int]:
    """Measured per-link collective-permute bytes of a lowered program:
    device-pair traffic (``hlo_analysis.collective_permute_pairs``)
    aggregated to stage links through the mesh's ``axis`` coordinates."""
    from repro.launch.hlo_analysis import collective_permute_pairs

    return schedules.pod_link_bytes(collective_permute_pairs(hlo_text),
                                    mesh, axis)


build_hub_step = schedules.build_hub_step
build_hub_grad_step = schedules.build_hub_grad_step


@functools.lru_cache(maxsize=16)
def _cached_hub_update(cfg: ArchConfig, mesh, hub: HubConfig,
                       opt_cfg: AdamWConfig, n_micro: int,
                       micro_batch: int, seq: int, warmup_steps: int,
                       total_steps: int, lora_rank: int = 0):
    """One jitted lockstep (hub grad step + AdamW apply) per configuration
    — the same recompile-avoidance cache as
    ``split_pipeline._cached_pipeline_update``.  ``lora_rank`` joins the
    cache key: the SplitLoRA update differentiates and steps the adapter
    tree only (the grads crossing the wire are the quantized adapter-grad
    return payloads of ``build_hub_grad_step``)."""
    from repro.train.loop import apply_adapter_gradients, apply_gradients

    grad_step = build_hub_grad_step(cfg, mesh, hub, n_micro, micro_batch,
                                    seq, lora_rank=lora_rank)

    @jax.jit
    def update(state, tokens, labels):
        loss, per_client, grads, wire_b = grad_step(state.params, tokens,
                                                    labels)
        if lora_rank > 0:
            state, _ = apply_adapter_gradients(state, grads, opt_cfg,
                                               warmup_steps=warmup_steps,
                                               total_steps=total_steps)
        else:
            state, _ = apply_gradients(state, grads, opt_cfg,
                                       warmup_steps=warmup_steps,
                                       total_steps=total_steps)
        return state, loss, per_client, wire_b

    return update


def train_hub(cfg: ArchConfig, hub: HubConfig, opt_cfg: AdamWConfig,
              batches: Iterable[Tuple[jnp.ndarray, jnp.ndarray]], *,
              micro_batch: int, seq: int, mode: str = "lockstep",
              mesh=None, n_micro: int = 1, n_ticks: Optional[int] = None,
              params: Optional[Dict] = None, warmup_steps: int = 0,
              total_steps: int = 0, seed: int = 0,
              wire_budget_bytes: Optional[float] = None,
              plan_groups: int = 8, replan_every: int = 1,
              plan_log: Optional[List] = None,
              lora_rank: int = 0) -> Dict:
    """Train the N-client hub.

    ``mode="lockstep"``: every client ships every tick on the SPMD mesh
    (``mesh`` required, pod axis of n_clients + 1); each element of
    ``batches`` is (tokens, labels) of shape (n_micro, N, B, S) and one
    optimizer step consumes one element.  Returns dict(params, opt,
    history, per_client, wire_bytes_per_tick).

    ``mode="async"``: the staleness-tolerant host loop — clients arrive
    per ``hub.tick_rates``, the server applies gradients per arrival,
    per-client calibration EMAs advance only for arrivals.  ``batches``
    yields (N, B, S) candidate microbatches, one per global tick
    (``n_ticks`` of them).  Mesh-free (in-graph wire form).  Returns
    dict(state, history, masks, quant_rel_err).

    Entropy-adaptive wire (lockstep only): ``wire_budget_bytes`` turns
    on per-client re-planning between compiled steps — each client's
    boundary activation feeds its OWN per-channel entropy EMA (clients
    have different data distributions; their plans must stay isolated,
    like their codec calibration), and each link gets its own
    ``plan_groups``-group width plan under the shared per-link budget.
    Plans live on the clients' ``QuantConfig.group_widths``, so the
    update cache compiles once per distinct plan vector.  ``plan_log``
    receives (step, plans) tuples on change.

    SplitLoRA (ROADMAP item 4): ``lora_rank > 0`` freezes the base
    weights and trains only the LoRA adapter tree in BOTH modes.  In
    lockstep the server's quantized gradient return shrinks to the
    adapter-grad payload (``hub.grad_quant`` codec); async runs the
    in-graph twin.  Optimizer moments are sized by adapter params only.
    """
    if mode == "lockstep":
        from repro.core import entropy as entropy_mod
        from repro.train.loop import TrainState, init_adapter_state

        assert mesh is not None, "lockstep mode needs the hub mesh"
        adaptive = wire_budget_bytes is not None
        update = _cached_hub_update(cfg, mesh, hub, opt_cfg, n_micro,
                                    micro_batch, seq, warmup_steps,
                                    total_steps, lora_rank)
        if params is None:
            params = init_hub_params(jax.random.PRNGKey(seed), cfg, hub,
                                     lora_rank=lora_rank)
        if lora_rank > 0:
            state = init_adapter_state(params, opt_cfg)
        else:
            state = TrainState(params=params,
                               opt=init_opt_state(params, opt_cfg),
                               step=jnp.zeros((), jnp.int32))
        n = hub.n_clients
        emas = ([entropy_mod.init_entropy_ema(cfg.d_model)
                 for _ in range(n)] if adaptive else None)
        scalars_per_ch = (micro_batch // mesh.shape["data"]) * seq
        plans: Tuple[Tuple[int, ...], ...] = ((),) * n
        history: List[float] = []
        per_client = None
        wire_b = 0.0
        with mesh:
            for step_i, (tokens, labels) in enumerate(batches):
                if adaptive and step_i % max(replan_every, 1) == 0:
                    new_plans = []
                    for c in range(n):
                        h = schedules.boundary_probe(cfg, state.params,
                                                     tokens[0, c], c)
                        emas[c] = entropy_mod.update_entropy_ema(emas[c], h)
                        new_plans.append(schedules.replan_widths(
                            emas[c], wire_budget_bytes,
                            n_groups=plan_groups,
                            scalars_per_channel=scalars_per_ch))
                    if tuple(new_plans) != plans:
                        plans = tuple(new_plans)
                        if plan_log is not None:
                            plan_log.append((step_i, plans))
                        hub = hub.with_plans(plans)
                        update = _cached_hub_update(
                            cfg, mesh, hub, opt_cfg, n_micro, micro_batch,
                            seq, warmup_steps, total_steps, lora_rank)
                state, loss, pc, wb = update(state, tokens, labels)
                history.append(float(loss))
                per_client = np.asarray(pc)
                wire_b = float(wb)
        return dict(params=state.params, opt=state.opt, history=history,
                    per_client=per_client, wire_bytes_per_tick=wire_b)

    if mode != "async":
        raise ValueError(f"unknown hub mode {mode!r}")

    rates = hub.resolve_tick_rates()
    assert n_ticks is not None, "async mode needs n_ticks"
    state = schedules.init_hub_state(jax.random.PRNGKey(seed), cfg, hub,
                                     opt_cfg, lora_rank=lora_rank)
    update = schedules.build_async_update(cfg, hub, opt_cfg, micro_batch,
                                          seq, lora_rank=lora_rank)
    history: List[float] = []
    masks: List[np.ndarray] = []
    rel_err = None
    for _t, mask, (tokens, labels) in schedules.async_tick_stream(
            batches, rates, n_ticks):
        state, metrics = update(state, jnp.asarray(tokens),
                                jnp.asarray(labels), jnp.asarray(mask))
        history.append(float(metrics["loss"]))
        masks.append(mask)
        rel_err = np.asarray(metrics["quant_rel_err"])
    return dict(state=state, history=history, masks=masks,
                quant_rel_err=rel_err)


# ---------------------------------------------------------------------------
# dry-runs
# ---------------------------------------------------------------------------

def _hub_quants(n_clients: int) -> Tuple[QuantConfig, ...]:
    """Heterogeneous per-client compressors: alternate 2-bit RD-FSQ and
    4-bit NF so neighbouring links carry different payloads."""
    return tuple(QuantConfig(method="rdfsq", bits=2) if c % 2 == 0
                 else QuantConfig(method="nf", bits=4)
                 for c in range(n_clients))


def dryrun_hub(arch: str = "llama3_2_3b", n_clients: int = 3,
               n_micro: int = 3, micro_batch: int = 4, seq: int = 16,
               reduced: bool = True) -> Dict:
    """Lower + compile the lockstep hub (N clients + 1 server) and assert
    every client->server link's static CommPayload bytes against the HLO
    collective-permute traffic of that link's device pairs, within 1%."""
    from repro.configs import get_config
    from repro.launch.split_pipeline import assert_links_match_hlo

    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    assert cfg.n_layers % 2 == 0, cfg.n_layers
    hub = HubConfig(n_clients=n_clients,
                    client_quants=_hub_quants(n_clients))
    mesh = hub_mesh(n_clients)
    params_sds = jax.eval_shape(
        lambda: init_hub_params(jax.random.PRNGKey(0), cfg, hub))
    tok_sds = jax.ShapeDtypeStruct(
        (n_micro, n_clients, micro_batch, seq), jnp.int32)
    n_ticks = n_micro + 1  # 1-tick fill/drain: served one tick after ship

    step = build_hub_step(cfg, mesh, hub, n_micro, micro_batch, seq)
    with mesh:
        compiled = jax.jit(step).lower(params_sds, tok_sds,
                                       tok_sds).compile()
    hlo = compiled.as_text()
    wire = hub_wire_bytes(cfg, hub, micro_batch, seq,
                          data_shards=mesh.shape["data"])
    assert_links_match_hlo(f"hub {arch} N={n_clients}", hlo, mesh, wire,
                           n_ticks)
    measured = hlo_link_bytes(hlo, mesh)
    print(f"[split-hub {arch} N={n_clients}] per-link HLO bytes: "
          + ", ".join(f"{s}->{d}: {v / 1024:.1f} KiB"
                      for (s, d), v in sorted(measured.items())))
    return dict(
        wire_links={f"{s}->{d}": v["fwd"]
                    for (s, d), v in wire["links"].items()},
        hlo_links={f"{s}->{d}": v for (s, d), v in measured.items()},
        wire_bytes_per_tick=wire["fwd_tick"],
    )


def dryrun_parity(arch: str = "llama3_2_3b", n_micro: int = 3,
                  micro_batch: int = 4, seq: int = 16,
                  tol: float = 3e-6) -> Dict:
    """The hub with ONE client is the paper's 2-partition pipeline: same
    parameters, same quantized wire, same loss — to ``tol``."""
    from repro.launch import split_pipeline as sp
    from repro.train.losses import IGNORE

    cfg = sp._homogeneous_cfg(arch, reduced=True, n_stages=2)
    q = QuantConfig(method="rdfsq", bits=2)
    key = jax.random.PRNGKey(0)
    params = sp.init_pipeline_params(key, cfg)  # == init_hub_params(N=1)
    tokens = jax.random.randint(key, (n_micro, micro_batch, seq), 0,
                                cfg.vocab_size)
    labels = jnp.concatenate(
        [tokens[:, :, 1:],
         jnp.full((n_micro, micro_batch, 1), IGNORE, tokens.dtype)],
        axis=-1)
    mesh = hub_mesh(1)

    pipe_step = sp.build_pipeline_step(cfg, mesh, q, n_micro, micro_batch,
                                       seq)
    hub = HubConfig(n_clients=1, quant=q)
    hub_step = build_hub_step(cfg, mesh, hub, n_micro, micro_batch, seq)
    with mesh:
        loss_pipe, _ = jax.jit(pipe_step)(params, tokens, labels)
        loss_hub, per_client, _ = jax.jit(hub_step)(
            params, tokens[:, None], labels[:, None])
    diff = abs(float(loss_pipe) - float(loss_hub))
    print(f"[split-hub parity] pipeline {float(loss_pipe):.6f} vs "
          f"hub(N=1) {float(loss_hub):.6f} (|diff| {diff:.2e})")
    assert diff < tol, (float(loss_pipe), float(loss_hub), diff)
    return dict(loss_pipeline=float(loss_pipe), loss_hub=float(loss_hub),
                diff=diff)


def dryrun_hub_grouped(arch: str = "llama3_2_3b", n_clients: int = 3,
                       n_micro: int = 3, micro_batch: int = 4,
                       seq: int = 16) -> Dict:
    """Grouped mixed-precision hub links, HLO-asserted per client.

    Client 0 ships a uniform 3-bit grouped FSQ plan (pure code bytes —
    must cost exactly 3/16 of the identity bf16 wire), client 1 the
    identity wire (the 16-bit reference on the same topology), and the
    remaining clients adaptive-shaped mixed-width RD-FSQ plans.  Every
    link's static ``GroupedPayload`` bytes are asserted against the HLO
    collective-permute traffic of that link's device pairs, within 1%.
    """
    from repro.configs import get_config
    from repro.launch.split_pipeline import assert_links_match_hlo

    cfg = get_config(arch).reduced()
    assert cfg.d_model % 8 == 0, cfg.d_model
    quants = [QuantConfig(method="fsq", group_widths=(3,) * 8),
              QuantConfig(method="identity")]
    quants += [QuantConfig(method="rdfsq", group_widths=(1, 2, 3, 8))
               for _ in range(n_clients - 2)]
    hub = HubConfig(n_clients=n_clients, client_quants=tuple(quants))
    mesh = hub_mesh(n_clients)
    params_sds = jax.eval_shape(
        lambda: init_hub_params(jax.random.PRNGKey(0), cfg, hub))
    tok_sds = jax.ShapeDtypeStruct(
        (n_micro, n_clients, micro_batch, seq), jnp.int32)
    n_ticks = n_micro + 1

    step = build_hub_step(cfg, mesh, hub, n_micro, micro_batch, seq)
    with mesh:
        compiled = jax.jit(step).lower(params_sds, tok_sds,
                                       tok_sds).compile()
    hlo = compiled.as_text()
    wire = hub_wire_bytes(cfg, hub, micro_batch, seq,
                          data_shards=mesh.shape["data"])
    assert_links_match_hlo(f"hub grouped {arch} N={n_clients}", hlo, mesh,
                           wire, n_ticks)
    links = wire["links"]
    ratio = (links[(0, hub.server_stage)]["fwd"]
             / links[(1, hub.server_stage)]["fwd"])
    print(f"[split-hub grouped] 3-bit/bf16 link ratio {ratio:.6f} "
          f"(exact 3/16 = {3 / 16:.6f})")
    assert abs(ratio - 3.0 / 16.0) < 0.01 * (3.0 / 16.0), ratio
    return dict(
        wire_links={f"{s}->{d}": v["fwd"] for (s, d), v in links.items()},
        ratio_3bit=ratio,
    )


def dryrun_parity_grouped(arch: str = "llama3_2_3b", n_micro: int = 3,
                          micro_batch: int = 4, seq: int = 16,
                          tol: float = 3e-6) -> Dict:
    """The identity plan: a single-group grouped wire IS the static wire.

    ``group_widths=(2,)`` slices the channel axis into one group whose
    scale statistics cover the whole tensor — numerically the static
    2-bit codec, shipped as a 1-group ``GroupedPayload``.  The hub(N=1)
    loss under that plan must match the monolithic static-2-bit pipeline
    loss to ``tol`` — the refactor's no-behavior-change anchor.
    """
    from repro.launch import split_pipeline as sp
    from repro.train.losses import IGNORE

    cfg = sp._homogeneous_cfg(arch, reduced=True, n_stages=2)
    q_static = QuantConfig(method="rdfsq", bits=2)
    q_plan = QuantConfig(method="rdfsq", bits=2, group_widths=(2,))
    key = jax.random.PRNGKey(0)
    params = sp.init_pipeline_params(key, cfg)
    tokens = jax.random.randint(key, (n_micro, micro_batch, seq), 0,
                                cfg.vocab_size)
    labels = jnp.concatenate(
        [tokens[:, :, 1:],
         jnp.full((n_micro, micro_batch, 1), IGNORE, tokens.dtype)],
        axis=-1)
    mesh = hub_mesh(1)

    pipe_step = sp.build_pipeline_step(cfg, mesh, q_static, n_micro,
                                       micro_batch, seq)
    hub = HubConfig(n_clients=1, quant=q_plan)
    hub_step = build_hub_step(cfg, mesh, hub, n_micro, micro_batch, seq)
    with mesh:
        loss_pipe, _ = jax.jit(pipe_step)(params, tokens, labels)
        loss_hub, _, _ = jax.jit(hub_step)(
            params, tokens[:, None], labels[:, None])
    diff = abs(float(loss_pipe) - float(loss_hub))
    print(f"[split-hub parity grouped] static-2bit pipeline "
          f"{float(loss_pipe):.6f} vs hub(N=1) identity-plan "
          f"{float(loss_hub):.6f} (|diff| {diff:.2e})")
    assert diff < tol, (float(loss_pipe), float(loss_hub), diff)
    return dict(loss_pipeline=float(loss_pipe), loss_hub=float(loss_hub),
                diff=diff)


def dryrun_train_adaptive(arch: str = "llama3_2_3b", n_clients: int = 3,
                          n_steps: int = 4, n_micro: int = 2,
                          micro_batch: int = 4, seq: int = 32,
                          lr: float = 5e-3) -> Dict:
    """Execute the per-client re-planning lockstep hub end to end: every
    client's entropy EMA drives its own plan under a shared ~2-bit code
    budget; asserts the loss decreases and the adopted plans respect the
    budget."""
    from repro.configs import get_config
    from repro.data.pipeline import make_pipeline

    cfg = get_config(arch).reduced()
    hub = HubConfig(n_clients=n_clients,
                    quant=QuantConfig(method="rdfsq", bits=2))
    mesh = hub_mesh(n_clients)
    pipe = make_pipeline(cfg, n_micro * n_clients * micro_batch, seq,
                         seed=0)

    def batches():
        for _ in range(n_steps):
            b = next(pipe)
            yield (b["tokens"].reshape(n_micro, n_clients, micro_batch,
                                       seq),
                   b["labels"].reshape(n_micro, n_clients, micro_batch,
                                       seq))

    budget = (micro_batch // 2) * seq * cfg.d_model * 2 / 8
    plan_log: List = []
    opt = AdamWConfig(lr=lr, weight_decay=0.0)
    out = train_hub(cfg, hub, opt, batches(), micro_batch=micro_batch,
                    seq=seq, mode="lockstep", mesh=mesh, n_micro=n_micro,
                    wire_budget_bytes=budget, plan_groups=8,
                    plan_log=plan_log)
    hist = out["history"]
    plans = plan_log[-1][1] if plan_log else ()
    print(f"[split-hub adaptive N={n_clients}] loss "
          + " -> ".join(f"{v:.4f}" for v in hist)
          + f" (plans {plans})")
    assert hist[-1] < hist[0], f"adaptive hub loss did not decrease: {hist}"
    assert plan_log, "adaptive hub never adopted a plan"
    for per_client_plans in (p for _, p in plan_log):
        for p in per_client_plans:
            assert len(p) == 8 and all(1 <= w <= 8 for w in p), p
            assert sum(p) / len(p) <= 2.0 + 1e-9, p
    return dict(loss_history=hist,
                plans=[list(p) for p in plans],
                wire_bytes_per_tick=out["wire_bytes_per_tick"])


def dryrun_train_async(arch: str = "llama3_2_3b", n_clients: int = 3,
                       n_ticks: int = 24, micro_batch: int = 4,
                       seq: int = 32, lr: float = 5e-3) -> Dict:
    """Execute the staleness-tolerant async hub for a few dozen global
    ticks — heterogeneous quants AND tick rates — and check the arrival
    loss decreases (monotone-ish: windowed means, not per-tick)."""
    from repro.configs import get_config
    from repro.data.pipeline import make_pipeline

    cfg = get_config(arch).reduced()
    hub = HubConfig(n_clients=n_clients,
                    client_quants=_hub_quants(n_clients),
                    bwd_quant=QuantConfig(method="rdfsq", bits=2),
                    tick_rates=tuple(1 + c % 3 for c in range(n_clients)))
    pipe = make_pipeline(cfg, n_clients * micro_batch, seq, seed=0)

    def batches():
        while True:
            b = next(pipe)
            yield (b["tokens"].reshape(n_clients, micro_batch, seq),
                   b["labels"].reshape(n_clients, micro_batch, seq))

    opt = AdamWConfig(lr=lr, weight_decay=0.0)
    out = train_hub(cfg, hub, opt, batches(), micro_batch=micro_batch,
                    seq=seq, mode="async", n_ticks=n_ticks)
    hist = out["history"]
    k = max(3, n_ticks // 6)
    head, tail = float(np.mean(hist[:k])), float(np.mean(hist[-k:]))
    n_arrivals = int(sum(m.sum() for m in out["masks"]))
    print(f"[split-hub async N={n_clients}] loss "
          + " -> ".join(f"{v:.4f}" for v in hist[:4])
          + f" ... {hist[-1]:.4f} (first-{k} mean {head:.4f}, last-{k} "
          f"mean {tail:.4f}; {n_arrivals} arrivals/{n_ticks} ticks)")
    assert tail < head, f"async hub loss did not decrease: {hist}"
    calib = out["state"]["calib"]
    assert float(jnp.min(calib["count"])) > 0, \
        "some client's calibration never updated"
    return dict(loss_history=hist, head_mean=head, tail_mean=tail,
                n_arrivals=n_arrivals,
                quant_rel_err=[float(v) for v in out["quant_rel_err"]])


def dryrun_lora(arch: str = "llama3_2_3b", n_clients: int = 3,
                n_steps: int = 4, n_micro: int = 2, micro_batch: int = 4,
                seq: int = 32, lora_rank: int = 4,
                lr: float = 3e-2) -> Dict:
    """SplitLoRA hub acceptance gate (ROADMAP item 4).

    Three checks:

    1. **adapter-grad wire vs HLO** — lower the LoRA lockstep grad step
       (heterogeneous client quants, 8-bit RD-FSQ adapter-grad codec) and
       assert every link's static bytes against the compiled HLO
       collective-permute traffic: forward ships x ticks PLUS the
       adapter-grad round trip once per step, in both directions.
    2. **lockstep trains** — loss decreases with every base weight
       bit-frozen and AdamW moments sized by the adapter params only.
    3. **async trains** — the in-graph twin also learns (windowed means)
       with its per-client adapter state advancing.
    """
    from repro.configs import get_config
    from repro.core.split import tree_payload_bytes
    from repro.data.pipeline import make_pipeline
    from repro.launch.split_pipeline import assert_links_match_hlo
    from repro.optim import param_bytes
    from repro.peft import adapter_bytes

    cfg = get_config(arch).reduced()
    grad_q = QuantConfig(method="rdfsq", bits=8, stats_axis="tensor")
    hub = HubConfig(n_clients=n_clients,
                    client_quants=_hub_quants(n_clients),
                    grad_quant=grad_q)
    mesh = hub_mesh(n_clients)

    # 1. HLO assertion on the adapter-grad return wire
    params_sds = jax.eval_shape(
        lambda: init_hub_params(jax.random.PRNGKey(0), cfg, hub,
                                lora_rank=lora_rank))
    tok_sds = jax.ShapeDtypeStruct(
        (n_micro, n_clients, micro_batch, seq), jnp.int32)
    grad_step = build_hub_grad_step(cfg, mesh, hub, n_micro, micro_batch,
                                    seq, lora_rank=lora_rank)
    with mesh:
        compiled = jax.jit(grad_step).lower(params_sds, tok_sds,
                                            tok_sds).compile()
    wire = hub_wire_bytes(cfg, hub, micro_batch, seq,
                          data_shards=mesh.shape["data"],
                          lora_rank=lora_rank)
    assert_links_match_hlo(f"hub lora r={lora_rank} {arch} N={n_clients}",
                           compiled.as_text(), mesh, wire,
                           n_micro + 1, check_bwd=True, check_grad=True)
    # the reduction claim: the adapter-grad payload vs shipping one
    # stage's FULL param-grads through the same 8-bit codec
    ad_stage = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
        params_sds["adapters"])
    full_stage = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
        params_sds["blocks"])
    ad_payload = tree_payload_bytes(grad_q, ad_stage)
    full_payload = tree_payload_bytes(grad_q, full_stage)
    print(f"[split-hub lora] adapter-grad payload {ad_payload / 1024:.1f} "
          f"KiB vs full param-grad {full_payload / 1024:.1f} KiB "
          f"({full_payload / max(ad_payload, 1):.1f}x smaller)")
    assert ad_payload < full_payload / 4, (ad_payload, full_payload)

    # 2. lockstep LoRA training: loss down, base frozen, opt adapter-sized
    params0 = init_hub_params(jax.random.PRNGKey(0), cfg, hub,
                              lora_rank=lora_rank)
    base0 = jax.tree_util.tree_map(
        jnp.copy, {k: v for k, v in params0.items() if k != "adapters"})
    pipe = make_pipeline(cfg, n_micro * n_clients * micro_batch, seq,
                         seed=0)

    def batches():
        for _ in range(n_steps):
            b = next(pipe)
            yield (b["tokens"].reshape(n_micro, n_clients, micro_batch,
                                       seq),
                   b["labels"].reshape(n_micro, n_clients, micro_batch,
                                       seq))

    opt_cfg = AdamWConfig(lr=lr, weight_decay=0.0)
    out = train_hub(cfg, hub, opt_cfg, batches(), micro_batch=micro_batch,
                    seq=seq, mode="lockstep", mesh=mesh, n_micro=n_micro,
                    params=params0, lora_rank=lora_rank)
    hist = out["history"]
    print(f"[split-hub lora lockstep N={n_clients} r={lora_rank}] loss "
          + " -> ".join(f"{v:.4f}" for v in hist))
    assert hist[-1] < hist[0], f"LoRA hub loss did not decrease: {hist}"
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(base0),
            jax.tree_util.tree_leaves_with_path(
                {k: v for k, v in out["params"].items()
                 if k != "adapters"})):
        assert bool(jnp.array_equal(a, b)), \
            f"base weight changed during LoRA hub training: {pa}"
    ad_bytes = adapter_bytes(out["params"]["adapters"])
    m_bytes = param_bytes(out["opt"]["m"])
    assert m_bytes == ad_bytes, (m_bytes, ad_bytes)

    # 3. async LoRA: the in-graph twin learns too
    hub_async = HubConfig(n_clients=n_clients,
                          client_quants=_hub_quants(n_clients),
                          grad_quant=grad_q,
                          tick_rates=tuple(1 + c % 2
                                           for c in range(n_clients)))
    pipe2 = make_pipeline(cfg, n_clients * micro_batch, seq, seed=1)

    def async_batches():
        while True:
            b = next(pipe2)
            yield (b["tokens"].reshape(n_clients, micro_batch, seq),
                   b["labels"].reshape(n_clients, micro_batch, seq))

    n_ticks = 18
    out_a = train_hub(cfg, hub_async, opt_cfg, async_batches(),
                      micro_batch=micro_batch, seq=seq, mode="async",
                      n_ticks=n_ticks, lora_rank=lora_rank)
    hist_a = out_a["history"]
    k = max(3, n_ticks // 6)
    head, tail = float(np.mean(hist_a[:k])), float(np.mean(hist_a[-k:]))
    print(f"[split-hub lora async N={n_clients} r={lora_rank}] "
          f"first-{k} mean {head:.4f} -> last-{k} mean {tail:.4f}")
    assert tail < head, f"async LoRA hub loss did not decrease: {hist_a}"
    assert "client_adapters" in out_a["state"], list(out_a["state"])
    return dict(loss_history=hist, async_head=head, async_tail=tail,
                adapter_grad_payload=ad_payload,
                full_grad_payload=full_payload,
                adapter_bytes=ad_bytes, opt_moment_bytes=m_bytes)


def main(smoke: bool = False) -> Dict:
    # the smoke profile IS the dry-run: 3 clients + 1 server on 8 fake
    # devices; the full profile only trains async longer
    out: Dict = {}
    out["hub"] = dryrun_hub()
    out["hub_grouped"] = dryrun_hub_grouped()
    out["parity"] = dryrun_parity()
    out["parity_grouped"] = dryrun_parity_grouped()
    out["adaptive"] = dryrun_train_adaptive()
    out["async"] = dryrun_train_async(n_ticks=18 if smoke else 36)
    out["lora"] = dryrun_lora()
    return out


if __name__ == "__main__":
    import json

    out = main(smoke="--smoke" in sys.argv)
    os.makedirs(os.path.join(os.path.dirname(__file__), "..", "..", "..",
                             "results"), exist_ok=True)
    path = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "results", "split_hub.json")
    with open(path, "w") as f:
        json.dump({str(k): v for k, v in out.items()}, f, indent=1)
    print("saved", path)
