from repro.sharding.specs import (batch_pspecs, cache_pspecs, leaf_pspec,
                                  mesh_axes, opt_pspecs, param_pspecs,
                                  state_pspecs)

__all__ = ["batch_pspecs", "cache_pspecs", "leaf_pspec", "mesh_axes",
           "opt_pspecs", "param_pspecs", "state_pspecs"]
