"""PartitionSpec rules for every parameter / batch / cache leaf.

Axes: ``data`` shards batch (and optionally weights, FSDP-style),
``model`` shards heads / FFN hidden / experts / vocab, ``pod`` is folded
into data-parallel for the 40-combo dry-runs (and is the split-stage axis
in launch/split_pipeline.py).

Rules are name-based on the leaf path; every candidate sharded dim is
checked for divisibility by the mesh axis size and silently falls back to
replication when it does not divide (e.g. 8 KV heads on a 16-way model
axis).

``fsdp=True`` additionally shards the "other" dim of >=2-D weights over
``data`` — this is the ZeRO-3-style mode that fits the 480B Arctic
optimizer state into per-device HBM (EXPERIMENTS.md SSPerf).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

Axes = Dict[str, int]  # axis name -> size


def _fits(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


def _axis(axes: Axes, name: str, dim: int) -> Optional[str]:
    return name if name in axes and _fits(dim, axes[name]) else None


def _col(shape, axes, fsdp):
    """(in, out) weight sharded on output dim; fsdp also shards input."""
    spec = [None] * len(shape)
    spec[-1] = _axis(axes, "model", shape[-1])
    if fsdp:
        spec[-2] = _axis(axes, "data", shape[-2])
    return P(*spec)


def _row(shape, axes, fsdp):
    spec = [None] * len(shape)
    spec[-2] = _axis(axes, "model", shape[-2])
    if fsdp:
        spec[-1] = _axis(axes, "data", shape[-1])
    return P(*spec)


_COL_NAMES = {"wq", "wk", "wv", "wq_a", "wq_b", "wkv_a", "wkv_b", "w_gate",
              "w_up", "w_in", "in_proj", "conv_w", "wr", "wg", "enc_w",
              "w1"}
_ROW_NAMES = {"wo", "w_down", "out_proj", "dec_w", "w2"}


def leaf_pspec(path_names: Sequence[str], shape: Tuple[int, ...],
               axes: Axes, *, fsdp: bool = False,
               stacked: bool = False) -> P:
    """PartitionSpec for one parameter leaf."""
    if stacked:  # leading layer axis from segment stacking
        inner = leaf_pspec(path_names, shape[1:], axes, fsdp=fsdp)
        return P(None, *inner)
    name = path_names[-1]
    parent = path_names[-2] if len(path_names) > 1 else ""

    if len(shape) <= 1:
        return P(*([None] * len(shape)))  # norms, biases, scalars

    if name == "emb":
        if len(shape) == 3:  # (K, V, D) audio codebooks
            return P(None, _axis(axes, "model", shape[1]),
                     _axis(axes, "data", shape[2]) if fsdp else None)
        return P(_axis(axes, "model", shape[0]),
                 _axis(axes, "data", shape[1]) if fsdp else None)
    if parent == "head" and name == "w":
        spec = [None] * len(shape)
        spec[-1] = _axis(axes, "model", shape[-1])
        if fsdp:
            spec[-2] = _axis(axes, "data", shape[-2])
        return P(*spec)
    if parent == "ffn" and len(shape) == 3:  # MoE experts (E, D, F)/(E, F, D)
        # E over model (expert parallel) + d_model over data (FSDP).
        # (Sharding the FFN-hidden dim to contraction-align the expert
        # einsums was tried and REFUTED: GSPMD all-gathered 4.9 TB/dev
        # instead of emitting all-to-alls — EXPERIMENTS.md SSPerf A4.)
        return P(_axis(axes, "model", shape[0]),
                 _axis(axes, "data", shape[1]) if fsdp else None, None)
    if name == "router":
        return P(None, None)
    if name in _COL_NAMES:
        return _col(shape, axes, fsdp)
    if name in _ROW_NAMES:
        return _row(shape, axes, fsdp)
    if name in ("maa_w1", "maa_w2", "decay_w1", "decay_w2", "u"):
        return P(*([None] * len(shape)))
    # default: replicate
    return P(*([None] * len(shape)))


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return tuple(out)


def param_pspecs(params, axes: Axes, *, fsdp: bool = False):
    """PartitionSpecs for the whole param tree."""

    def rule(path, leaf):
        names = _path_names(path)
        stacked = any(n.startswith("seg") for n in names)
        return leaf_pspec(names, tuple(leaf.shape), axes, fsdp=fsdp,
                          stacked=stacked)

    return jax.tree_util.tree_map_with_path(rule, params)


def opt_pspecs(opt_state, params_specs):
    """Adam moments share the parameter specs; step is replicated."""
    return dict(m=params_specs, v=params_specs, step=P())


def _dp_size(axes: Axes, dp: Tuple[str, ...]) -> int:
    n = 1
    for a in dp:
        n *= axes.get(a, 1)
    return n


def _dp_or_none(axes: Axes, dp: Tuple[str, ...], dim: int):
    """Batch axis group if the dim divides; else replicate (e.g. B=1)."""
    return dp if dim % max(_dp_size(axes, dp), 1) == 0 else None


def batch_pspecs(batch, dp: Tuple[str, ...], axes: Optional[Axes] = None):
    """Shard every batch leaf on its leading (batch) dim when divisible.

    ``positions`` is per-sequence (not per-sample) and stays replicated.
    """

    def rule(path, leaf):
        if _path_names(path)[-1] == "positions":
            return P(*([None] * len(leaf.shape)))
        lead = _dp_or_none(axes, dp, leaf.shape[0]) if axes else dp
        return P(lead, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(rule, batch)


def cache_pspecs(caches, dp: Tuple[str, ...], axes: Axes):
    """Caches: layer-stacked leaves (n, B, ...); shard batch + KV heads.

    KV head counts that do not divide the model axis (e.g. 8 GQA heads on a
    16-way axis) fall back to sharding head_dim — the KV cache is by far
    the largest decode buffer, so leaving it only data-sharded would blow
    per-device HBM at decode_32k.
    """

    def rule(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        spec = [None] * len(shape)
        if len(shape) >= 2:
            spec[1] = _dp_or_none(axes, dp, shape[1])
        if names[-1] in ("k", "v") and len(shape) == 5:
            # (n, B, L, KH, hd): prefer heads, fall back to head_dim
            head_ax = _axis(axes, "model", shape[3])
            if head_ax:
                spec[3] = head_ax
            else:
                spec[4] = _axis(axes, "model", shape[4])
        if names[-1] == "ckv" and len(shape) == 4:  # MLA latent (n,B,L,c)
            spec[3] = _axis(axes, "model", shape[3])
        if names[-1] == "state" and len(shape) == 5:  # mamba (n,B,H,P,N)
            spec[2] = _axis(axes, "model", shape[2])
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, caches)


def state_pspecs(state, axes: Axes, *, fsdp: bool = False):
    """Specs for a TrainState(params, opt, step)."""
    import dataclasses

    pspecs = param_pspecs(state.params, axes, fsdp=fsdp)
    return type(state)(params=pspecs,
                       opt=opt_pspecs(state.opt, pspecs),
                       step=P())


def mesh_axes(mesh) -> Axes:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
