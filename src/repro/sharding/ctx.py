"""Activation-sharding context.

GSPMD propagates weight shardings to activations, but at reshape-heavy
spots (microbatch split, logits/CE, MoE dispatch buffers) it can drop the
batch sharding and replicate multi-GiB tensors (measured on train_4k:
fp32 logits at 128k vocab replicated; deepseek-v2 MoE dispatch buffers at
251 GiB/device).  Production JAX stacks pin logical activation axes
explicitly; this module is that, kept minimal.

The launcher (dryrun/train) installs rules; model code calls
``constrain(x, kind)`` which is a no-op when no rules are installed (unit
tests, single-device runs).  Every sharded dim is divisibility-checked
against the mesh axis sizes and silently dropped when it does not divide
(e.g. 16 MoE groups on a 32-way multi-pod data axis).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_RULES: Dict[str, P] = {}
_AXES: Dict[str, int] = {}
_PARAM_SPECS = None  # pytree of PartitionSpec matching the model params


def install(dp: Tuple[str, ...], axes: Optional[Dict[str, int]] = None,
            model: str = "model") -> None:
    """Install standard rules for a (data..., model) mesh."""
    global _RULES, _AXES
    _AXES = dict(axes or {})
    _RULES = dict(
        hidden=P(dp, None, None),            # (B, S, D) / (G, TG, D)
        logits=P(dp, None, model),           # (B, S, V)
        batch_leading=P(dp),                 # generic leading batch dim
        moe_experts=P(dp, model, None, None),  # (G, E, C, D)
        decode_q=P(dp, None, None, model),     # (B, KH, G, hd): contract
        # the head_dim against the hd-sharded KV cache (partial sums are
        # ~MBs; gathering the cache is ~GBs — SSPerf B2)
    )


def set_param_specs(specs) -> None:
    """Register the parameter PartitionSpecs so gradient accumulators can
    be pinned to the same (FSDP) sharding — turning the per-microbatch
    gradient all-reduce into a reduce-scatter (EXPERIMENTS.md SSPerf A3).
    """
    global _PARAM_SPECS
    _PARAM_SPECS = specs


def constrain_like_params(tree):
    if _PARAM_SPECS is None or not _RULES:
        return tree
    return jax.tree_util.tree_map(
        lambda a, spec: jax.lax.with_sharding_constraint(
            a, _fit_spec(spec, a.shape)) if hasattr(a, "ndim") and
        len(tuple(spec)) == a.ndim else a,
        tree, _PARAM_SPECS,
        is_leaf=lambda x: isinstance(x, P))


def clear() -> None:
    global _RULES, _AXES, _PARAM_SPECS
    _RULES = {}
    _AXES = {}
    _PARAM_SPECS = None


def active() -> bool:
    return bool(_RULES)


def dp_size() -> int:
    n = 1
    for a in ("pod", "data"):
        n *= _AXES.get(a, 1)
    return n


def _axis_size(entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= _AXES.get(a, 1)
        return n
    return _AXES.get(entry, 1)


def _fit_spec(spec: P, shape) -> P:
    """Drop spec entries whose axis size does not divide the dim."""
    out = []
    for dim, entry in zip(shape, tuple(spec)):
        size = _axis_size(entry)
        out.append(entry if size > 1 and dim % size == 0 else None)
    return P(*out)


def constrain(x, kind: str):
    spec = _RULES.get(kind)
    if spec is None or not hasattr(x, "ndim"):
        return x
    if kind == "batch_leading":
        spec = P(*(tuple(spec) + (None,) * (x.ndim - 1)))
    if len(spec) != x.ndim:
        return x
    spec = _fit_spec(spec, x.shape)
    if all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_kv(x, dp=("pod", "data")):
    """Pin a new KV token (B, KH, hd) to the ring-buffer cache layout:
    heads over `model` when they divide, else head_dim over `model`.

    Without this, the decode-step cache scatter reshards through a full
    rematerialization of the cache (GSPMD "involuntary full remat" —
    measured 60 GB/device per decoded token on llama decode_32k;
    EXPERIMENTS.md SSPerf B1).
    """
    if not _RULES or x.ndim != 3:
        return x
    m = _AXES.get("model", 1)
    dp_t = tuple(a for a in dp if a in _AXES)
    b, kh, hd = x.shape
    lead = dp_t if dp_t and b % max(_axis_size(dp_t), 1) == 0 else None
    if m > 1 and kh % m == 0:
        spec = P(lead, "model", None)
    elif m > 1 and hd % m == 0:
        spec = P(lead, None, "model")
    else:
        spec = P(lead, None, None)
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_latent(x, dp=("pod", "data")):
    """Pin a new MLA latent token (B, C) to the latent-cache layout."""
    if not _RULES or x.ndim != 2:
        return x
    m = _AXES.get("model", 1)
    dp_t = tuple(a for a in dp if a in _AXES)
    b, c = x.shape
    lead = dp_t if dp_t and b % max(_axis_size(dp_t), 1) == 0 else None
    spec = P(lead, "model" if m > 1 and c % m == 0 else None)
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_batch_tree(tree):
    """Pin the leading batch dim of every array leaf."""
    if not _RULES:
        return tree
    return jax.tree_util.tree_map(
        lambda a: constrain(a, "batch_leading"), tree)
