"""Weight-only serving quantization (GPTQ-style) — ROADMAP item 5.

The wire compressors point at *activations*; this subsystem points the
same machinery (exact odd-width bitstream packers, grouped scales,
importance-sorted channel permutation) at the serving stacks' *weights*:
post-training int4/int3 quantization with optional Hessian-based GPTQ
error compensation, stored packed (:class:`PackedLinear`) and
dequantized inside a fused Pallas matmul (``kernels/wq_kernel.py``,
``REPRO_WQ_IMPL`` dispatch, jnp oracle in ``kernels/ref.py``).
"""
from repro.wq.calibrate import collect_hessians
from repro.wq.ops import resolve_impl, wq_matmul
from repro.wq.packed import PackedLinear
from repro.wq.quantize import (QUANTIZED_SUBTREES, WqConfig, gptq_quantize,
                               packed_tree_bytes, parse_weight_quant,
                               quantize_linear, quantize_params,
                               quantize_tree, rtn_quantize)

__all__ = [
    "PackedLinear", "WqConfig", "QUANTIZED_SUBTREES", "collect_hessians",
    "gptq_quantize", "packed_tree_bytes", "parse_weight_quant",
    "quantize_linear", "quantize_params", "quantize_tree", "resolve_impl",
    "rtn_quantize", "wq_matmul",
]
