"""Backend dispatch for the packed dequant-matmul.

Same ladder as attention (``REPRO_ATTN_IMPL``) and the wire codecs
(``REPRO_QUANT_IMPL``): explicit ``impl=`` kwarg beats the
``REPRO_WQ_IMPL`` env var beats the backend default (Pallas on TPU, the
jnp oracle elsewhere; the Pallas path runs ``interpret=True`` off-TPU so
parity tests exercise the kernel everywhere).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref, wq_kernel
from repro.utils.dispatch import resolve_backend_impl

__all__ = ["resolve_impl", "wq_matmul"]


def resolve_impl(impl: Optional[str] = None) -> str:
    return resolve_backend_impl(impl, "REPRO_WQ_IMPL", "wq matmul")


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def wq_matmul(x: jnp.ndarray, w, impl: Optional[str] = None) -> jnp.ndarray:
    """``x @ w`` for a :class:`~repro.wq.packed.PackedLinear` ``w``.

    ``x``: (…, d_in) activations; returns (…, d_out) in ``x.dtype``
    (fp32 accumulation in both backends).  Stacked stores must be sliced
    to their 2-D per-layer form first (the stack executor's scan does).
    """
    if w.codes.ndim != 2:
        raise ValueError(
            "matmul on a layer-stacked PackedLinear: slice the stack "
            f"(codes ndim {w.codes.ndim}) to one layer first")
    if x.shape[-1] != w.d_in:
        raise ValueError(f"x feature dim {x.shape[-1]} != d_in {w.d_in}")
    if w.perm is not None:
        # act-order: gather activations into the storage channel order
        x = jnp.take(x, w.perm, axis=-1)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, w.d_in)
    impl = resolve_impl(impl)
    if impl == "jnp":
        y = ref.wq_matmul_ref(x2, w.codes, w.scales, w.mins,
                              bits=w.bits, group=w.group, d_in=w.d_in)
    else:
        y = wq_kernel.matmul_pallas(x2, w.codes, w.scales, w.mins,
                                    bits=w.bits, group=w.group,
                                    d_in=w.d_in, interpret=_interpret())
    return y.reshape(lead + (w.d_out,)).astype(x.dtype)
