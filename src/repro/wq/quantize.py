"""RTN and GPTQ weight-only quantizers producing :class:`PackedLinear`.

Both share one asymmetric affine grid per ``(group, d_out)``: ``w_hat =
code * scale + min`` with the fp16-ROUNDED scale/min (the stored side
info), so quantization error is measured against exactly what serving
dequantizes.  ``group`` runs down ``d_in`` (the contraction axis — one
scale per K-tile slice of the fused kernel); a ragged last group is
handled exactly (its statistics cover only the real rows).

* **RTN** (round-to-nearest): vectorized jnp, the zero-calibration
  baseline.
* **GPTQ** (Frantar et al.): per-column quantization with second-order
  error compensation — after quantizing column ``j`` the residual error
  is propagated into the not-yet-quantized columns through the Cholesky
  factor of the inverse Hessian ``H = X^T X`` accumulated from a small
  calibration sample (``repro.wq.calibrate``).  ``act_order=True``
  processes columns by descending ``diag(H)`` — the same
  importance-sorted channel permutation trick the adaptive wire uses
  (``QuantConfig.channel_perm``) — and stores the permutation on the
  ``PackedLinear`` so the matmul gathers activations into storage order.

GPTQ runs in numpy: it is an offline, sequential-by-column calibration
pass (not a jitted hot path), and numpy keeps it eager and debuggable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import is_weight_site
from repro.wq.packed import PackedLinear, pack_weight_codes

__all__ = ["WqConfig", "parse_weight_quant", "rtn_quantize",
           "gptq_quantize", "quantize_linear", "quantize_tree",
           "quantize_params", "packed_tree_bytes", "QUANTIZED_SUBTREES"]

#: params subtrees whose w* matmul sites the serving quantizer packs —
#: the transformer block stacks.  Embed / connector / head / norms stay
#: dense (the head is also a w*-named site but lives outside these).
QUANTIZED_SUBTREES = ("client", "server", "shared_attn")

_SUPPORTED_BITS = (2, 3, 4)


@dataclasses.dataclass(frozen=True)
class WqConfig:
    """Weight-only serving quantization settings."""

    bits: int = 4
    group: int = 128
    act_order: bool = False

    def __post_init__(self):
        if self.bits not in _SUPPORTED_BITS:
            raise ValueError(f"wq bits must be in {_SUPPORTED_BITS}, "
                             f"got {self.bits}")
        if self.group < 8 or self.group % 8:
            raise ValueError(f"wq group must be a positive multiple of 8 "
                             f"(packed 8-code alignment), got {self.group}")


def parse_weight_quant(weight_quant: str, *, group: int = 128,
                       act_order: bool = False) -> WqConfig:
    """``"int4" | "int3" | "int2"`` -> :class:`WqConfig`."""
    names = {f"int{b}": b for b in _SUPPORTED_BITS}
    if weight_quant not in names:
        raise ValueError(f"unknown weight_quant {weight_quant!r}; "
                         f"expected one of {sorted(names)}")
    return WqConfig(bits=names[weight_quant], group=group,
                    act_order=act_order)


def _grid(wg: jnp.ndarray, mask: jnp.ndarray, bits: int):
    """fp16-rounded (scale, min) of one group tensor (G, group, C)."""
    big = jnp.float32(3.0e38)
    mn = jnp.where(mask, wg, big).min(axis=1)
    mx = jnp.where(mask, wg, -big).max(axis=1)
    scale = (mx - mn) / (2 ** bits - 1)
    scale = jnp.maximum(scale, 1e-8).astype(jnp.float16)
    return scale, mn.astype(jnp.float16)


def rtn_quantize(w: jnp.ndarray, cfg: WqConfig) -> PackedLinear:
    """Round-to-nearest grouped quantization of a (d_in, d_out) matrix."""
    d_in, d_out = w.shape
    g = cfg.group
    n_groups = -(-d_in // g)
    pad = n_groups * g - d_in
    wf = jnp.pad(w.astype(jnp.float32), ((0, pad), (0, 0)))
    wg = wf.reshape(n_groups, g, d_out)
    mask = (jnp.arange(n_groups * g).reshape(n_groups, g, 1) < d_in)
    scale, mn = _grid(wg, mask, cfg.bits)
    s32 = scale.astype(jnp.float32)[:, None, :]
    m32 = mn.astype(jnp.float32)[:, None, :]
    codes = jnp.clip(jnp.round((wg - m32) / s32), 0, 2 ** cfg.bits - 1)
    codes = codes.reshape(-1, d_out)[:d_in].astype(jnp.uint8)
    return PackedLinear(codes=pack_weight_codes(codes, cfg.bits),
                        scales=scale, mins=mn, perm=None,
                        bits=cfg.bits, group=g, d_in=d_in, d_out=d_out)


def gptq_quantize(w: jnp.ndarray, hessian: np.ndarray,
                  cfg: WqConfig) -> PackedLinear:
    """GPTQ error-compensated quantization of a (d_in, d_out) matrix.

    ``hessian``: (d_in, d_in) accumulated ``X^T X`` of the site's
    calibration inputs.  Columns here are input channels (we work on the
    (d_out, d_in) transpose, as GPTQ is row-wise in the out dimension).
    """
    d_in, d_out = w.shape
    g = cfg.group
    W = np.asarray(w, dtype=np.float32).T.copy()       # (d_out, d_in)
    H = np.asarray(hessian, dtype=np.float64).copy()
    if H.shape != (d_in, d_in):
        raise ValueError(f"hessian shape {H.shape} != ({d_in}, {d_in})")

    dead = np.diag(H) <= 0
    if dead.any():
        H[dead, dead] = 1.0
        W[:, dead] = 0.0
    perm = None
    if cfg.act_order:
        perm = np.argsort(-np.diag(H), kind="stable")
        W = W[:, perm]
        H = H[np.ix_(perm, perm)]
    damp = 0.01 * float(np.mean(np.diag(H)))
    H[np.diag_indices(d_in)] += max(damp, 1e-8)
    # upper Cholesky factor U of H^-1 (H^-1 = U^T U): the standard GPTQ
    # error propagator — column j's residual spreads to j+1.. via U[j, j+1:]
    Hinv = np.linalg.inv(H)
    U = np.linalg.cholesky(Hinv).T.astype(np.float32)

    n_groups = -(-d_in // g)
    qmax = 2 ** cfg.bits - 1
    codes = np.zeros((d_out, d_in), np.uint8)
    scales = np.zeros((n_groups, d_out), np.float16)
    mins = np.zeros((n_groups, d_out), np.float16)
    for b0 in range(0, d_in, g):
        b1 = min(b0 + g, d_in)
        gi = b0 // g
        # grid from the error-COMPENSATED block values (the live W)
        blk = W[:, b0:b1]
        mn = blk.min(axis=1)
        scale = np.maximum((blk.max(axis=1) - mn) / qmax, 1e-8)
        scale16 = scale.astype(np.float16)
        mn16 = mn.astype(np.float16)
        scales[gi] = scale16
        mins[gi] = mn16
        s32 = scale16.astype(np.float32)
        m32 = mn16.astype(np.float32)
        err_blk = np.zeros((d_out, b1 - b0), np.float32)
        for j in range(b0, b1):
            col = W[:, j]
            q = np.clip(np.rint((col - m32) / s32), 0, qmax)
            codes[:, j] = q.astype(np.uint8)
            dq = q * s32 + m32
            err = (col - dq) / U[j, j]
            if j + 1 < b1:
                W[:, j + 1:b1] -= np.outer(err, U[j, j + 1:b1])
            err_blk[:, j - b0] = err
        if b1 < d_in:  # propagate the whole block's error past it
            W[:, b1:] -= err_blk @ U[b0:b1, b1:]

    pl_perm = None
    if perm is not None:
        pl_perm = jnp.asarray(perm.astype(np.int32))
    return PackedLinear(
        codes=pack_weight_codes(jnp.asarray(codes.T), cfg.bits),
        scales=jnp.asarray(scales), mins=jnp.asarray(mins), perm=pl_perm,
        bits=cfg.bits, group=cfg.group, d_in=d_in, d_out=d_out)


def quantize_linear(w: jnp.ndarray, cfg: WqConfig,
                    hessian: Optional[np.ndarray] = None) -> PackedLinear:
    """One (…, d_in, d_out) site -> PackedLinear (GPTQ iff ``hessian``).

    Leading batch axes (layer stacking) are quantized independently and
    restacked; a stacked ``hessian`` must carry the same leading axes.
    """
    if w.ndim == 2:
        if hessian is None:
            return rtn_quantize(w, cfg)
        return gptq_quantize(w, np.asarray(hessian), cfg)
    lead = w.shape[:-2]
    flat = np.prod(lead, dtype=int)
    wf = w.reshape((flat,) + w.shape[-2:])
    hf = None
    if hessian is not None:
        hessian = np.asarray(hessian)
        if hessian.shape[:-2] != lead:
            raise ValueError(f"hessian batch {hessian.shape[:-2]} != "
                             f"site batch {lead}")
        hf = hessian.reshape((flat,) + hessian.shape[-2:])
    parts = [quantize_linear(wf[i], cfg, None if hf is None else hf[i])
             for i in range(flat)]
    if any((p.perm is None) != (parts[0].perm is None) for p in parts):
        raise AssertionError("inconsistent act-order across batch")

    def restack(*leaves):
        return jnp.stack(leaves).reshape(lead + leaves[0].shape)

    return jax.tree_util.tree_map(restack, parts[0], *parts[1:])


def _site_ok(leaf, stacked_axes: int) -> bool:
    """Only ``@``-consumed matmul sites are packable: per-layer 2-D
    matrices.  MoE expert banks (per-layer 3-D, consumed via einsum)
    stay dense — their bandwidth needs an einsum-aware kernel."""
    return getattr(leaf, "ndim", 0) == stacked_axes + 2


def quantize_tree(tree, cfg: WqConfig, *, stacked_axes: int = 1,
                  hessians: Optional[Dict] = None,
                  prefix: Tuple[str, ...] = ()):
    """Replace every packable w* site of a (nested-dict) param tree.

    ``stacked_axes``: leading layer axes on every site (1 for the
    ``client``/``server`` segment stacks, 0 for the unstacked
    ``shared_attn`` block, 2 for stage-stacked hub trees).
    ``hessians``: full-path-keyed ``{path: X^T X}`` from
    :func:`repro.wq.calibrate.collect_hessians`; sites without an entry
    fall back to RTN.  Returns ``(quantized_tree, report)`` where report
    maps site paths to ``(dense_bytes, packed_bytes)``.
    """
    report: Dict[Tuple[str, ...], Tuple[int, int]] = {}

    def walk(node, path):
        if not isinstance(node, dict):
            raise TypeError(f"expected nested dicts at {path}, "
                            f"got {type(node)}")
        out = {}
        for k, v in node.items():
            if isinstance(v, dict):
                out[k] = walk(v, path + (k,))
            elif is_weight_site(k, v) and _site_ok(v, stacked_axes):
                h = (hessians or {}).get(path + (k,))
                q = quantize_linear(v, cfg, h)
                report[path + (k,)] = (v.size * v.dtype.itemsize,
                                       q.packed_bytes())
                out[k] = q
            else:
                out[k] = v
        return out

    return walk(tree, prefix), report


def quantize_params(params: Dict, cfg: WqConfig,
                    hessians: Optional[Dict] = None) -> Tuple[Dict, Dict]:
    """Quantize a full model param tree's serving block stacks.

    Packs the w* matmul sites of ``client``/``server`` (layer-stacked)
    and ``shared_attn`` (unstacked); everything else — embed, connector,
    head, norms, codec — is returned untouched.  Returns
    ``(params, report)``.
    """
    out = dict(params)
    report: Dict = {}
    for side in ("client", "server"):
        if side in params:
            out[side], rep = quantize_tree(params[side], cfg,
                                           stacked_axes=1,
                                           hessians=hessians,
                                           prefix=(side,))
            report.update(rep)
    if "shared_attn" in params:
        out["shared_attn"], rep = quantize_tree(params["shared_attn"], cfg,
                                                stacked_axes=0,
                                                hessians=hessians,
                                                prefix=("shared_attn",))
        report.update(rep)
    if not report:
        raise ValueError("no packable w* matmul sites found in params")
    return out, report


def packed_tree_bytes(tree) -> int:
    """Physical weight bytes of a (possibly partially) packed tree."""
    total = 0
    seen = set()

    def visit(node):
        nonlocal total
        if isinstance(node, PackedLinear):
            if id(node) not in seen:
                seen.add(id(node))
                total += node.packed_bytes()
        elif isinstance(node, dict):
            for v in node.values():
                visit(v)
        elif hasattr(node, "dtype"):
            total += node.size * node.dtype.itemsize

    visit(tree)
    return total
