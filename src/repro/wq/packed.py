"""``PackedLinear`` — a weight matrix stored as packed int codes.

The serving stacks consume every projection weight the same structural
way (``x @ p["w*"].astype(dt)``), so a weight store only has to satisfy
that one contract to flow through the unmodified forward/decode code.
``PackedLinear`` is a registered pytree node that does exactly that:

* children: ``codes`` (uint8, the exact ``core.packing`` bitstream of the
  int codes, packed along ``d_in`` *per output column* so a fused kernel
  can unpack K-tiles), fp16 ``scales``/``mins`` (one affine pair per
  ``(group, d_out)``), and an optional ``perm`` (int32 act-order storage
  permutation of the input channels);
* static aux: ``bits``, ``group``, ``d_in``, ``d_out``.

Because it is a pytree node, a layer-stacked tree of them (children with
a leading layer axis) scans through ``models/stack.py`` unchanged — the
scan slices the children per layer and rebuilds the node — and
``checkpoint.ckpt`` saves/restores the children bit-exactly through the
ordinary path-keyed flatten.  ``astype`` is identity (the dequantized
matmul follows the activation dtype), ``__rmatmul__`` defers to the
``REPRO_WQ_IMPL``-dispatched packed dequant-matmul, so JAX arrays hand
``x @ w`` over to us via the NotImplemented protocol.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import packing

__all__ = ["PackedLinear", "pack_weight_codes", "unpack_weight_codes"]


def pack_weight_codes(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """(d_in, d_out) uint8 codes -> (packed_size(d_in, bits), d_out) words.

    Each output column's codes are packed independently down the input
    axis (the exact ``core.packing`` bitstream per column), so a K-tile of
    the packed array holds whole 8-code groups of every column in it.
    """
    per_col = jax.vmap(lambda c: packing.pack_bits(c, bits))
    return per_col(codes.T).T


def unpack_weight_codes(words: jnp.ndarray, bits: int,
                        d_in: int) -> jnp.ndarray:
    """Inverse of :func:`pack_weight_codes`: -> (d_in, d_out) uint8."""
    per_col = jax.vmap(lambda w: packing.unpack_bits(w, bits, d_in))
    return per_col(words.T).T


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class PackedLinear:
    """A ``(…, d_in, d_out)`` weight matrix served as packed int codes.

    ``w_hat[perm[r], c] = codes[r, c] * scales[r // group, c] +
    mins[r // group, c]`` (``perm`` identity when ``None``), with all
    leading axes of the children treated as batch (layer / stage
    stacking).  Matmul is only defined on the unstacked (2-D) form —
    the stack executor's scan slices a stacked tree down to it.
    """

    codes: jnp.ndarray                 # (*batch, packed_rows, d_out) uint8
    scales: jnp.ndarray                # (*batch, n_groups, d_out) fp16
    mins: jnp.ndarray                  # (*batch, n_groups, d_out) fp16
    perm: Optional[jnp.ndarray]        # (*batch, d_in) int32, or None
    bits: int
    group: int
    d_in: int
    d_out: int

    # -- pytree protocol -------------------------------------------------
    def tree_flatten_with_keys(self):
        children = [(jax.tree_util.GetAttrKey("codes"), self.codes),
                    (jax.tree_util.GetAttrKey("scales"), self.scales),
                    (jax.tree_util.GetAttrKey("mins"), self.mins),
                    (jax.tree_util.GetAttrKey("perm"), self.perm)]
        aux = (self.bits, self.group, self.d_in, self.d_out)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scales, mins, perm = children
        bits, group, d_in, d_out = aux
        return cls(codes=codes, scales=scales, mins=mins, perm=perm,
                   bits=bits, group=group, d_in=d_in, d_out=d_out)

    # -- the array-like surface the forward code touches -----------------
    @property
    def batch_shape(self) -> Tuple[int, ...]:
        return tuple(self.codes.shape[:-2])

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.batch_shape + (self.d_in, self.d_out)

    @property
    def ndim(self) -> int:
        return len(self.shape)

    def astype(self, dtype):
        """Identity: the packed matmul output follows the activation
        dtype, exactly like ``(w.astype(x.dtype))``'s result would."""
        del dtype
        return self

    def __rmatmul__(self, x):
        from repro.wq import ops
        return ops.wq_matmul(x, self)

    def __matmul__(self, other):  # pragma: no cover - guidance only
        raise TypeError("PackedLinear is an x @ w weight store; "
                        "w @ x is not supported")

    # -- introspection ---------------------------------------------------
    def packed_bytes(self) -> int:
        """Physical weight-store bytes (codes + scale/min side info)."""
        total = self.codes.size * self.codes.dtype.itemsize
        total += self.scales.size * self.scales.dtype.itemsize
        total += self.mins.size * self.mins.dtype.itemsize
        if self.perm is not None:
            total += self.perm.size * self.perm.dtype.itemsize
        return total

    def dequantize(self) -> jnp.ndarray:
        """fp32 ``(…, d_in, d_out)`` in the ORIGINAL input-channel order.

        Test/debug path (it materializes the dense matrix the packed
        store exists to avoid); batch axes are vmapped.
        """
        if self.batch_shape:
            flat = jax.tree_util.tree_map(
                lambda a: a.reshape((-1,) + a.shape[len(self.batch_shape):]),
                self)
            dense = jax.vmap(lambda s: s.dequantize())(flat)
            return dense.reshape(self.batch_shape + (self.d_in, self.d_out))
        codes = unpack_weight_codes(self.codes, self.bits, self.d_in)
        n_groups = self.scales.shape[-2]
        pad = n_groups * self.group - self.d_in
        cf = jnp.pad(codes.astype(jnp.float32), ((0, pad), (0, 0)))
        cf = cf.reshape(n_groups, self.group, self.d_out)
        w = cf * self.scales.astype(jnp.float32)[:, None, :] \
            + self.mins.astype(jnp.float32)[:, None, :]
        w = w.reshape(n_groups * self.group, self.d_out)[: self.d_in]
        if self.perm is not None:
            w = jnp.zeros_like(w).at[self.perm].set(w)
        return w
