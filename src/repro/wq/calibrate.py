"""GPTQ calibration: per-site Hessians from a small activation sample.

GPTQ needs ``H = X^T X`` of each projection's *inputs* on calibration
data.  The stacks normally execute through ``lax.scan`` (one trace per
segment), where per-site side effects are impossible — so calibration
re-runs the blocks EAGERLY, one layer at a time, with every packable w*
site wrapped in a :class:`_Tap`: an object that satisfies the structural
weight contract (``astype`` + ``x @ w``) and accumulates ``X^T X`` in
numpy the moment the forward consumes it.  No per-arch code: the same
``x @ p["w*"].astype(dt)`` convention that lets :class:`PackedLinear`
serve the weights lets the tap observe them.

Supported segment types are exactly the serving engine's (``dense`` /
``moe`` / ``shared_attn``); MoE expert banks are einsum sites and are
neither tapped nor quantized.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import split as split_mod
from repro.models import transformer as tf
from repro.utils.tree import is_weight_site

__all__ = ["collect_hessians"]

_CALIB_SEGMENTS = ("dense", "moe", "shared_attn")


class _Tap:
    """Weight wrapper recording ``X^T X`` of everything matmul'd into it."""

    def __init__(self, w, sink: np.ndarray):
        self._w = w
        self._sink = sink
        self._dt = w.dtype

    @property
    def ndim(self) -> int:
        return self._w.ndim

    @property
    def shape(self):
        return self._w.shape

    def astype(self, dtype):
        self._dt = dtype
        return self

    def __rmatmul__(self, x):
        x2 = np.asarray(x, dtype=np.float32).reshape(-1, self.shape[-2])
        self._sink += x2.T @ x2
        return x @ self._w.astype(self._dt)


def _tap_block(p: Dict, path: Tuple[str, ...], layer: Optional[int],
               sinks: Dict) -> Dict:
    """Per-layer block params with every 2-D w* leaf wrapped in a tap."""
    out = {}
    for k, v in p.items():
        if isinstance(v, dict):
            out[k] = _tap_block(v, path + (k,), layer, sinks)
        elif is_weight_site(k, v) and v.ndim == 2:
            sink = sinks.setdefault(
                (path + (k,), layer),
                np.zeros((v.shape[-2], v.shape[-2]), np.float32))
            out[k] = _Tap(v, sink)
        else:
            out[k] = v
    return out


def collect_hessians(params: Dict, cfg: ArchConfig, batch: Dict, *,
                     window: Optional[int] = None) -> Dict:
    """Run ``batch`` through the stacks eagerly, tapping every w* site.

    Returns ``{site_path: H}`` keyed by the full params path (e.g.
    ``("server", "seg0", "attn", "wq")``) with ``H`` layer-stacked
    ``(n, d_in, d_in)`` for stacked segments and ``(d_in, d_in)`` for
    the shared block — exactly the shapes
    :func:`repro.wq.quantize.quantize_params` consumes.
    """
    segs = cfg.client_server_segments()
    for side_segs in segs:
        for t, _n in side_segs:
            if t not in _CALIB_SEGMENTS:
                raise NotImplementedError(
                    f"wq calibration supports {_CALIB_SEGMENTS} segments "
                    f"(the serving engine's); got {t!r}")

    x = tf._embed_inputs(params, cfg, batch)
    emb0 = x
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    sinks: Dict = {}

    def run_side(side: str, side_segs, x):
        for i, (t, n) in enumerate(side_segs):
            if t == "shared_attn":
                p = _tap_block(params["shared_attn"], ("shared_attn",),
                               None, sinks)
                x, _, _ = tf.block_forward(cfg, t, p, x,
                                           positions=positions,
                                           window=window, emb0=emb0)
                continue
            stacked = params[side][f"seg{i}"]
            for layer in range(n):
                p_l = {k: _slice_layer(v, layer) for k, v in stacked.items()}
                p = _tap_block(p_l, (side, f"seg{i}"), layer, sinks)
                x, _, _ = tf.block_forward(cfg, t, p, x,
                                           positions=positions,
                                           window=window, emb0=emb0)
        return x

    client_segs, server_segs = segs
    x = run_side("client", client_segs, x)
    x, _ = split_mod.compressor_roundtrip(params.get("codec"), cfg.split, x)
    run_side("server", server_segs, x)

    # stack per-layer sinks back into the site-path keyed dict
    out: Dict[Tuple[str, ...], np.ndarray] = {}
    by_path: Dict[Tuple[str, ...], Dict[Optional[int], np.ndarray]] = {}
    for (path, layer), h in sinks.items():
        by_path.setdefault(path, {})[layer] = h
    for path, layers in by_path.items():
        if None in layers:
            out[path] = layers[None]
        else:
            out[path] = np.stack([layers[i] for i in sorted(layers)])
    return out


def _slice_layer(v, layer: int):
    if isinstance(v, dict):
        return {k: _slice_layer(x, layer) for k, x in v.items()}
    return v[layer]
