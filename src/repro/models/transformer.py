"""Composable decoder stack interpreting ``ArchConfig.block_pattern()``.

Block types: ``dense`` (GQA/MLA attention + SwiGLU), ``moe`` (attention +
mixture-of-experts), ``mamba2`` (SSD), ``rwkv6`` (time-mix + channel-mix),
``shared_attn`` (Zamba2's parameter-shared attention block over
concat(hidden, initial embedding)).

Consecutive identical layers are *stacked* (leading layer axis) and executed
through ``repro.models.stack`` — the unified stack executor that owns the
scan / remat / sqrt-L-remat / cache-collection policies (one trace per
segment instead of one per layer, which keeps 62-layer dry-run compiles
tractable).  The split-learning cut never falls inside a segment (see
``ArchConfig.segments``); the compressor (quantize -> wire -> dequantize,
STE) runs between the client and server segment lists.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import utils
from repro.configs.base import ArchConfig
from repro.core import split as split_mod
from repro.models import stack as stack_mod
from repro.models.layers import attention as attn_mod
from repro.models.layers import embedding as emb_mod
from repro.models.layers import mamba2 as mamba_mod
from repro.models.layers import mla as mla_mod
from repro.models.layers import rwkv6 as rwkv_mod
from repro.models.layers.mlp import (init_mlp_params, init_swiglu_params,
                                     mlp_forward, swiglu_forward)
from repro.models.layers.moe import init_moe_params, moe_forward
from repro.models.layers.norms import rms_norm
from repro.sharding import ctx as shard_ctx

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
          "float16": jnp.float16}


def pdtype(cfg: ArchConfig):
    return DTYPES[cfg.param_dtype]


def cdtype(cfg: ArchConfig):
    return DTYPES[cfg.compute_dtype]


# ---------------------------------------------------------------------------
# RWKV channel mix (the FFN half of an RWKV block)
# ---------------------------------------------------------------------------

def init_cmix_params(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s = d_model ** -0.5
    return dict(
        mu_k=jnp.full((d_model,), 0.5, dtype),
        mu_r=jnp.full((d_model,), 0.5, dtype),
        wk=(jax.random.normal(k1, (d_model, d_ff)) * s).astype(dtype),
        wv=(jax.random.normal(k2, (d_ff, d_model)) * d_ff ** -0.5
            ).astype(dtype),
        wr=(jax.random.normal(k3, (d_model, d_model)) * s).astype(dtype),
    )


def cmix_forward(p: Dict, x: jnp.ndarray, x_prev: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    xk = x + (x_prev - x) * p["mu_k"].astype(dt)
    xr = x + (x_prev - x) * p["mu_r"].astype(dt)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dt)))
    return jax.nn.sigmoid(xr @ p["wr"].astype(dt)) * (k @ p["wv"].astype(dt))


# ---------------------------------------------------------------------------
# per-block init
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: ArchConfig, d_model: int, dtype):
    if cfg.attn_type == "mla":
        return mla_mod.init_mla_params(
            key, d_model, cfg.n_heads, q_lora_rank=cfg.q_lora_rank,
            kv_lora_rank=cfg.kv_lora_rank, qk_nope_dim=cfg.qk_nope_dim,
            qk_rope_dim=cfg.qk_rope_dim, v_head_dim=cfg.v_head_dim,
            dtype=dtype)
    return attn_mod.init_attention_params(
        key, d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dtype=dtype)


def init_block_params(key, cfg: ArchConfig, block_type: str) -> Dict:
    dtype = pdtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if block_type in ("dense", "moe"):
        p = dict(ln1=jnp.ones((d,), dtype), ln2=jnp.ones((d,), dtype),
                 attn=_init_attn(ks[0], cfg, d, dtype))
        if block_type == "moe":
            p["ffn"] = init_moe_params(
                ks[1], d, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff,
                n_shared_experts=cfg.n_shared_experts,
                dense_residual_d_ff=cfg.d_ff if cfg.dense_residual else 0,
                dtype=dtype)
        else:
            p["ffn"] = init_swiglu_params(ks[1], d, cfg.d_ff, dtype)
        return p
    if block_type == "mamba2":
        return dict(ln=jnp.ones((d,), dtype),
                    mixer=mamba_mod.init_mamba2_params(
                        ks[0], d, expand=cfg.ssm_expand,
                        headdim=cfg.ssm_headdim, d_state=cfg.ssm_state,
                        dtype=dtype))
    if block_type == "rwkv6":
        return dict(ln1=jnp.ones((d,), dtype), ln2=jnp.ones((d,), dtype),
                    tmix=rwkv_mod.init_rwkv6_params(
                        ks[0], d, cfg.rwkv_head_dim, dtype=dtype),
                    cmix=init_cmix_params(ks[1], d, cfg.d_ff, dtype))
    if block_type == "shared_attn":
        return dict(
            w_in=(jax.random.normal(ks[0], (2 * d, d)) * (2 * d) ** -0.5
                  ).astype(dtype),
            ln1=jnp.ones((d,), dtype), ln2=jnp.ones((d,), dtype),
            attn=_init_attn(ks[1], cfg, d, dtype),
            ffn=init_swiglu_params(ks[2], d, cfg.d_ff, dtype))
    raise ValueError(block_type)


# ---------------------------------------------------------------------------
# per-block forward (full sequence) and decode (one token)
# ---------------------------------------------------------------------------

_EMPTY_AUX = dict(load_balance=jnp.zeros((), jnp.float32),
                  router_z=jnp.zeros((), jnp.float32),
                  drop_fraction=jnp.zeros((), jnp.float32))


def _attn_forward(cfg: ArchConfig, p, x, positions, window, return_kv=False):
    if cfg.attn_type == "mla":
        return mla_mod.mla_forward(
            p, x, n_heads=cfg.n_heads, qk_nope_dim=cfg.qk_nope_dim,
            qk_rope_dim=cfg.qk_rope_dim, v_head_dim=cfg.v_head_dim,
            kv_lora_rank=cfg.kv_lora_rank, rope_theta=cfg.rope_theta,
            positions=positions, window=window, return_kv=return_kv)
    return attn_mod.gqa_forward(
        p, x, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        positions=positions, window=window, return_kv=return_kv)


def block_forward(cfg: ArchConfig, block_type: str, p: Dict, x: jnp.ndarray,
                  *, positions, window, emb0=None,
                  collect_cache: Optional[int] = None):
    """Full-sequence block. Returns (x, aux, cache_or_None)."""
    aux = dict(_EMPTY_AUX)
    cache = None
    # Tie positions to the layer input: without this barrier XLA hoists the
    # (layer-invariant) attention-mask computation out of the layer scan as
    # a precomputed (nq x nkv x ...) table — gigabytes per device
    # (EXPERIMENTS.md SSPerf).  grad_safe_barrier keeps the pin on BOTH
    # the forward and backward scans (raw optimization_barrier has no
    # differentiation rule and would kill jax.grad through the stack).
    x, positions = utils.grad_safe_barrier((x, positions))
    if block_type in ("dense", "moe", "shared_attn"):
        if block_type == "shared_attn":
            xin = jnp.concatenate([x, emb0], axis=-1) @ \
                p["w_in"].astype(x.dtype)
        else:
            xin = x
        h = rms_norm(xin, p["ln1"], cfg.norm_eps)
        if collect_cache is not None:
            a, kv = _attn_forward(cfg, p["attn"], h, positions, window,
                                  return_kv=True)
            cache = _fill_kv_cache(cfg, kv, collect_cache, positions)
        else:
            a = _attn_forward(cfg, p["attn"], h, positions, window)
        xin = xin + a
        h2 = rms_norm(xin, p["ln2"], cfg.norm_eps)
        if block_type == "moe":
            f, moe_aux = moe_forward(p["ffn"], h2, top_k=cfg.moe_top_k,
                                     capacity_factor=cfg.capacity_factor)
            aux.update({k: jnp.asarray(v, jnp.float32)
                        for k, v in moe_aux.items()})
        else:
            f = swiglu_forward(p["ffn"], h2)
        out = xin + f
        if block_type == "shared_attn":
            out = x + out  # residual around the whole shared block
        return shard_ctx.constrain(out, "hidden"), aux, cache
    if block_type == "mamba2":
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        if collect_cache is not None:
            y, cache = mamba_mod.mamba2_forward(
                p["mixer"], h, expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
                d_state=cfg.ssm_state, return_state=True)
        else:
            y = mamba_mod.mamba2_forward(
                p["mixer"], h, expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
                d_state=cfg.ssm_state)
        return shard_ctx.constrain(x + y, "hidden"), aux, cache
    if block_type == "rwkv6":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if collect_cache is not None:
            y, tcache = rwkv_mod.rwkv6_forward(
                p["tmix"], h, head_dim=cfg.rwkv_head_dim, return_state=True)
        else:
            y = rwkv_mod.rwkv6_forward(p["tmix"], h,
                                       head_dim=cfg.rwkv_head_dim)
            tcache = None
        x = x + y
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        h2_prev = jnp.pad(h2, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        x = x + cmix_forward(p["cmix"], h2, h2_prev)
        if collect_cache is not None:
            cache = dict(tmix=tcache, cmix_last=h2[:, -1:])
        return shard_ctx.constrain(x, "hidden"), aux, cache
    raise ValueError(block_type)


def _fill_kv_cache(cfg: ArchConfig, kv, cache_len: int, positions):
    """Place prefill K/V into a ring buffer of ``cache_len`` slots."""
    if cfg.attn_type == "mla":
        ckv, krope = kv  # (B, S, kv_lora), (B, S, dr)
        b, s = ckv.shape[:2]
        cache = mla_mod.init_mla_cache(b, cache_len, cfg.kv_lora_rank,
                                       cfg.qk_rope_dim,
                                       dtype=ckv.dtype)
        keep = min(s, cache_len)
        pos = positions[-keep:]
        slots = jnp.mod(pos, cache_len)
        cache["ckv"] = cache["ckv"].at[:, slots].set(ckv[:, -keep:])
        cache["krope"] = cache["krope"].at[:, slots].set(krope[:, -keep:])
        cache["pos"] = cache["pos"].at[:, slots].set(
            jnp.broadcast_to(pos, (b, keep)))
        return cache
    k, v = kv  # (B, S, KH, hd)
    b, s = k.shape[:2]
    cache = attn_mod.init_kv_cache(b, cache_len, cfg.n_kv_heads,
                                   cfg.head_dim, dtype=k.dtype,
                                   bits=cfg.kv_cache_bits)
    keep = min(s, cache_len)
    pos = positions[-keep:]
    slots = jnp.mod(pos, cache_len)
    if cfg.kv_cache_bits == 8:
        kc, ks = attn_mod.quantize_kv_token(k[:, -keep:])
        vc, vs = attn_mod.quantize_kv_token(v[:, -keep:])
        cache["k"] = cache["k"].at[:, slots].set(kc)
        cache["v"] = cache["v"].at[:, slots].set(vc)
        cache["k_scale"] = cache["k_scale"].at[:, slots].set(ks)
        cache["v_scale"] = cache["v_scale"].at[:, slots].set(vs)
    else:
        cache["k"] = cache["k"].at[:, slots].set(k[:, -keep:])
        cache["v"] = cache["v"].at[:, slots].set(v[:, -keep:])
    cache["pos"] = cache["pos"].at[:, slots].set(
        jnp.broadcast_to(pos, (b, keep)))
    return cache


def block_decode(cfg: ArchConfig, block_type: str, p: Dict, x: jnp.ndarray,
                 cache, *, qpos, window, emb0=None, page_table=None):
    """One-token block step. Returns (x, new_cache).

    ``page_table`` (S, npp) switches the attention blocks onto the paged
    KV pool path (serving engine): ``cache`` is then the (P, pg, ...) pool
    tree from ``attn_mod.init_paged_kv_pool`` and the batch axis of ``x``
    is the scheduler slot axis."""
    if block_type in ("dense", "moe", "shared_attn"):
        if block_type == "shared_attn":
            xin = jnp.concatenate([x, emb0], axis=-1) @ \
                p["w_in"].astype(x.dtype)
        else:
            xin = x
        h = rms_norm(xin, p["ln1"], cfg.norm_eps)
        if page_table is not None:
            if cfg.attn_type == "mla":
                raise NotImplementedError("paged decode requires GQA KV "
                                          "caches (attn_type != mla)")
            a, new_cache = attn_mod.gqa_decode_paged(
                p["attn"], h, cache, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                rope_theta=cfg.rope_theta, qpos=qpos,
                page_table=page_table, window=window)
        elif cfg.attn_type == "mla":
            a, new_cache = mla_mod.mla_decode(
                p["attn"], h, cache, n_heads=cfg.n_heads,
                qk_nope_dim=cfg.qk_nope_dim, qk_rope_dim=cfg.qk_rope_dim,
                v_head_dim=cfg.v_head_dim, kv_lora_rank=cfg.kv_lora_rank,
                rope_theta=cfg.rope_theta, qpos=qpos, window=window)
        else:
            a, new_cache = attn_mod.gqa_decode(
                p["attn"], h, cache, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                rope_theta=cfg.rope_theta, qpos=qpos, window=window)
        xin = xin + a
        h2 = rms_norm(xin, p["ln2"], cfg.norm_eps)
        if block_type == "moe":
            f, _ = moe_forward(p["ffn"], h2, top_k=cfg.moe_top_k,
                               capacity_factor=8.0)  # no drops at decode
        else:
            f = swiglu_forward(p["ffn"], h2)
        out = xin + f
        if block_type == "shared_attn":
            out = x + out
        return out, new_cache
    if block_type == "mamba2":
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        y, new_cache = mamba_mod.mamba2_decode(
            p["mixer"], h, cache, expand=cfg.ssm_expand,
            headdim=cfg.ssm_headdim, d_state=cfg.ssm_state)
        return x + y, new_cache
    if block_type == "rwkv6":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, tcache = rwkv_mod.rwkv6_decode(p["tmix"], h, cache["tmix"],
                                          head_dim=cfg.rwkv_head_dim)
        x = x + y
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + cmix_forward(p["cmix"], h2,
                             cache["cmix_last"].astype(h2.dtype))
        return x, dict(tmix=tcache, cmix_last=h2)
    raise ValueError(block_type)


# ---------------------------------------------------------------------------
# cache init (for serve_step input specs and tests)
# ---------------------------------------------------------------------------

def init_block_cache(cfg: ArchConfig, block_type: str, batch: int,
                     cache_len: int, dtype):
    if block_type in ("dense", "moe", "shared_attn"):
        if cfg.attn_type == "mla":
            return mla_mod.init_mla_cache(batch, cache_len, cfg.kv_lora_rank,
                                          cfg.qk_rope_dim, dtype)
        return attn_mod.init_kv_cache(batch, cache_len, cfg.n_kv_heads,
                                      cfg.head_dim, dtype,
                                      bits=cfg.kv_cache_bits)
    if block_type == "mamba2":
        return mamba_mod.init_mamba2_cache(
            batch, cfg.d_model, expand=cfg.ssm_expand,
            headdim=cfg.ssm_headdim, d_state=cfg.ssm_state, dtype=dtype)
    if block_type == "rwkv6":
        return dict(
            tmix=rwkv_mod.init_rwkv6_cache(batch, cfg.d_model,
                                           cfg.rwkv_head_dim, dtype),
            cmix_last=jnp.zeros((batch, 1, cfg.d_model), dtype))
    raise ValueError(block_type)


def init_caches(cfg: ArchConfig, batch: int, cache_len: int,
                dtype=jnp.bfloat16):
    """Stacked caches per segment, keyed like the params tree."""
    client_segs, server_segs = cfg.client_server_segments()
    out = {}
    for side, segs in (("client", client_segs), ("server", server_segs)):
        side_caches = {}
        for i, (t, n) in enumerate(segs):
            one = init_block_cache(cfg, t, batch, cache_len, dtype)
            side_caches[f"seg{i}"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy()
                if n > 1 else a[None], one)
        out[side] = side_caches
    return out


def init_paged_caches(cfg: ArchConfig, n_pages: int, page_size: int,
                      dtype=jnp.bfloat16):
    """Stacked paged KV pools per segment, keyed like ``init_caches``.

    One (P, pg, ...) pool per layer (leading layer axis per segment); the
    per-request page table is shared across layers, so page p always means
    the same logical span in every layer's pool.  Serving-engine only:
    requires every block to be an attention block with GQA caches."""
    if cfg.attn_type == "mla":
        raise NotImplementedError("paged serving requires GQA KV caches")
    client_segs, server_segs = cfg.client_server_segments()
    out = {}
    for side, segs in (("client", client_segs), ("server", server_segs)):
        side_caches = {}
        for i, (t, n) in enumerate(segs):
            if t not in ("dense", "moe", "shared_attn"):
                raise NotImplementedError(
                    f"paged serving does not support {t} blocks")
            one = attn_mod.init_paged_kv_pool(
                n_pages, page_size, cfg.n_kv_heads, cfg.head_dim, dtype,
                bits=cfg.kv_cache_bits)
            side_caches[f"seg{i}"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy()
                if n > 1 else a[None], one)
        out[side] = side_caches
    return out


# ---------------------------------------------------------------------------
# whole-model init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig) -> Dict:
    dtype = pdtype(cfg)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    if cfg.modality == "audio":
        params["embed"] = emb_mod.init_codebook_embedding(
            keys[0], cfg.n_codebooks, cfg.vocab_size, cfg.d_model, dtype)
    else:
        params["embed"] = emb_mod.init_embedding(
            keys[0], cfg.vocab_size, cfg.d_model, dtype)
    if cfg.modality == "vlm":
        params["connector"] = init_mlp_params(
            keys[1], cfg.d_vision, cfg.d_connector or cfg.d_model,
            cfg.d_model, dtype)
    params["head"] = emb_mod.init_head(
        keys[2], cfg.d_model, cfg.vocab_size,
        n_codebooks=cfg.n_codebooks if cfg.modality == "audio" else 0,
        dtype=dtype)
    params["final_norm"] = jnp.ones((cfg.d_model,), dtype)

    pattern = cfg.block_pattern()
    if "shared_attn" in pattern:
        params["shared_attn"] = init_block_params(keys[3], cfg, "shared_attn")

    client_segs, server_segs = cfg.client_server_segments()
    seg_key = keys[4]
    for side, segs in (("client", client_segs), ("server", server_segs)):
        side_params = {}
        for i, (t, n) in enumerate(segs):
            seg_key, sub = jax.random.split(seg_key)
            if t == "shared_attn":
                side_params[f"seg{i}"] = {}  # params live at top level
            else:
                lkeys = jax.random.split(sub, n)
                side_params[f"seg{i}"] = jax.vmap(
                    lambda k: init_block_params(k, cfg, t))(lkeys)
        params[side] = side_params

    if cfg.split.enabled and cfg.split.learnable_codec:
        params["codec"] = split_mod.init_codec_params(
            keys[5], cfg.d_model, dtype)
    return params


# ---------------------------------------------------------------------------
# whole-model forward
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg: ArchConfig, batch: Dict) -> jnp.ndarray:
    dtype = cdtype(cfg)
    if cfg.modality == "vlm":
        if "image_features" in batch:
            # split-serve: the client ran the vision tower + connector and
            # shipped the connector activations over the quantized wire —
            # the server embeds them as-is (core/split.serve_*).
            img = batch["image_features"].astype(dtype)
        else:
            img = mlp_forward(params["connector"],
                              batch["image_embeds"].astype(dtype))
        tok = emb_mod.embed(params["embed"], batch["tokens"], dtype)
        return jnp.concatenate([img, tok], axis=1)
    if cfg.modality == "audio":
        return emb_mod.embed_codebooks(params["embed"], batch["codes"], dtype)
    return emb_mod.embed(params["embed"], batch["tokens"], dtype)


def _run_segments(params, cfg: ArchConfig, side: str, segs, x, *, positions,
                  window, emb0, collect_cache: Optional[int] = None):
    """Run one side's segment list through the stack executor.

    Returns (x, aux_sum, caches)."""
    aux_sum = dict(_EMPTY_AUX)
    caches = {}
    for i, (t, n) in enumerate(segs):
        if t == "shared_attn":
            x, aux, cache = block_forward(
                cfg, t, params["shared_attn"], x, positions=positions,
                window=window, emb0=emb0, collect_cache=collect_cache)
            aux_sum = {k: aux_sum[k] + aux[k] for k in aux_sum}
            if collect_cache is not None:
                caches[f"seg{i}"] = jax.tree_util.tree_map(
                    lambda a: a[None], cache)
            continue

        def body(carry, p, _t=t):
            y, aux, cache = block_forward(
                cfg, _t, p, carry, positions=positions, window=window,
                emb0=emb0, collect_cache=collect_cache)
            return y, (aux, cache)

        stacked = params[side][f"seg{i}"]
        remat_group = cfg.remat_group
        if cfg.remat and remat_group == 0:
            # unset -> bytes-aware auto-tune from the carry entering the
            # segment (the stored layer input of the remat schedule)
            remat_group = stack_mod.auto_group_size(
                stack_mod.stack_len(stacked), x.size * x.dtype.itemsize)
        x, seg_aux, seg_caches = stack_mod.run_stack(
            body, x, stacked, remat=cfg.remat,
            remat_group=remat_group,
            collect=collect_cache is not None)
        aux_sum = {kk: aux_sum[kk] + seg_aux[kk] for kk in aux_sum}
        if collect_cache is not None:
            caches[f"seg{i}"] = seg_caches
    return x, aux_sum, caches


def forward(params, cfg: ArchConfig, batch: Dict, *,
            rng: Optional[jax.Array] = None, window: Optional[int] = None,
            collect_cache: Optional[int] = None):
    """Full-sequence forward (train / prefill).

    Returns (logits, aux) or (logits, aux, caches) when
    ``collect_cache`` (a cache length) is given.
    aux = {commit, load_balance, router_z, drop_fraction}.
    """
    x = shard_ctx.constrain(_embed_inputs(params, cfg, batch), "hidden")
    emb0 = x
    s = x.shape[1]
    # positions as RUNTIME data (input_specs provides them): if they were
    # trace-time iota, XLA constant-folds attention masks and widens them
    # into giant stacked buffers inside the layer scans (see attention.py).
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.arange(s)
    positions = positions.astype(jnp.int32)
    client_segs, server_segs = cfg.client_server_segments()

    x, aux_c, caches_c = _run_segments(
        params, cfg, "client", client_segs, x, positions=positions,
        window=window, emb0=emb0, collect_cache=collect_cache)

    # --- the paper's compressor at the cut ---
    x, commit = split_mod.compressor_roundtrip(
        params.get("codec"), cfg.split, x, rng)

    x, aux_s, caches_s = _run_segments(
        params, cfg, "server", server_segs, x, positions=positions,
        window=window, emb0=emb0, collect_cache=collect_cache)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = emb_mod.head_logits(params["head"], x)
    if logits.ndim == 3:
        logits = shard_ctx.constrain(logits, "logits")
    aux = {k: aux_c[k] + aux_s[k] for k in aux_c}
    aux["commit"] = commit
    if collect_cache is not None:
        return logits, aux, dict(client=caches_c, server=caches_s)
    return logits, aux


def decode_step(params, cfg: ArchConfig, caches: Dict, batch: Dict,
                qpos: jnp.ndarray, *, window: Optional[int] = None,
                rng: Optional[jax.Array] = None):
    """One-token serve step.

    batch: {tokens: (B, 1)} (or codes (B, K, 1) for audio;
    tokens-only for VLM decode — images were consumed at prefill).
    qpos: (B,) absolute positions.  Returns (logits, new_caches).
    """
    dtype = cdtype(cfg)
    if cfg.modality == "audio":
        x = emb_mod.embed_codebooks(params["embed"], batch["codes"], dtype)
    else:
        x = emb_mod.embed(params["embed"], batch["tokens"], dtype)
    emb0 = x
    client_segs, server_segs = cfg.client_server_segments()
    new_caches = {"client": {}, "server": {}}

    def run_side(side, segs, x):
        for i, (t, n) in enumerate(segs):
            cache = caches[side][f"seg{i}"]
            if t == "shared_attn":
                x, c_new = block_decode(
                    cfg, t, params["shared_attn"], x,
                    jax.tree_util.tree_map(lambda a: a[0], cache),
                    qpos=qpos, window=window, emb0=emb0)
                new_caches[side][f"seg{i}"] = jax.tree_util.tree_map(
                    lambda a: a[None], c_new)
                continue
            stacked = params[side][f"seg{i}"]

            def body(carry, pc, _t=t):
                p, c = pc
                y, c_new = block_decode(cfg, _t, p, carry, c, qpos=qpos,
                                        window=window, emb0=emb0)
                return y, c_new

            x, seg_caches = stack_mod.run_decode_stack(body, x, stacked,
                                                       cache)
            new_caches[side][f"seg{i}"] = seg_caches
        return x

    x = run_side("client", client_segs, x)
    x, _ = split_mod.compressor_roundtrip(params.get("codec"), cfg.split, x,
                                          rng)
    x = run_side("server", server_segs, x)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = emb_mod.head_logits(params["head"], x)
    return logits, new_caches


def decode_step_paged(params, cfg: ArchConfig, pools: Dict, batch: Dict,
                      qpos: jnp.ndarray, page_table: jnp.ndarray, *,
                      window: Optional[int] = None,
                      rng: Optional[jax.Array] = None):
    """One decode tick of the serving engine against paged KV pools.

    ``pools``: tree from ``init_paged_caches``; ``page_table``: (S, npp)
    int32, -1 = unallocated; ``qpos``: (S,), -1 = inactive slot (its
    logits are garbage and its KV write lands on the trash page).
    Returns (logits, new_pools)."""
    dtype = cdtype(cfg)
    if cfg.modality == "audio":
        x = emb_mod.embed_codebooks(params["embed"], batch["codes"], dtype)
    else:
        x = emb_mod.embed(params["embed"], batch["tokens"], dtype)
    emb0 = x
    client_segs, server_segs = cfg.client_server_segments()
    new_pools = {"client": {}, "server": {}}

    def run_side(side, segs, x):
        for i, (t, n) in enumerate(segs):
            cache = pools[side][f"seg{i}"]
            if t == "shared_attn":
                x, c_new = block_decode(
                    cfg, t, params["shared_attn"], x,
                    jax.tree_util.tree_map(lambda a: a[0], cache),
                    qpos=qpos, window=window, emb0=emb0,
                    page_table=page_table)
                new_pools[side][f"seg{i}"] = jax.tree_util.tree_map(
                    lambda a: a[None], c_new)
                continue
            stacked = params[side][f"seg{i}"]

            def body(carry, pc, _t=t):
                p, c = pc
                y, c_new = block_decode(cfg, _t, p, carry, c, qpos=qpos,
                                        window=window, emb0=emb0,
                                        page_table=page_table)
                return y, c_new

            x, seg_pools = stack_mod.run_decode_stack(body, x, stacked,
                                                      cache)
            new_pools[side][f"seg{i}"] = seg_pools
        return x

    x = run_side("client", client_segs, x)
    x, _ = split_mod.compressor_roundtrip(params.get("codec"), cfg.split, x,
                                          rng)
    x = run_side("server", server_segs, x)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = emb_mod.head_logits(params["head"], x)
    return logits, new_pools
