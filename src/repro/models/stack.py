"""Unified layer-stack executor.

Every consecutive run of identical blocks in the decoder is stored with a
leading layer axis and executed as ONE ``lax.scan`` — one trace per
segment instead of one per layer, which keeps 62-layer dry-run compiles
tractable.  Before this module the scan/remat/cache plumbing was
duplicated (with slightly different bugs) across ``transformer.forward``'s
client and server halves, the split pipeline stages and the decode path.
This is now the single place that knows how to run a stacked segment.

Execution policies, selected by keyword arguments of :func:`run_stack`:

* **plain scan** — ``remat=False``: one forward scan, cheapest compile.
* **single-level remat** — ``remat=True``: the per-layer body is wrapped
  in ``jax.checkpoint`` so the backward pass stores only layer inputs.
* **two-level (sqrt-L) remat** — ``remat=True, remat_group=k>1``: layers
  are grouped into chunks of ``k``; both the group scan and the per-layer
  body are checkpointed, so the backward stores ``n/k`` group inputs plus
  the ``k`` layer inputs of the group in flight instead of all ``n``
  layer inputs.  Remainder layers (``n % k``) run through the
  single-level path, so prime segment lengths still group.
* **cache collection** — ``collect=True``: the scan also stacks the
  per-layer cache outputs (KV / SSM state) for the serve path.

The body contract is ``body(carry, p) -> (carry, (aux, cache))`` where
``aux`` is a pytree of per-layer scalars (may be ``{}``) and ``cache`` is
``None`` unless the caller collects caches.  ``run_stack`` returns
``(carry, aux_summed_over_layers, caches_or_None)``.

Gradient safety: bodies that pin values with a barrier must use
``repro.utils.grad_safe_barrier`` (NOT raw ``lax.optimization_barrier``,
which has no differentiation rule) — the executor is on the hot path of
every train step.
"""
from __future__ import annotations

import math
import os
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

Body = Callable[[Any, Any], Tuple[Any, Tuple[Any, Any]]]

# Per-device byte budget for single-level-remat stored layer inputs before
# the auto-tuner switches a segment to two-level (sqrt-L) grouping.
# Shapes at trace time are pre-GSPMD (global), so the default is sized for
# global activations on a production mesh; override per deployment with
# REPRO_REMAT_BUDGET_BYTES.
_DEFAULT_REMAT_BUDGET = 4 * 1024 ** 3


def auto_group_size(n: int, layer_bytes: int,
                    budget: Optional[int] = None) -> int:
    """Bytes-aware two-level-remat group size for an ``n``-layer segment.

    Single-level remat stores one carry per layer: ``n * layer_bytes``.
    When that fits ``budget`` (REPRO_REMAT_BUDGET_BYTES, default 4 GiB),
    grouping only costs extra recompute + FSDP regathers, so stay
    single-level (returns 1).  Beyond it, ``k = round(sqrt(n))`` minimizes
    the ``n/k`` group inputs + ``k`` in-flight layer inputs the two-level
    schedule stores — ~2*sqrt(n) carries instead of n (EXPERIMENTS.md
    SSPerf A8).  Explicit ``cfg.remat_group`` always wins over this.
    """
    if n < 4:
        return 1
    if budget is None:
        budget = int(os.environ.get("REPRO_REMAT_BUDGET_BYTES",
                                    _DEFAULT_REMAT_BUDGET))
    if n * layer_bytes <= budget:
        return 1
    return max(2, round(math.sqrt(n)))


def group_size(n: int, target: int = 8) -> int:
    """Inner group size <= target for sqrt-L remat.

    The ``n % k`` remainder layers run through the single-level path, so
    prime segment lengths like 29/31 still get grouping for the bulk.
    """
    if n < 4:
        return 1
    return min(target, n)


def stack_len(stacked) -> int:
    """Leading (layer) axis length of a stacked parameter tree."""
    return jax.tree_util.tree_leaves(stacked)[0].shape[0]


def _sum_layer_axis(tree):
    return jax.tree_util.tree_map(lambda v: jnp.sum(v, axis=0), tree)


def _tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def run_stack(body: Body, carry, stacked, *, remat: bool = False,
              remat_group: int = 0, collect: bool = False):
    """Run ``body`` over the leading layer axis of ``stacked``.

    Returns ``(carry, aux_sum, caches)`` — ``aux_sum`` is the per-layer
    aux pytree summed over layers; ``caches`` is the layer-stacked cache
    pytree when ``collect`` else ``None``.
    """
    n = stack_len(stacked)
    layer = jax.checkpoint(body) if remat else body

    k = group_size(n, remat_group) if remat_group > 1 else 1
    if remat and not collect and k > 1:
        # two-level (sqrt-L) checkpointing (EXPERIMENTS.md SSPerf A8)
        m = (n // k) * k
        grouped = jax.tree_util.tree_map(
            lambda a: a[:m].reshape((m // k, k) + a.shape[1:]), stacked)

        def group(c, pk):
            c, (auxs, _) = jax.lax.scan(layer, c, pk)
            return c, _sum_layer_axis(auxs)

        carry, group_auxs = jax.lax.scan(jax.checkpoint(group), carry,
                                         grouped)
        aux_sum = _sum_layer_axis(group_auxs)
        if m < n:  # remainder layers: single-level remat
            rest = jax.tree_util.tree_map(lambda a: a[m:], stacked)
            carry, (auxs_r, _) = jax.lax.scan(layer, carry, rest)
            aux_sum = _tree_add(aux_sum, _sum_layer_axis(auxs_r))
        return carry, aux_sum, None

    carry, (auxs, caches) = jax.lax.scan(layer, carry, stacked)
    return carry, _sum_layer_axis(auxs), (caches if collect else None)


def run_decode_stack(body: Callable[[Any, Tuple[Any, Any]],
                                    Tuple[Any, Any]],
                     carry, stacked, caches):
    """One-token decode over a stacked segment.

    ``body(carry, (p, cache)) -> (carry, new_cache)``; scans layer params
    and their caches in lockstep and returns ``(carry, new_caches)`` with
    the same layer-stacked structure as ``caches``.
    """
    return jax.lax.scan(body, carry, (stacked, caches))
