"""Mamba2 (SSD) layer — chunked state-space duality forward + O(1) decode.

Training/prefill uses the chunkwise SSD algorithm (Dao & Gu 2024): within a
chunk of Q tokens the quadratic matmul form runs on the MXU; states are
carried across chunks with a lax.scan, so memory is O(Q^2) per chunk rather
than O(S^2).  Decode is the exact single-step recurrence on the
(B, nheads, headdim, dstate) state — this is what makes long_500k decode
O(1) in sequence length for the SSM/hybrid architectures.

ngroups is fixed at 1 (B/C shared across heads), matching Zamba2.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers.norms import rms_norm

CONV_WIDTH = 4


def dims(d_model: int, expand: int, headdim: int, d_state: int):
    d_inner = expand * d_model
    nheads = d_inner // headdim
    conv_dim = d_inner + 2 * d_state  # x, B, C all convolved
    return d_inner, nheads, conv_dim


def init_mamba2_params(key, d_model: int, *, expand: int = 2,
                       headdim: int = 64, d_state: int = 64,
                       dtype=jnp.float32) -> Dict:
    d_inner, nheads, conv_dim = dims(d_model, expand, headdim, d_state)
    ks = jax.random.split(key, 4)
    in_dim = 2 * d_inner + 2 * d_state + nheads  # z, x, B, C, dt
    s = d_model ** -0.5
    dt_init = jnp.exp(jax.random.uniform(ks[2], (nheads,)) *
                      (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
    return dict(
        in_proj=(jax.random.normal(ks[0], (d_model, in_dim)) * s
                 ).astype(dtype),
        conv_w=(jax.random.normal(ks[1], (CONV_WIDTH, conv_dim)) * 0.1
                ).astype(dtype),
        conv_b=jnp.zeros((conv_dim,), dtype),
        A_log=jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        D=jnp.ones((nheads,), jnp.float32),
        dt_bias=(dt_init + jnp.log(-jnp.expm1(-dt_init))).astype(jnp.float32),
        norm_w=jnp.ones((d_inner,), dtype),
        out_proj=(jax.random.normal(ks[3], (d_inner, d_model))
                  * d_inner ** -0.5).astype(dtype),
    )


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 cache: jnp.ndarray | None = None):
    """Depthwise causal conv over (B, S, C); cache (B, CONV_WIDTH-1, C)."""
    if cache is None:
        pad = jnp.zeros((x.shape[0], CONV_WIDTH - 1, x.shape[2]), x.dtype)
    else:
        pad = cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(CONV_WIDTH))
    new_cache = xp[:, -(CONV_WIDTH - 1):]
    return out + b.astype(x.dtype), new_cache


def _split_proj(zxbcdt, d_inner, d_state, nheads):
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_inner + 2 * d_state]
    dt = zxbcdt[..., -nheads:]
    return z, xbc, dt


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                b_in: jnp.ndarray, c_in: jnp.ndarray, d_skip: jnp.ndarray,
                *, chunk: int = 128,
                init_state: jnp.ndarray | None = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunkwise SSD.

    x: (B, S, H, P); dt: (B, S, H); b_in/c_in: (B, S, N); returns
    (y (B, S, H, P), final_state (B, H, P, N)).  fp32 internally.
    """
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk

    a = -jnp.exp(a_log.astype(jnp.float32))  # (H,)
    dtf = dt.astype(jnp.float32)
    xf = (x.astype(jnp.float32) * dtf[..., None])  # dt-scaled input
    adt = dtf * a  # (B, S', H)

    def to_chunks(t, trailing):
        return t.reshape((bsz, nc, chunk) + trailing).transpose(
            (1, 0, 2) + tuple(range(3, 3 + len(trailing))))

    xc = to_chunks(xf, (h, p))       # (nc, B, Q, H, P)
    ac = to_chunks(adt, (h,))        # (nc, B, Q, H)
    bc = to_chunks(b_in.astype(jnp.float32), (n,))  # (nc, B, Q, N)
    cc = to_chunks(c_in.astype(jnp.float32), (n,))

    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(state, inp):
        x_q, a_q, b_q, c_q = inp  # (B,Q,H,P) (B,Q,H) (B,Q,N) (B,Q,N)
        t_cum = jnp.cumsum(a_q, axis=1)  # inclusive (B,Q,H)
        # intra-chunk: M[b,h,i,j] = exp(T_i - T_j) * (C_i . B_j), i >= j
        scores = jnp.einsum("bin,bjn->bij", c_q, b_q)
        decay = jnp.exp(t_cum[:, :, None, :] - t_cum[:, None, :, :])
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        m = jnp.where(tri[None, :, :, None], decay, 0.0) * \
            scores[..., None]  # (B,i,j,H)
        y_diag = jnp.einsum("bijh,bjhp->bihp", m, x_q)
        # inter-chunk: previous state decayed to each position
        out_decay = jnp.exp(t_cum)  # (B,Q,H)
        y_off = jnp.einsum("bin,bhpn,bih->bihp", c_q, state, out_decay)
        # state update
        t_last = t_cum[:, -1:, :]  # (B,1,H)
        in_decay = jnp.exp(t_last - t_cum)  # (B,Q,H)
        chunk_state = jnp.einsum("bjn,bjhp,bjh->bhpn", b_q, x_q, in_decay)
        state_new = jnp.exp(t_last[:, 0, :])[..., None, None] * state + \
            chunk_state
        return state_new, y_diag + y_off

    final_state, ys = jax.lax.scan(step, init_state, (xc, ac, bc, cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, nc * chunk, h, p)[:, :s]
    y = y + d_skip.astype(jnp.float32) * x.astype(jnp.float32)[:, :s]
    return y, final_state


def mamba2_forward(params: Dict, x: jnp.ndarray, *, expand: int,
                   headdim: int, d_state: int, chunk: int = 128,
                   return_state: bool = False):
    """Full-sequence forward. x: (B, S, D)."""
    d_model = x.shape[-1]
    d_inner, nheads, _ = dims(d_model, expand, headdim, d_state)
    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z, xbc, dt = _split_proj(zxbcdt, d_inner, d_state, nheads)
    xbc, conv_cache = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xbc = jax.nn.silu(xbc)
    xin = xbc[..., :d_inner].reshape(*x.shape[:2], nheads, headdim)
    b_in = xbc[..., d_inner:d_inner + d_state]
    c_in = xbc[..., d_inner + d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    y, state = ssd_chunked(xin, dt, params["A_log"], b_in, c_in,
                           params["D"][None, None, :, None], chunk=chunk)
    y = y.reshape(*x.shape[:2], d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"])
    out = y @ params["out_proj"].astype(x.dtype)
    if return_state:
        return out, dict(state=state, conv=conv_cache)
    return out


def mamba2_decode(params: Dict, x: jnp.ndarray, cache: Dict, *, expand: int,
                  headdim: int, d_state: int):
    """Single-token recurrence. x: (B, 1, D); cache {state, conv}."""
    d_model = x.shape[-1]
    d_inner, nheads, _ = dims(d_model, expand, headdim, d_state)
    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z, xbc, dt = _split_proj(zxbcdt, d_inner, d_state, nheads)
    xbc, conv_cache = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                   cache["conv"])
    xbc = jax.nn.silu(xbc)[:, 0]
    xin = xbc[..., :d_inner].reshape(-1, nheads, headdim).astype(jnp.float32)
    b_in = xbc[..., d_inner:d_inner + d_state].astype(jnp.float32)
    c_in = xbc[..., d_inner + d_state:].astype(jnp.float32)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    da = jnp.exp(dtv * a)  # (B, H)
    state = cache["state"] * da[..., None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", xin, b_in, dtv)
    y = jnp.einsum("bhpn,bn->bhp", state, c_in) + \
        params["D"][None, :, None] * xin
    y = y.reshape(-1, 1, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"])
    out = y @ params["out_proj"].astype(x.dtype)
    return out, dict(state=state, conv=conv_cache)


def init_mamba2_cache(batch: int, d_model: int, *, expand: int, headdim: int,
                      d_state: int, dtype=jnp.float32) -> Dict:
    d_inner, nheads, conv_dim = dims(d_model, expand, headdim, d_state)
    return dict(
        state=jnp.zeros((batch, nheads, headdim, d_state), jnp.float32),
        conv=jnp.zeros((batch, CONV_WIDTH - 1, conv_dim), dtype),
    )
