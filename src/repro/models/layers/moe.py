"""Mixture-of-Experts with grouped, sort-based capacity dispatch.

Token-choice top-k routing with a static per-expert capacity, dispatched
*per token group* so that routing stays local to a data shard:

  1. tokens reshaped to (G, T/G, D); groups align with the data-parallel
     axis (sharding constraint), so the per-group argsort / searchsorted /
     scatter never cross shards,
  2. top-k experts per token, gates renormalized,
  3. stable per-group sort of (token, expert) copies by expert id;
     position-in-expert via searchsorted; tokens past the per-group
     capacity are dropped,
  4. scatter into a (G, E, C, D) buffer — expert axis sharded over the
     ``model`` mesh axis (expert parallelism; the dispatch becomes the
     all-to-all you expect in the lowered HLO) — one batched einsum
     against the stacked expert weights, gather + segment-sum back.

This avoids the (tokens, experts, capacity) one-hot dispatch masks of the
classic Switch formulation AND keeps every intermediate sharded: with
ungrouped dispatch the 1M-token deepseek-v2 buffers replicated to
251 GiB/device (EXPERIMENTS.md SSPerf iteration A1).

Aux losses: switch-style load balance + router z-loss.  Covers deepseek-v2
(2 shared + 160 routed, top-6) and arctic (dense-residual + 128 routed,
top-2).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers.mlp import init_swiglu_params, swiglu_forward
from repro.sharding import ctx as shard_ctx


def init_moe_params(key, d_model: int, n_experts: int, d_ff: int, *,
                    n_shared_experts: int = 0,
                    dense_residual_d_ff: int = 0,
                    dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 6)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    p = dict(
        router=(jax.random.normal(ks[0], (d_model, n_experts)) * s_in
                ).astype(jnp.float32),  # router kept fp32 for stability
        w_gate=(jax.random.normal(ks[1], (n_experts, d_model, d_ff)) * s_in
                ).astype(dtype),
        w_up=(jax.random.normal(ks[2], (n_experts, d_model, d_ff)) * s_in
              ).astype(dtype),
        w_down=(jax.random.normal(ks[3], (n_experts, d_ff, d_model)) * s_out
                ).astype(dtype),
    )
    if n_shared_experts > 0:
        p["shared"] = init_swiglu_params(
            ks[4], d_model, n_shared_experts * d_ff, dtype)
    if dense_residual_d_ff > 0:
        p["dense_residual"] = init_swiglu_params(
            ks[5], d_model, dense_residual_d_ff, dtype)
    return p


def moe_aux_losses(logits: jnp.ndarray, probs: jnp.ndarray,
                   expert_ids: jnp.ndarray, n_experts: int) -> Dict:
    """Switch-style load balance + router z-loss.

    ``load_balance = E * mean_t(mean prob of token t's top-k experts)``.
    The expert fraction and the router prob MUST be coupled per token
    (not averaged over tokens separately and then dotted — that version
    has no lower bound and dips below 1 from sampling noise): each
    token's k selected probs are its k largest, so their mean is >= the
    all-expert mean 1/E, giving ``load_balance >= 1`` for ANY router
    (Cauchy-Schwarz / Chebyshev sum), with equality iff the router is
    uniform.  Dropped-by-capacity tokens are intentionally included —
    the router chose them, so they must count toward balance pressure.

    Balance pressure is preserved: d(loss)/d(prob of expert i), summed
    over tokens, is E/(T*k) * count_i = E * frac_i — the same per-expert
    aggregate down-pressure as the classic Switch E*sum(frac_i*mean_p_i)
    term (whose gradient wrt mean_p_i is E*frac_i), so overloaded
    experts are pushed down proportionally to their actual load.
    """
    sel_probs = jnp.take_along_axis(probs, expert_ids, axis=-1).astype(
        jnp.float32)  # (..., K): router prob of each selected expert
    load_balance = n_experts * jnp.mean(sel_probs)
    z = jax.nn.logsumexp(logits, axis=-1)
    z_loss = jnp.mean(z * z)
    return dict(load_balance=load_balance, router_z=z_loss)


def _pick_groups(t: int, preferred: int = 16) -> int:
    """Largest divisor of t that is <= preferred."""
    g = min(preferred, t)
    while t % g:
        g -= 1
    return max(g, 1)


def moe_forward(params: Dict, x: jnp.ndarray, *, top_k: int,
                capacity_factor: float = 1.25,
                n_groups: int = 0) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, S, D) -> (B, S, D), aux-loss dict."""
    b, s, d = x.shape
    e = params["router"].shape[1]
    t = b * s
    preferred = shard_ctx.dp_size() if shard_ctx.active() else 16
    g = n_groups or _pick_groups(t, max(preferred, 16))
    tg = t // g
    dt = x.dtype

    xg = shard_ctx.constrain(x.reshape(g, tg, d), "hidden")

    logits = xg.astype(jnp.float32) @ params["router"]  # (G, TG, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)  # (G, TG, K)
    gate_vals = gate_vals / (gate_vals.sum(axis=-1, keepdims=True) + 1e-9)
    aux = moe_aux_losses(logits, probs, expert_ids, e)

    tk = tg * top_k
    cap = max(1, int(tk * capacity_factor / e))

    e_flat = expert_ids.reshape(g, tk)
    gates = gate_vals.reshape(g, tk)
    tok_ids = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg), top_k)[None], (g, tk))

    order = jnp.argsort(e_flat, axis=-1)  # stable per group
    sorted_e = jnp.take_along_axis(e_flat, order, axis=-1)
    sorted_tok = jnp.take_along_axis(tok_ids, order, axis=-1)
    sorted_g = jnp.take_along_axis(gates, order, axis=-1)

    starts = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(e), side="left")
    )(sorted_e)  # (G, E)
    pos_in_e = jnp.arange(tk)[None] - jnp.take_along_axis(
        starts, sorted_e, axis=-1)
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)

    def scatter_group(xr, sl, tok):
        buf = jnp.zeros((e * cap + 1, d), dt)
        return buf.at[sl].set(xr[tok])

    buf = jax.vmap(scatter_group)(xg, slot, sorted_tok)  # (G, E*cap+1, D)
    xe = buf[:, :-1].reshape(g, e, cap, d)
    xe = shard_ctx.constrain(xe, "moe_experts")

    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe,
                                  params["w_gate"].astype(dt)))
    up = jnp.einsum("gecd,edf->gecf", xe, params["w_up"].astype(dt))
    he = jnp.einsum("gecf,efd->gecd", gate * up,
                    params["w_down"].astype(dt))
    he = shard_ctx.constrain(he, "moe_experts")

    out_rows = jnp.concatenate(
        [he.reshape(g, e * cap, d), jnp.zeros((g, 1, d), dt)], axis=1)

    def gather_group(rows, sl, gv, kp, tok):
        contrib = rows[sl] * (gv * kp).astype(dt)[:, None]
        return jax.ops.segment_sum(contrib, tok, num_segments=tg)

    yg = jax.vmap(gather_group)(out_rows, slot, sorted_g, keep, sorted_tok)
    yg = shard_ctx.constrain(yg, "hidden")
    y_flat = yg.reshape(t, d)

    if "shared" in params:
        y_flat = y_flat + swiglu_forward(params["shared"], x.reshape(t, d))
    if "dense_residual" in params:
        y_flat = y_flat + swiglu_forward(params["dense_residual"],
                                         x.reshape(t, d))

    aux["drop_fraction"] = 1.0 - keep.mean()
    return y_flat.reshape(b, s, d), aux
