"""Token embeddings + output heads (text and multi-codebook audio)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32) -> Dict:
    return dict(emb=(jax.random.normal(key, (vocab, d_model)) * 0.02
                     ).astype(dtype))


def embed(params: Dict, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return params["emb"].astype(dtype)[tokens]


def init_codebook_embedding(key, n_codebooks: int, vocab: int, d_model: int,
                            dtype=jnp.float32) -> Dict:
    """MusicGen-style: one embedding table per EnCodec codebook, summed."""
    return dict(emb=(jax.random.normal(key, (n_codebooks, vocab, d_model))
                     * 0.02).astype(dtype))


def embed_codebooks(params: Dict, codes: jnp.ndarray, dtype) -> jnp.ndarray:
    """codes: (B, K, S) int32 -> (B, S, D) summed over codebooks."""
    emb = params["emb"].astype(dtype)  # (K, V, D)
    outs = [emb[k][codes[:, k]] for k in range(codes.shape[1])]
    return sum(outs)


def init_head(key, d_model: int, vocab: int, n_codebooks: int = 0,
              dtype=jnp.float32) -> Dict:
    if n_codebooks:
        return dict(w=(jax.random.normal(key, (n_codebooks, d_model, vocab))
                       * d_model ** -0.5).astype(dtype))
    return dict(w=(jax.random.normal(key, (d_model, vocab))
                   * d_model ** -0.5).astype(dtype))


def head_logits(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """(B, S, D) -> (B, S, V) or (B, S, K, V) for audio."""
    w = params["w"].astype(x.dtype)
    if w.ndim == 3:
        return jnp.einsum("bsd,kdv->bskv", x, w)
    return x @ w
