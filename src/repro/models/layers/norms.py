"""Normalization layers."""
from __future__ import annotations

import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf / jnp.sqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)


def group_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               n_groups: int, eps: float = 1e-5) -> jnp.ndarray:
    """GroupNorm over the last axis split into ``n_groups`` (RWKV head norm)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    shape = xf.shape
    xg = xf.reshape(*shape[:-1], n_groups, shape[-1] // n_groups)
    mean = jnp.mean(xg, axis=-1, keepdims=True)
    var = jnp.var(xg, axis=-1, keepdims=True)
    xg = (xg - mean) / jnp.sqrt(var + eps)
    out = xg.reshape(shape) * weight.astype(jnp.float32) + \
        bias.astype(jnp.float32)
    return out.astype(dtype)
