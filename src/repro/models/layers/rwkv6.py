"""RWKV6 ("Finch") time-mixing layer — data-dependent per-channel decay.

Attention-free linear-attention recurrence with matrix-valued state
S in R^{K x V} per head:

    y_t = r_t . (S_t + diag(u) k_t v_t^T)
    S_{t+1} = diag(w_t) S_t + k_t v_t^T,   w_t = exp(-exp(ww_t))

Training/prefill uses a chunkwise form (chunk Q=16): within a chunk the
decay products are factored into r~ = r * exp(T_{t-1}) and
k~ = k * exp(-T_t) (T = cumulative log-decay), turning the strictly-causal
part into two matmuls; states are carried across chunks by lax.scan.  The
per-step log-decay is clamped to [-DECAY_CLAMP, 0] so exp(-T) stays inside
fp32 for Q=16 (documented deviation; real RWKV6 decays rarely hit the
clamp).  Decode is the exact O(1) recurrence — this is the sub-quadratic
path for long_500k.

Token-shift uses the RWKV6 DDLerp: a low-rank, data-dependent interpolation
between x_t and x_{t-1} for each of (w, k, v, r, g).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers.norms import group_norm

DECAY_CLAMP = 5.0
MAA_RANK = 32
DECAY_RANK = 64
N_MIX = 5  # w, k, v, r, g


def init_rwkv6_params(key, d_model: int, head_dim: int = 64,
                      dtype=jnp.float32) -> Dict:
    h = d_model // head_dim
    ks = jax.random.split(key, 10)
    s = d_model ** -0.5
    lin = lambda k, i, o, sc: (jax.random.normal(k, (i, o)) * sc).astype(dtype)
    return dict(
        mu_x=jnp.full((d_model,), 0.5, dtype),
        mu_mix=jnp.full((N_MIX, d_model), 0.5, dtype),
        maa_w1=lin(ks[0], d_model, N_MIX * MAA_RANK, 0.01),
        maa_w2=(jax.random.normal(ks[1], (N_MIX, MAA_RANK, d_model)) * 0.01
                ).astype(dtype),
        decay_base=jnp.full((d_model,), -4.0, jnp.float32),
        decay_w1=lin(ks[2], d_model, DECAY_RANK, 0.01),
        decay_w2=lin(ks[3], DECAY_RANK, d_model, 0.01),
        u=(jax.random.normal(ks[4], (h, head_dim)) * 0.1).astype(jnp.float32),
        wr=lin(ks[5], d_model, d_model, s),
        wk=lin(ks[6], d_model, d_model, s),
        wv=lin(ks[7], d_model, d_model, s),
        wg=lin(ks[8], d_model, d_model, s),
        wo=lin(ks[9], d_model, d_model, s),
        ln_w=jnp.ones((d_model,), dtype),
        ln_b=jnp.zeros((d_model,), dtype),
    )


def _ddlerp(params: Dict, x: jnp.ndarray, x_prev: jnp.ndarray):
    """Data-dependent token shift; returns (xw, xk, xv, xr, xg)."""
    dt = x.dtype
    xx = x_prev - x
    xxx = x + xx * params["mu_x"].astype(dt)
    delta = jnp.tanh(xxx @ params["maa_w1"].astype(dt))
    delta = delta.reshape(*x.shape[:-1], N_MIX, MAA_RANK)
    delta = jnp.einsum("...mr,mrd->m...d", delta,
                       params["maa_w2"].astype(dt))
    mixed = [x + xx * (params["mu_mix"][i].astype(dt) + delta[i])
             for i in range(N_MIX)]
    return mixed  # w, k, v, r, g order


def _projections(params: Dict, x: jnp.ndarray, x_prev: jnp.ndarray,
                 head_dim: int):
    d = x.shape[-1]
    h = d // head_dim
    xw, xk, xv, xr, xg = _ddlerp(params, x, x_prev)
    dt = x.dtype
    r = (xr @ params["wr"].astype(dt)).reshape(*x.shape[:-1], h, head_dim)
    k = (xk @ params["wk"].astype(dt)).reshape(*x.shape[:-1], h, head_dim)
    v = (xv @ params["wv"].astype(dt)).reshape(*x.shape[:-1], h, head_dim)
    g = jax.nn.silu(xg @ params["wg"].astype(dt))
    ww = params["decay_base"] + (
        jnp.tanh(xw @ params["decay_w1"].astype(dt)) @
        params["decay_w2"].astype(dt)).astype(jnp.float32)
    log_w = -jnp.exp(ww)  # log of decay, <= 0
    log_w = jnp.clip(log_w, -DECAY_CLAMP, 0.0)
    log_w = log_w.reshape(*x.shape[:-1], h, head_dim)
    return r, k, v, g, log_w


def wkv_chunked(r, k, v, log_w, u, *, chunk: int = 16,
                init_state=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunkwise WKV.  r/k/v/log_w: (B, S, H, K); u: (H, K).

    Returns (y (B, S, H, K), final state (B, H, K, K)).
    """
    b, s, h, dk = r.shape
    pad = (-s) % chunk
    if pad:
        zf = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, log_w = zf(r), zf(k), zf(v), zf(log_w)
    nc = r.shape[1] // chunk

    def to_chunks(t):
        return t.reshape(b, nc, chunk, h, dk).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, wc = map(to_chunks, (
        r.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), log_w.astype(jnp.float32)))

    if init_state is None:
        init_state = jnp.zeros((b, h, dk, dk), jnp.float32)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # strict lower

    def step(state, inp):
        r_q, k_q, v_q, w_q = inp  # (B, Q, H, K)
        t_cum = jnp.cumsum(w_q, axis=1)  # inclusive (B,Q,H,K)
        t_prev = t_cum - w_q  # exclusive cumsum
        r_dec = r_q * jnp.exp(t_prev)
        k_dec = k_q * jnp.exp(-t_cum)
        scores = jnp.einsum("bqhk,bjhk->bhqj", r_dec, k_dec)
        scores = jnp.where(tri[None, None], scores, 0.0)
        bonus = jnp.einsum("bqhk,hk,bqhk->bhq", r_q, u, k_q)
        y_intra = jnp.einsum("bhqj,bjhk->bqhk", scores, v_q) + \
            bonus.transpose(0, 2, 1)[..., None] * v_q
        y_inter = jnp.einsum("bqhk,bhkv->bqhv", r_dec, state)
        t_last = t_cum[:, -1]  # (B,H,K)
        k_rem = k_q * jnp.exp(t_last[:, None] - t_cum)
        state_new = jnp.exp(t_last)[..., None] * state + jnp.einsum(
            "bqhk,bqhv->bhkv", k_rem, v_q)
        return state_new, y_intra + y_inter

    final_state, ys = jax.lax.scan(step, init_state, (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, dk)[:, :s]
    return y, final_state


def wkv_recurrent(r, k, v, log_w, u, init_state=None):
    """Exact per-token recurrence — test oracle for wkv_chunked."""
    b, s, h, dk = r.shape
    if init_state is None:
        init_state = jnp.zeros((b, h, dk, dk), jnp.float32)

    def step(state, inp):
        r_t, k_t, v_t, w_t = [t.astype(jnp.float32) for t in inp]
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, state + u[..., None] * kv)
        state = jnp.exp(w_t)[..., None] * state + kv
        return state, y

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, log_w))
    state, ys = jax.lax.scan(step, init_state, xs)
    return ys.transpose(1, 0, 2, 3), state


def rwkv6_forward(params: Dict, x: jnp.ndarray, *, head_dim: int = 64,
                  chunk: int = 16, return_state: bool = False):
    """Full-sequence forward. x: (B, S, D)."""
    b, s, d = x.shape
    h = d // head_dim
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, log_w = _projections(params, x, x_prev, head_dim)
    y, state = wkv_chunked(r, k, v, log_w, params["u"], chunk=chunk)
    y = group_norm(y.reshape(b, s, d).astype(x.dtype), params["ln_w"],
                   params["ln_b"], n_groups=h)
    out = (y * g) @ params["wo"].astype(x.dtype)
    if return_state:
        return out, dict(state=state, x_last=x[:, -1:])
    return out


def rwkv6_decode(params: Dict, x: jnp.ndarray, cache: Dict, *,
                 head_dim: int = 64):
    """One-token step. x: (B, 1, D); cache {state, x_last}."""
    b, _, d = x.shape
    h = d // head_dim
    r, k, v, g, log_w = _projections(params, x, cache["x_last"], head_dim)
    r1, k1, v1, w1 = (t[:, 0].astype(jnp.float32) for t in (r, k, v, log_w))
    kv = jnp.einsum("bhk,bhv->bhkv", k1, v1)
    y = jnp.einsum("bhk,bhkv->bhv", r1,
                   cache["state"] + params["u"][..., None] * kv)
    state = jnp.exp(w1)[..., None] * cache["state"] + kv
    y = group_norm(y.reshape(b, 1, d).astype(x.dtype), params["ln_w"],
                   params["ln_b"], n_groups=h)
    out = (y * g) @ params["wo"].astype(x.dtype)
    return out, dict(state=state, x_last=x)


def init_rwkv6_cache(batch: int, d_model: int, head_dim: int = 64,
                     dtype=jnp.float32) -> Dict:
    h = d_model // head_dim
    return dict(
        state=jnp.zeros((batch, h, head_dim, head_dim), jnp.float32),
        x_last=jnp.zeros((batch, 1, d_model), dtype),
    )
