"""Rotary position embeddings."""
from __future__ import annotations

import jax.numpy as jnp


def rope_angles(positions: jnp.ndarray, dim: int,
                theta: float = 10000.0) -> tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for the given absolute positions.

    positions: int array (...,) -> returns cos/sin of shape (..., dim // 2).
    """
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray,
               sin: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs (x[..., ::2], x[..., 1::2]).

    x: (B, S, H, D); cos/sin: (B, S, D//2) or (S, D//2) — broadcast over H.
    """
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    x1 = xf[..., 0::2]
    x2 = xf[..., 1::2]
    if cos.ndim == x.ndim - 2:  # (S, D/2) -> (S, 1, D/2)
        cos = cos[:, None, :]
        sin = sin[:, None, :]
    elif cos.ndim == x.ndim - 1:  # (B, S, D/2) -> (B, S, 1, D/2)
        cos = cos[..., None, :]
        sin = sin[..., None, :]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(dtype)
