"""Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3).

Train/prefill: materialize K/V from the compressed latent and run chunked
flash attention.  Decode: the *absorbed-weight* formulation — scores and
outputs are computed directly in the kv_lora latent space, so the cache per
token is only (kv_lora_rank + qk_rope_dim) scalars instead of
2 * H * head_dim.  This is the production MLA decode path and is what makes
the decode_32k roofline memory term small for the MLA architectures.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.layers.attention import flash_attention, _NEG_INF
from repro.models.layers.norms import rms_norm
from repro.models.layers.rope import apply_rope, rope_angles
from repro.sharding import ctx as shard_ctx


def init_mla_params(key, d_model: int, n_heads: int, *, q_lora_rank: int,
                    kv_lora_rank: int, qk_nope_dim: int, qk_rope_dim: int,
                    v_head_dim: int, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 6)
    qk_dim = qk_nope_dim + qk_rope_dim
    s = d_model ** -0.5
    p = {}
    if q_lora_rank > 0:
        p["wq_a"] = (jax.random.normal(ks[0], (d_model, q_lora_rank)) * s
                     ).astype(dtype)
        p["q_norm"] = jnp.ones((q_lora_rank,), dtype)
        p["wq_b"] = (jax.random.normal(ks[1], (q_lora_rank, n_heads * qk_dim))
                     * q_lora_rank ** -0.5).astype(dtype)
    else:
        p["wq"] = (jax.random.normal(ks[1], (d_model, n_heads * qk_dim)) * s
                   ).astype(dtype)
    p["wkv_a"] = (jax.random.normal(
        ks[2], (d_model, kv_lora_rank + qk_rope_dim)) * s).astype(dtype)
    p["kv_norm"] = jnp.ones((kv_lora_rank,), dtype)
    p["wkv_b"] = (jax.random.normal(
        ks[3], (kv_lora_rank, n_heads * (qk_nope_dim + v_head_dim)))
        * kv_lora_rank ** -0.5).astype(dtype)
    p["wo"] = (jax.random.normal(ks[4], (n_heads * v_head_dim, d_model))
               * (n_heads * v_head_dim) ** -0.5).astype(dtype)
    return p


def _project_q(params: Dict, x: jnp.ndarray, n_heads: int, qk_nope: int,
               qk_rope: int):
    b, s, _ = x.shape
    qk_dim = qk_nope + qk_rope
    if "wq_a" in params:
        ql = x @ params["wq_a"].astype(x.dtype)
        ql = rms_norm(ql, params["q_norm"])
        q = ql @ params["wq_b"].astype(x.dtype)
    else:
        q = x @ params["wq"].astype(x.dtype)
    q = q.reshape(b, s, n_heads, qk_dim)
    return q[..., :qk_nope], q[..., qk_nope:]


def mla_forward(params: Dict, x: jnp.ndarray, *, n_heads: int,
                qk_nope_dim: int, qk_rope_dim: int, v_head_dim: int,
                kv_lora_rank: int, rope_theta: float,
                positions: jnp.ndarray, window: Optional[int] = None,
                return_kv: bool = False, impl: Optional[str] = None):
    """Train / prefill with materialized K/V.

    ``impl`` selects the attention backend (pallas | jnp); the Dv != Dk
    head shape exercises the kernels' MLA path.
    """
    b, s, _ = x.shape
    q_nope, q_rope = _project_q(params, x, n_heads, qk_nope_dim, qk_rope_dim)

    kv_a = x @ params["wkv_a"].astype(x.dtype)
    c_kv = rms_norm(kv_a[..., :kv_lora_rank], params["kv_norm"])
    k_rope = kv_a[..., kv_lora_rank:].reshape(b, s, 1, qk_rope_dim)

    cos, sin = rope_angles(positions, qk_rope_dim, rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)

    kv = (c_kv @ params["wkv_b"].astype(x.dtype)).reshape(
        b, s, n_heads, qk_nope_dim + v_head_dim)
    k_nope, v = kv[..., :qk_nope_dim], kv[..., qk_nope_dim:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, n_heads, qk_rope_dim))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = flash_attention(q, k, v, positions=positions, causal=True,
                          window=window, impl=impl)
    y = out.reshape(b, s, n_heads * v_head_dim) @ params["wo"].astype(x.dtype)
    if return_kv:
        return y, (c_kv, k_rope[:, :, 0, :])  # latent cache
    return y


def mla_decode(params: Dict, x: jnp.ndarray, cache: Dict, *, n_heads: int,
               qk_nope_dim: int, qk_rope_dim: int, v_head_dim: int,
               kv_lora_rank: int, rope_theta: float, qpos: jnp.ndarray,
               window: Optional[int] = None):
    """Absorbed-weight one-token decode against the latent cache.

    cache = {ckv: (B, L, kv_lora), krope: (B, L, qk_rope), pos: (B, L)}.
    """
    b = x.shape[0]
    q_nope, q_rope = _project_q(params, x, n_heads, qk_nope_dim, qk_rope_dim)
    cos, sin = rope_angles(qpos[:, None], qk_rope_dim, rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)[:, 0]  # (B, H, dr)

    kv_a = (x @ params["wkv_a"].astype(x.dtype))[:, 0]
    c_kv_new = rms_norm(kv_a[..., :kv_lora_rank], params["kv_norm"])
    k_rope_new = apply_rope(
        kv_a[..., kv_lora_rank:].reshape(b, 1, 1, qk_rope_dim), cos, sin
    )[:, 0, 0]

    slot = jnp.mod(qpos, cache["ckv"].shape[1])
    bidx = jnp.arange(b)
    c_kv_new = shard_ctx.constrain_latent(
        c_kv_new.astype(cache["ckv"].dtype))  # SSPerf B1
    ckv = cache["ckv"].at[bidx, slot].set(c_kv_new)
    krope = cache["krope"].at[bidx, slot].set(
        k_rope_new.astype(cache["krope"].dtype))
    kpos = cache["pos"].at[bidx, slot].set(qpos)

    wkv_b = params["wkv_b"].astype(x.dtype).reshape(
        kv_lora_rank, n_heads, qk_nope_dim + v_head_dim)
    w_uk = wkv_b[..., :qk_nope_dim]  # (kv_lora, H, dn)
    w_uv = wkv_b[..., qk_nope_dim:]  # (kv_lora, H, dv)

    # absorb: q_lat = q_nope @ W_uk  -> score directly against latent cache
    q_lat = jnp.einsum("bhd,chd->bhc", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = (qk_nope_dim + qk_rope_dim) ** -0.5
    s_lat = jnp.einsum("bhc,blc->bhl", q_lat,
                       ckv.astype(jnp.float32)) * scale
    s_rope = jnp.einsum("bhd,bld->bhl", q_rope.astype(jnp.float32),
                        krope.astype(jnp.float32)) * scale
    s_all = s_lat + s_rope
    valid = (kpos >= 0) & (kpos <= qpos[:, None])
    if window is not None:
        valid &= qpos[:, None] - kpos < window
    s_all = jnp.where(valid[:, None, :], s_all, _NEG_INF)
    p = jax.nn.softmax(s_all, axis=-1)
    out_lat = jnp.einsum("bhl,blc->bhc", p, ckv.astype(jnp.float32))
    out = jnp.einsum("bhc,chd->bhd", out_lat, w_uv.astype(jnp.float32))
    y = out.reshape(b, 1, n_heads * v_head_dim).astype(x.dtype) @ \
        params["wo"].astype(x.dtype)
    return y, dict(ckv=ckv, krope=krope, pos=kpos)


def init_mla_cache(batch: int, length: int, kv_lora_rank: int,
                   qk_rope_dim: int, dtype=jnp.bfloat16) -> Dict:
    return dict(
        ckv=jnp.zeros((batch, length, kv_lora_rank), dtype),
        krope=jnp.zeros((batch, length, qk_rope_dim), dtype),
        pos=jnp.full((batch, length), -1, jnp.int32),
    )
