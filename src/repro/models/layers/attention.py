"""GQA attention public API: projections, RoPE, KV caches, and dispatch.

The actual attention math lives behind a two-backend dispatch
(``repro.kernels.attention_ops``):

* **pallas** — fused TPU flash-attention kernels
  (``kernels/flash_kernel.py`` forward + backward,
  ``kernels/decode_kernel.py`` single-token bf16/int8 decode); default on
  TPU backends, interpret-mode elsewhere.
* **jnp** — the chunked online-softmax reference with a custom VJP
  (``kernels/attention_ref.py``); default off-TPU and the oracle for the
  kernel parity tests.

Select with the ``impl=`` keyword, the ``REPRO_ATTN_IMPL`` env var
(``pallas`` | ``jnp``), or leave unset for the backend default.  Both
backends share the operand contract: operands stay in model dtype (bf16),
every dot accumulates in fp32, the backward recomputes per-block
probabilities from the saved (row-max, row-sum) so no (Sq x Skv) tensor
is ever materialized, and masking uses RUNTIME position vectors (see
``attention_ref._block_mask`` for why trace-time iota is forbidden).

Supports: causal masking, sliding windows (the sub-quadratic variant used
for long_500k on full-attention architectures), GQA head grouping,
Dv != Dk (MLA), decode against ring-buffer KV caches (bf16 and
int8-quantized with fused scales).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels import attention_ops
from repro.kernels.attention_ref import (_FAR, _NEG_INF,
                                         decode_attention_paged_q8_ref,
                                         decode_attention_paged_ref,
                                         decode_attention_q8_ref,
                                         decode_attention_ref,
                                         flash_reference)
from repro.models.layers.rope import apply_rope, rope_angles
from repro.sharding import ctx as shard_ctx

__all__ = [
    "init_attention_params", "flash_attention", "decode_attention",
    "decode_attention_q8", "decode_attention_paged",
    "decode_attention_paged_q8", "gqa_forward", "gqa_decode",
    "gqa_decode_paged", "init_kv_cache", "init_paged_kv_pool",
    "quantize_kv_token", "_NEG_INF",
]


def init_attention_params(key, d_model: int, n_heads: int, n_kv_heads: int,
                          head_dim: int, dtype=jnp.float32) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = d_model ** -0.5
    s_out = (n_heads * head_dim) ** -0.5
    return dict(
        wq=(jax.random.normal(k1, (d_model, n_heads * head_dim)) * s_in
            ).astype(dtype),
        wk=(jax.random.normal(k2, (d_model, n_kv_heads * head_dim)) * s_in
            ).astype(dtype),
        wv=(jax.random.normal(k3, (d_model, n_kv_heads * head_dim)) * s_in
            ).astype(dtype),
        wo=(jax.random.normal(k4, (n_heads * head_dim, d_model)) * s_out
            ).astype(dtype),
    )


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    positions: Optional[jnp.ndarray] = None,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, kv_valid_len: Optional[int] = None,
                    q_chunk: int = 512, kv_chunk: int = 512,
                    impl: Optional[str] = None) -> jnp.ndarray:
    """Online-softmax causal attention.

    q: (B, Sq, H, D); k: (B, Skv, KH, D); v: (B, Skv, KH, Dv) with
    H % KH == 0 (Dv may differ from D, as in MLA).
    ``positions``: (Sq,) runtime token positions (defaults to arange —
    pass the model's position-id input so XLA cannot constant-fold masks).
    ``impl``: attention backend override (``pallas`` | ``jnp``).
    Returns (B, Sq, H, Dv) in q.dtype.
    """
    assert causal and q_offset == 0, "flash path is causal/offset-0 only"
    b, sq, h, d = q.shape
    skv = k.shape[1]
    scale = d ** -0.5
    if positions is None:
        positions = jnp.arange(sq, dtype=jnp.int32)
    positions = positions.astype(jnp.int32)
    if kv_valid_len is None:
        kv_valid_len = skv
    chunk = min(q_chunk, kv_chunk, sq, skv)
    pad_q = (-sq) % chunk
    pad_kv = (-skv) % chunk
    qs = jnp.pad(q * jnp.asarray(scale, q.dtype),
                 ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp_arr = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    qpos = jnp.pad(positions, (0, pad_q), constant_values=-_FAR)
    # key positions: match q positions where they exist; anything beyond
    # (longer KV, padding, kv_valid_len cutoff) is marked unreachable.
    kpos = jnp.full((skv + pad_kv,), _FAR, jnp.int32)
    kpos = kpos.at[:min(sq, skv)].set(positions[:min(sq, skv)])
    kpos = jnp.where(jnp.arange(kpos.shape[0]) < kv_valid_len, kpos, _FAR)
    if attention_ops.resolve_impl(impl) == "pallas" \
            and attention_ops.compiled_shape_ok(chunk):
        out = attention_ops.flash_pallas(qs, kp_arr, vp, qpos, kpos, window,
                                         chunk)
    else:
        out = flash_reference(qs, kp_arr, vp, qpos, kpos, window, chunk)
    # the q * scale pre-multiplication is in-graph, so its chain rule is
    # handled by the surrounding autodiff.
    return out[:, :sq]


def _grouped_query(q: jnp.ndarray, kh: int) -> jnp.ndarray:
    """(B, 1, H, D) -> pre-scaled, shard-constrained (B, KH, G, D)."""
    b, _, h, d = q.shape
    qf = q.reshape(b, kh, h // kh, d) * jnp.asarray(d ** -0.5, q.dtype)
    return shard_ctx.constrain(qf, "decode_q")  # SSPerf B2


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, kpos: jnp.ndarray,
                     qpos: jnp.ndarray, *,
                     window: Optional[int] = None,
                     impl: Optional[str] = None) -> jnp.ndarray:
    """Single-token attention against a (ring-buffer) KV cache.

    q: (B, 1, H, D); caches: (B, L, KH, D/Dv); kpos: (B, L) absolute
    position of each cache slot (-1 for empty); qpos: (B,).
    """
    b, _, h, _ = q.shape
    qf = _grouped_query(q, k_cache.shape[2])
    if attention_ops.resolve_impl(impl) == "pallas":
        out = attention_ops.decode_pallas(qf, k_cache, v_cache, kpos, qpos,
                                          window=window)
    else:
        out = decode_attention_ref(qf, k_cache, v_cache, kpos, qpos,
                                   window=window)
    return out.reshape(b, 1, h, v_cache.shape[-1]).astype(q.dtype)


def decode_attention_q8(q, k_codes, v_codes, k_scale, v_scale, kpos, qpos, *,
                        window=None, impl: Optional[str] = None):
    """Single-token attention against an int8 cache; scales fold into the
    dots: s = (q . codes) * k_scale;  out = (p * v_scale) . codes."""
    b, _, h, d = q.shape
    qf = _grouped_query(q, k_codes.shape[2])
    if attention_ops.resolve_impl(impl) == "pallas":
        out = attention_ops.decode_q8_pallas(qf, k_codes, v_codes, k_scale,
                                             v_scale, kpos, qpos,
                                             window=window)
    else:
        out = decode_attention_q8_ref(qf, k_codes, v_codes, k_scale, v_scale,
                                      kpos, qpos, window=window)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def decode_attention_paged(q, k_pool, v_pool, pos_pool, page_table, qpos, *,
                           window: Optional[int] = None,
                           impl: Optional[str] = None) -> jnp.ndarray:
    """Single-token attention against a paged KV pool (serving engine).

    q: (S, 1, H, D) one row per scheduler slot; pools: (P, pg, KH, D/Dv)
    with pos_pool (P, pg) absolute positions (-1 empty); page_table:
    (S, npp) physical page per logical page (-1 unallocated); qpos: (S,)
    with -1 marking inactive slots (their output is 0).
    """
    s, _, h, _ = q.shape
    qf = _grouped_query(q, k_pool.shape[2])
    if attention_ops.resolve_impl(impl) == "pallas":
        out = attention_ops.decode_paged_pallas(
            qf, k_pool, v_pool, pos_pool, page_table, qpos, window=window)
    else:
        out = decode_attention_paged_ref(
            qf, k_pool, v_pool, pos_pool, page_table, qpos, window=window)
    return out.reshape(s, 1, h, v_pool.shape[-1]).astype(q.dtype)


def decode_attention_paged_q8(q, k_pool, v_pool, k_scale_pool, v_scale_pool,
                              pos_pool, page_table, qpos, *,
                              window: Optional[int] = None,
                              impl: Optional[str] = None) -> jnp.ndarray:
    """Paged int8-pool decode; scale pools (P, pg, KH) fp16 fold into the
    dots exactly as in ``decode_attention_q8``."""
    s, _, h, d = q.shape
    qf = _grouped_query(q, k_pool.shape[2])
    if attention_ops.resolve_impl(impl) == "pallas":
        out = attention_ops.decode_paged_q8_pallas(
            qf, k_pool, v_pool, k_scale_pool, v_scale_pool, pos_pool,
            page_table, qpos, window=window)
    else:
        out = decode_attention_paged_q8_ref(
            qf, k_pool, v_pool, k_scale_pool, v_scale_pool, pos_pool,
            page_table, qpos, window=window)
    return out.reshape(s, 1, h, d).astype(q.dtype)


def gqa_forward(params: Dict, x: jnp.ndarray, *, n_heads: int,
                n_kv_heads: int, head_dim: int, rope_theta: float,
                positions: jnp.ndarray, causal: bool = True,
                window: Optional[int] = None,
                return_kv: bool = False):
    """Full-sequence attention (train / prefill)."""
    b, s, _ = x.shape
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, n_heads, head_dim)
    k = (x @ params["wk"].astype(x.dtype)).reshape(b, s, n_kv_heads, head_dim)
    v = (x @ params["wv"].astype(x.dtype)).reshape(b, s, n_kv_heads, head_dim)
    cos, sin = rope_angles(positions, head_dim, rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    out = flash_attention(q, k, v, positions=positions, causal=causal,
                          window=window)
    y = out.reshape(b, s, n_heads * head_dim) @ params["wo"].astype(x.dtype)
    if return_kv:
        return y, (k, v)
    return y


def gqa_decode(params: Dict, x: jnp.ndarray, cache: Dict, *, n_heads: int,
               n_kv_heads: int, head_dim: int, rope_theta: float,
               qpos: jnp.ndarray, window: Optional[int] = None):
    """One-token decode. ``cache`` = {k, v, pos} ring buffer; returns
    (y, new_cache)."""
    b, s1, _ = x.shape
    assert s1 == 1
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, 1, n_heads, head_dim)
    k = (x @ params["wk"].astype(x.dtype)).reshape(b, 1, n_kv_heads, head_dim)
    v = (x @ params["wv"].astype(x.dtype)).reshape(b, 1, n_kv_heads, head_dim)
    cos, sin = rope_angles(qpos[:, None], head_dim, rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    slot = jnp.mod(qpos, cache["k"].shape[1])  # ring buffer
    bidx = jnp.arange(b)
    kpos = cache["pos"].at[bidx, slot].set(qpos)
    if "k_scale" in cache:  # int8-quantized cache (SSPerf D5)
        kc, ks = quantize_kv_token(k[:, 0])
        vc, vs = quantize_kv_token(v[:, 0])
        kc = shard_ctx.constrain_kv(kc)
        vc = shard_ctx.constrain_kv(vc)
        k_cache = cache["k"].at[bidx, slot].set(kc)
        v_cache = cache["v"].at[bidx, slot].set(vc)
        k_scale = cache["k_scale"].at[bidx, slot].set(ks)
        v_scale = cache["v_scale"].at[bidx, slot].set(vs)
        out = decode_attention_q8(q, k_cache, v_cache, k_scale, v_scale,
                                  kpos, qpos, window=window)
        y = out.reshape(b, 1, n_heads * head_dim) @ \
            params["wo"].astype(x.dtype)
        return y, dict(k=k_cache, v=v_cache, k_scale=k_scale,
                       v_scale=v_scale, pos=kpos)
    # align the new token with the cache layout BEFORE the scatter — else
    # GSPMD reshards via a full cache rematerialization (SSPerf B1)
    k_new = shard_ctx.constrain_kv(k[:, 0].astype(cache["k"].dtype))
    v_new = shard_ctx.constrain_kv(v[:, 0].astype(cache["v"].dtype))
    k_cache = cache["k"].at[bidx, slot].set(k_new)
    v_cache = cache["v"].at[bidx, slot].set(v_new)
    out = decode_attention(q, k_cache, v_cache, kpos, qpos, window=window)
    y = out.reshape(b, 1, n_heads * head_dim) @ params["wo"].astype(x.dtype)
    return y, dict(k=k_cache, v=v_cache, pos=kpos)


def gqa_decode_paged(params: Dict, x: jnp.ndarray, cache: Dict, *,
                     n_heads: int, n_kv_heads: int, head_dim: int,
                     rope_theta: float, qpos: jnp.ndarray,
                     page_table: jnp.ndarray,
                     window: Optional[int] = None):
    """One decode tick against a paged KV pool.

    ``cache`` = {k, v, pos[, k_scale, v_scale]} pools of shape
    (P, pg, ...); ``page_table`` (S, npp) maps each slot's logical pages
    to physical ones; ``qpos`` (S,) is the position of the token being
    decoded, -1 for inactive slots.  Inactive (or unallocated) writes are
    routed to the reserved trash page 0 with pos = -1, so they are never
    attended to.  Returns (y, new_cache); the page table is host-owned
    and never mutated here.
    """
    s, s1, _ = x.shape
    assert s1 == 1
    pg = cache["k"].shape[1]
    q = (x @ params["wq"].astype(x.dtype)).reshape(s, 1, n_heads, head_dim)
    k = (x @ params["wk"].astype(x.dtype)).reshape(s, 1, n_kv_heads, head_dim)
    v = (x @ params["wv"].astype(x.dtype)).reshape(s, 1, n_kv_heads, head_dim)
    cos, sin = rope_angles(qpos[:, None], head_dim, rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    active = qpos >= 0
    qp = jnp.maximum(qpos, 0)
    phys = page_table[jnp.arange(s), qp // pg]
    phys = jnp.where(active & (phys >= 0), phys, 0)
    off = qp % pg
    pos_pool = cache["pos"].at[phys, off].set(jnp.where(active, qpos, -1))
    if "k_scale" in cache:  # int8-quantized pool
        kc, ks = quantize_kv_token(k[:, 0])
        vc, vs = quantize_kv_token(v[:, 0])
        k_pool = cache["k"].at[phys, off].set(kc)
        v_pool = cache["v"].at[phys, off].set(vc)
        k_scale = cache["k_scale"].at[phys, off].set(ks)
        v_scale = cache["v_scale"].at[phys, off].set(vs)
        out = decode_attention_paged_q8(q, k_pool, v_pool, k_scale, v_scale,
                                        pos_pool, page_table, qpos,
                                        window=window)
        y = out.reshape(s, 1, n_heads * head_dim) @ \
            params["wo"].astype(x.dtype)
        return y, dict(k=k_pool, v=v_pool, k_scale=k_scale,
                       v_scale=v_scale, pos=pos_pool)
    k_pool = cache["k"].at[phys, off].set(k[:, 0].astype(cache["k"].dtype))
    v_pool = cache["v"].at[phys, off].set(v[:, 0].astype(cache["v"].dtype))
    out = decode_attention_paged(q, k_pool, v_pool, pos_pool, page_table,
                                 qpos, window=window)
    y = out.reshape(s, 1, n_heads * head_dim) @ params["wo"].astype(x.dtype)
    return y, dict(k=k_pool, v=v_pool, pos=pos_pool)


def init_kv_cache(batch: int, length: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16, bits: int = 16) -> Dict:
    """bits=8: int8-quantized cache (BEYOND-PAPER: the paper's activation
    quantization applied to the KV cache — the decode-roofline's dominant
    memory; EXPERIMENTS.md SSPerf D5).  Codes + per-(token, head) fp16
    absmax scales; the scales fold into the attention dots, so no
    dequantized copy is ever stored."""
    if bits == 8:
        return dict(
            k=jnp.zeros((batch, length, n_kv_heads, head_dim), jnp.int8),
            v=jnp.zeros((batch, length, n_kv_heads, head_dim), jnp.int8),
            k_scale=jnp.zeros((batch, length, n_kv_heads), jnp.float16),
            v_scale=jnp.zeros((batch, length, n_kv_heads), jnp.float16),
            pos=jnp.full((batch, length), -1, jnp.int32),
        )
    return dict(
        k=jnp.zeros((batch, length, n_kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, length, n_kv_heads, head_dim), dtype),
        pos=jnp.full((batch, length), -1, jnp.int32),
    )


def init_paged_kv_pool(n_pages: int, page_size: int, n_kv_heads: int,
                       head_dim: int, dtype=jnp.bfloat16,
                       bits: int = 16) -> Dict:
    """Paged twin of ``init_kv_cache``: (P, pg, ...) pools shared by every
    request, indexed through per-request page tables.  Physical page 0 is
    reserved as the trash page (inactive-slot writes land there and its
    pos stays -1), so allocators must hand out pages 1..P-1 only."""
    if bits == 8:
        return dict(
            k=jnp.zeros((n_pages, page_size, n_kv_heads, head_dim),
                        jnp.int8),
            v=jnp.zeros((n_pages, page_size, n_kv_heads, head_dim),
                        jnp.int8),
            k_scale=jnp.zeros((n_pages, page_size, n_kv_heads),
                              jnp.float16),
            v_scale=jnp.zeros((n_pages, page_size, n_kv_heads),
                              jnp.float16),
            pos=jnp.full((n_pages, page_size), -1, jnp.int32),
        )
    return dict(
        k=jnp.zeros((n_pages, page_size, n_kv_heads, head_dim), dtype),
        v=jnp.zeros((n_pages, page_size, n_kv_heads, head_dim), dtype),
        pos=jnp.full((n_pages, page_size), -1, jnp.int32),
    )


def quantize_kv_token(x: jnp.ndarray):
    """(..., KH, hd) -> (int8 codes, fp16 absmax scale over hd)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-8
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.float16)
