"""GQA attention with memory-safe chunked (flash-style) computation.

Pure-jnp online-softmax attention with a **custom VJP**: the forward saves
only (out, row-max, row-sum); the backward recomputes per-(q-chunk,
kv-chunk) probabilities instead of storing them — without this, the
lax.scan backward would checkpoint an (Sq x Skv) probability tensor per
layer and the train_4k shapes could never fit HBM (measured: 255 GiB/dev
-> 12 GiB/dev on llama3.2-3b; EXPERIMENTS.md §Perf).

Operands stay in model dtype (bf16); every dot accumulates in fp32 via
``preferred_element_type``.  Chunk-level causal/window skipping avoids
issuing fully-masked blocks (splash-attention style).

Supports: causal masking, sliding windows (the sub-quadratic variant used
for long_500k on full-attention architectures), GQA head grouping,
Dv != Dk (MLA), decode against ring-buffer KV caches.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers.rope import apply_rope, rope_angles
from repro.sharding import ctx as shard_ctx

_NEG_INF = -1e30


def init_attention_params(key, d_model: int, n_heads: int, n_kv_heads: int,
                          head_dim: int, dtype=jnp.float32) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = d_model ** -0.5
    s_out = (n_heads * head_dim) ** -0.5
    return dict(
        wq=(jax.random.normal(k1, (d_model, n_heads * head_dim)) * s_in
            ).astype(dtype),
        wk=(jax.random.normal(k2, (d_model, n_kv_heads * head_dim)) * s_in
            ).astype(dtype),
        wv=(jax.random.normal(k3, (d_model, n_kv_heads * head_dim)) * s_in
            ).astype(dtype),
        wo=(jax.random.normal(k4, (n_heads * head_dim, d_model)) * s_out
            ).astype(dtype),
    )


_FAR = jnp.int32(2 ** 30)


def _block_mask(qpos, kpos, window):
    """(cq, ckv) causal/window mask from RUNTIME position vectors.

    Positions must be runtime data (not trace-time iota): if XLA can
    constant-fold the masks it widens them into (nq x nkv x ...) stacked
    buffers inside the scan loops — measured 26 GiB/device on train_4k
    before this fix (EXPERIMENTS.md SSPerf).
    """
    m = kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= qpos[:, None] - kpos[None, :] < window
    return m


# ---------------------------------------------------------------------------
# forward implementation (shared by primal and VJP fwd)
# ---------------------------------------------------------------------------

def _flash_fwd_impl(qs, k, v, qpos, kpos, *, window, chunk):
    """qs is the pre-scaled query; qpos/kpos are runtime position vectors
    (padded with +/-2^30 sentinels).  Returns (out fp32, m, l) chunked:
    out (nq, B, KH, G, cq, Dv); m, l (nq, B, KH, G, cq)."""
    b, sq, h, d = qs.shape
    skv, kh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // kh
    nq = sq // chunk
    nkv = skv // chunk

    qc_all = qs.reshape(b, nq, chunk, kh, g, d).transpose(1, 0, 3, 4, 2, 5)
    ks = k.reshape(b, nkv, chunk, kh, d).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nkv, chunk, kh, dv).transpose(1, 0, 2, 3, 4)
    qp_all = qpos.reshape(nq, chunk)
    kp_all = kpos.reshape(nkv, chunk)

    def q_body(qc, qp):  # qc: (B, KH, G, cq, D); qp: (cq,)
        def kv_body(carry, inp):
            m_run, l_run, acc = carry
            kc, vc, kp = inp

            def compute(c):
                m_run, l_run, acc = c
                s = jnp.einsum("bkgqd,bskd->bkgqs", qc, kc,
                               preferred_element_type=jnp.float32)
                mask = _block_mask(qp, kp, window)
                s = jnp.where(mask[None, None, None], s, _NEG_INF)
                m_new = jnp.maximum(m_run, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m_run - m_new)
                l_new = l_run * corr + p.sum(axis=-1)
                pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vc.dtype), vc,
                                preferred_element_type=jnp.float32)
                return m_new, l_new, acc * corr[..., None] + pv

            visible = kp.min() <= qp.max()
            if window is not None:
                visible &= kp.max() > qp.min() - window
            carry = jax.lax.cond(visible, compute, lambda c: c,
                                 (m_run, l_run, acc))
            return carry, None

        m0 = jnp.full((b, kh, g, chunk), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, chunk), jnp.float32)
        a0 = jnp.zeros((b, kh, g, chunk, dv), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0),
                                          (ks, vs, kp_all))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return out, m_f, l_f

    def q_scan(_, inp):
        qc, qp = inp
        return 0, q_body(qc, qp)

    _, (outs, ms, ls) = jax.lax.scan(q_scan, 0, (qc_all, qp_all))
    return outs, ms, ls


def _unchunk_out(outs, b, sq, h, dv, dtype):
    nq = outs.shape[0]
    q_chunk = outs.shape[4]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * q_chunk, h, dv)
    return out[:, :sq].astype(dtype)


# ---------------------------------------------------------------------------
# custom-VJP flash attention
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _flash(q, k, v, qpos, kpos, window, chunk):
    outs, _, _ = _flash_fwd_impl(q, k, v, qpos, kpos, window=window,
                                 chunk=chunk)
    b, sq, h, _ = q.shape
    return _unchunk_out(outs, b, sq, h, v.shape[-1], q.dtype)


def _flash_vjp_fwd(q, k, v, qpos, kpos, window, chunk):
    outs, ms, ls = _flash_fwd_impl(q, k, v, qpos, kpos, window=window,
                                   chunk=chunk)
    b, sq, h, _ = q.shape
    out = _unchunk_out(outs, b, sq, h, v.shape[-1], q.dtype)
    return out, (q, k, v, qpos, kpos, out, ms, ls)


def _flash_vjp_bwd(window, chunk, res, gout):
    """Flash backward: recompute per-block probabilities from saved (m, l);
    never stores an (Sq x Skv) tensor."""
    q, k, v, qpos, kpos, out, ms, ls = res
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // kh
    nq = sq // chunk
    nkv = skv // chunk

    delta_all = jnp.einsum("bshd,bshd->bsh", gout.astype(jnp.float32),
                           out.astype(jnp.float32))
    delta_all = delta_all.reshape(b, nq, chunk, kh, g).transpose(
        1, 0, 3, 4, 2)
    go = gout.reshape(b, nq, chunk, kh, g, dv).transpose(1, 0, 3, 4, 2, 5)
    qc_all = q.reshape(b, nq, chunk, kh, g, d).transpose(1, 0, 3, 4, 2, 5)
    ks = k.reshape(b, nkv, chunk, kh, d).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nkv, chunk, kh, dv).transpose(1, 0, 2, 3, 4)
    qp_all = qpos.reshape(nq, chunk)
    kp_all = kpos.reshape(nkv, chunk)

    def q_body(carry, inp):
        dk_acc, dv_acc, kj0 = carry  # (nkv, B, ckv, KH, d/dv) fp32
        qc, qp, m_q, l_q, go_q, delta_q = inp
        linv = 1.0 / jnp.maximum(l_q, 1e-30)

        def kv_body(c, inp2):
            kj, dq_c, dk_acc, dv_acc = c
            kc, vc, kp = inp2

            def compute(c):
                dq_c, dk_acc, dv_acc = c
                s = jnp.einsum("bkgqd,bskd->bkgqs", qc, kc,
                               preferred_element_type=jnp.float32)
                mask = _block_mask(qp, kp, window)
                s = jnp.where(mask[None, None, None], s, _NEG_INF)
                p = jnp.exp(s - m_q[..., None]) * linv[..., None]
                dv_blk = jnp.einsum("bkgqs,bkgqd->bskd",
                                    p.astype(go_q.dtype), go_q,
                                    preferred_element_type=jnp.float32)
                dp = jnp.einsum("bkgqd,bskd->bkgqs", go_q, vc,
                                preferred_element_type=jnp.float32)
                ds = p * (dp - delta_q[..., None])
                dq_blk = jnp.einsum("bkgqs,bskd->bkgqd",
                                    ds.astype(kc.dtype), kc,
                                    preferred_element_type=jnp.float32)
                dk_blk = jnp.einsum("bkgqs,bkgqd->bskd",
                                    ds.astype(qc.dtype), qc,
                                    preferred_element_type=jnp.float32)
                return (dq_c + dq_blk,
                        dk_acc.at[kj].add(dk_blk),
                        dv_acc.at[kj].add(dv_blk))

            visible = kp.min() <= qp.max()
            if window is not None:
                visible &= kp.max() > qp.min() - window
            dq_c, dk_acc, dv_acc = jax.lax.cond(
                visible, compute, lambda c: c, (dq_c, dk_acc, dv_acc))
            return (kj + 1, dq_c, dk_acc, dv_acc), None

        dq0 = jnp.zeros((b, kh, g, chunk, d), jnp.float32)
        (_, dq_c, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_body, (jnp.zeros((), jnp.int32), dq0, dk_acc, dv_acc),
            (ks, vs, kp_all))
        return (dk_acc, dv_acc, kj0), dq_c

    dk0 = jnp.zeros((nkv, b, chunk, kh, d), jnp.float32)
    dv0 = jnp.zeros((nkv, b, chunk, kh, dv), jnp.float32)
    (dk_acc, dv_acc, _), dqs = jax.lax.scan(
        q_body, (dk0, dv0, 0), (qc_all, qp_all, ms, ls, go, delta_all))

    dq = dqs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, d).astype(q.dtype)
    dk = dk_acc.transpose(1, 0, 2, 3, 4).reshape(b, skv, kh, d).astype(
        k.dtype)
    dvv = dv_acc.transpose(1, 0, 2, 3, 4).reshape(b, skv, kh, dv).astype(
        v.dtype)
    return dq, dk, dvv, jnp.zeros_like(qpos), jnp.zeros_like(kpos)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    positions: Optional[jnp.ndarray] = None,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, kv_valid_len: Optional[int] = None,
                    q_chunk: int = 512, kv_chunk: int = 512) -> jnp.ndarray:
    """Online-softmax causal attention.

    q: (B, Sq, H, D); k: (B, Skv, KH, D); v: (B, Skv, KH, Dv) with
    H % KH == 0 (Dv may differ from D, as in MLA).
    ``positions``: (Sq,) runtime token positions (defaults to arange —
    pass the model's position-id input so XLA cannot constant-fold masks).
    Returns (B, Sq, H, Dv) in q.dtype.
    """
    assert causal and q_offset == 0, "flash path is causal/offset-0 only"
    b, sq, h, d = q.shape
    skv = k.shape[1]
    scale = d ** -0.5
    if positions is None:
        positions = jnp.arange(sq, dtype=jnp.int32)
    positions = positions.astype(jnp.int32)
    if kv_valid_len is None:
        kv_valid_len = skv
    chunk = min(q_chunk, kv_chunk, sq, skv)
    pad_q = (-sq) % chunk
    pad_kv = (-skv) % chunk
    qs = jnp.pad(q * jnp.asarray(scale, q.dtype),
                 ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp_arr = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    qpos = jnp.pad(positions, (0, pad_q), constant_values=-_FAR)
    # key positions: match q positions where they exist; anything beyond
    # (longer KV, padding, kv_valid_len cutoff) is marked unreachable.
    kpos = jnp.full((skv + pad_kv,), _FAR, jnp.int32)
    kpos = kpos.at[:min(sq, skv)].set(positions[:min(sq, skv)])
    kpos = jnp.where(jnp.arange(kpos.shape[0]) < kv_valid_len, kpos, _FAR)
    out = _flash(qs, kp_arr, vp, qpos, kpos, window, chunk)
    # the q * scale pre-multiplication is in-graph, so its chain rule is
    # handled by the surrounding autodiff.
    return out[:, :sq]


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, kpos: jnp.ndarray,
                     qpos: jnp.ndarray, *,
                     window: Optional[int] = None) -> jnp.ndarray:
    """Single-token attention against a (ring-buffer) KV cache.

    q: (B, 1, H, D); caches: (B, L, KH, D/Dv); kpos: (B, L) absolute
    position of each cache slot (-1 for empty); qpos: (B,).
    """
    b, _, h, d = q.shape
    kh = k_cache.shape[2]
    g = h // kh
    scale = d ** -0.5
    qf = q.reshape(b, kh, g, d) * jnp.asarray(scale, q.dtype)
    qf = shard_ctx.constrain(qf, "decode_q")  # SSPerf B2
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache,
                   preferred_element_type=jnp.float32)
    valid = kpos >= 0
    valid &= kpos <= qpos[:, None]
    if window is not None:
        valid &= qpos[:, None] - kpos < window
    s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, v_cache.shape[-1]).astype(q.dtype)


def gqa_forward(params: Dict, x: jnp.ndarray, *, n_heads: int,
                n_kv_heads: int, head_dim: int, rope_theta: float,
                positions: jnp.ndarray, causal: bool = True,
                window: Optional[int] = None,
                return_kv: bool = False):
    """Full-sequence attention (train / prefill)."""
    b, s, _ = x.shape
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, n_heads, head_dim)
    k = (x @ params["wk"].astype(x.dtype)).reshape(b, s, n_kv_heads, head_dim)
    v = (x @ params["wv"].astype(x.dtype)).reshape(b, s, n_kv_heads, head_dim)
    cos, sin = rope_angles(positions, head_dim, rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    out = flash_attention(q, k, v, positions=positions, causal=causal,
                          window=window)
    y = out.reshape(b, s, n_heads * head_dim) @ params["wo"].astype(x.dtype)
    if return_kv:
        return y, (k, v)
    return y


def gqa_decode(params: Dict, x: jnp.ndarray, cache: Dict, *, n_heads: int,
               n_kv_heads: int, head_dim: int, rope_theta: float,
               qpos: jnp.ndarray, window: Optional[int] = None):
    """One-token decode. ``cache`` = {k, v, pos} ring buffer; returns
    (y, new_cache)."""
    b, s1, _ = x.shape
    assert s1 == 1
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, 1, n_heads, head_dim)
    k = (x @ params["wk"].astype(x.dtype)).reshape(b, 1, n_kv_heads, head_dim)
    v = (x @ params["wv"].astype(x.dtype)).reshape(b, 1, n_kv_heads, head_dim)
    cos, sin = rope_angles(qpos[:, None], head_dim, rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    slot = jnp.mod(qpos, cache["k"].shape[1])  # ring buffer
    bidx = jnp.arange(b)
    kpos = cache["pos"].at[bidx, slot].set(qpos)
    if "k_scale" in cache:  # int8-quantized cache (SSPerf D5)
        kc, ks = quantize_kv_token(k[:, 0])
        vc, vs = quantize_kv_token(v[:, 0])
        kc = shard_ctx.constrain_kv(kc)
        vc = shard_ctx.constrain_kv(vc)
        k_cache = cache["k"].at[bidx, slot].set(kc)
        v_cache = cache["v"].at[bidx, slot].set(vc)
        k_scale = cache["k_scale"].at[bidx, slot].set(ks)
        v_scale = cache["v_scale"].at[bidx, slot].set(vs)
        out = decode_attention_q8(q, k_cache, v_cache, k_scale, v_scale,
                                  kpos, qpos, window=window)
        y = out.reshape(b, 1, n_heads * head_dim) @ \
            params["wo"].astype(x.dtype)
        return y, dict(k=k_cache, v=v_cache, k_scale=k_scale,
                       v_scale=v_scale, pos=kpos)
    # align the new token with the cache layout BEFORE the scatter — else
    # GSPMD reshards via a full cache rematerialization (SSPerf B1)
    k_new = shard_ctx.constrain_kv(k[:, 0].astype(cache["k"].dtype))
    v_new = shard_ctx.constrain_kv(v[:, 0].astype(cache["v"].dtype))
    k_cache = cache["k"].at[bidx, slot].set(k_new)
    v_cache = cache["v"].at[bidx, slot].set(v_new)
    out = decode_attention(q, k_cache, v_cache, kpos, qpos, window=window)
    y = out.reshape(b, 1, n_heads * head_dim) @ params["wo"].astype(x.dtype)
    return y, dict(k=k_cache, v=v_cache, pos=kpos)


def init_kv_cache(batch: int, length: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16, bits: int = 16) -> Dict:
    """bits=8: int8-quantized cache (BEYOND-PAPER: the paper's activation
    quantization applied to the KV cache — the decode-roofline's dominant
    memory; EXPERIMENTS.md SSPerf D5).  Codes + per-(token, head) fp16
    absmax scales; the scales fold into the attention dots, so no
    dequantized copy is ever stored."""
    if bits == 8:
        return dict(
            k=jnp.zeros((batch, length, n_kv_heads, head_dim), jnp.int8),
            v=jnp.zeros((batch, length, n_kv_heads, head_dim), jnp.int8),
            k_scale=jnp.zeros((batch, length, n_kv_heads), jnp.float16),
            v_scale=jnp.zeros((batch, length, n_kv_heads), jnp.float16),
            pos=jnp.full((batch, length), -1, jnp.int32),
        )
    return dict(
        k=jnp.zeros((batch, length, n_kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, length, n_kv_heads, head_dim), dtype),
        pos=jnp.full((batch, length), -1, jnp.int32),
    )


def quantize_kv_token(x: jnp.ndarray):
    """(..., KH, hd) -> (int8 codes, fp16 absmax scale over hd)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-8
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.float16)


def decode_attention_q8(q, k_codes, v_codes, k_scale, v_scale, kpos, qpos, *,
                        window=None):
    """Single-token attention against an int8 cache; scales fold into the
    dots: s = (q . codes) * k_scale;  out = (p * v_scale) . codes."""
    b, _, h, d = q.shape
    kh = k_codes.shape[2]
    g = h // kh
    scale = d ** -0.5
    qf = q.reshape(b, kh, g, d) * jnp.asarray(scale, q.dtype)
    qf = shard_ctx.constrain(qf, "decode_q")
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k_codes.astype(q.dtype),
                   preferred_element_type=jnp.float32)
    s = s * k_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
    valid = (kpos >= 0) & (kpos <= qpos[:, None])
    if window is not None:
        valid &= qpos[:, None] - kpos < window
    s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    pv = p * v_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
    out = jnp.einsum("bkgs,bskd->bkgd", pv.astype(q.dtype),
                     v_codes.astype(q.dtype),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)
