"""Feed-forward layers: SwiGLU (llama family) and GELU MLP (connector)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def init_swiglu_params(key, d_model: int, d_ff: int,
                       dtype=jnp.float32) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    return dict(
        w_gate=(jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        w_up=(jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
        w_down=(jax.random.normal(k3, (d_ff, d_model)) * s_out).astype(dtype),
    )


def swiglu_forward(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    gate = jax.nn.silu(x @ params["w_gate"].astype(dt))
    up = x @ params["w_up"].astype(dt)
    return (gate * up) @ params["w_down"].astype(dt)


def init_mlp_params(key, d_in: int, d_hidden: int, d_out: int,
                    dtype=jnp.float32) -> Dict:
    """Two-layer GELU MLP — the paper's vision->language connector."""
    k1, k2 = jax.random.split(key)
    return dict(
        w1=(jax.random.normal(k1, (d_in, d_hidden)) * d_in ** -0.5
            ).astype(dtype),
        b1=jnp.zeros((d_hidden,), dtype),
        w2=(jax.random.normal(k2, (d_hidden, d_out)) * d_hidden ** -0.5
            ).astype(dtype),
        b2=jnp.zeros((d_out,), dtype),
    )


def mlp_forward(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    h = jax.nn.gelu(x @ params["w1"].astype(dt) + params["b1"].astype(dt))
    return h @ params["w2"].astype(dt) + params["b2"].astype(dt)
