from repro.models import transformer

__all__ = ["transformer"]
