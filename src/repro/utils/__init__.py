from repro.utils.barrier import grad_safe_barrier
from repro.utils.tree import tree_bytes, tree_count, cast_tree, ste

__all__ = ["grad_safe_barrier", "tree_bytes", "tree_count", "cast_tree",
           "ste"]
