from repro.utils.barrier import grad_safe_barrier
from repro.utils.tree import (cast_tree, is_weight_site, ste, tree_bytes,
                              tree_count, weight_sites)

__all__ = ["grad_safe_barrier", "tree_bytes", "tree_count", "cast_tree",
           "ste", "is_weight_site", "weight_sites"]
