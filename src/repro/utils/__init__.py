from repro.utils.tree import tree_bytes, tree_count, cast_tree, ste

__all__ = ["tree_bytes", "tree_count", "cast_tree", "ste"]
