"""Shared kernel-backend selection policy.

One ladder for every Pallas/jnp dispatch layer (attention via
``REPRO_ATTN_IMPL``, wire codecs via ``REPRO_QUANT_IMPL``):

  1. explicit ``impl=`` keyword (parity tests / benchmarks);
  2. the per-subsystem environment variable (zero-code A/B flips);
  3. default: Pallas on TPU backends, the jnp reference elsewhere (the
     interpreter is exact but slow, so CPU CI stays on jnp unless a
     test opts in).
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax

DEFAULT_IMPLS = ("pallas", "jnp")


def resolve_backend_impl(impl: Optional[str], env_var: str, what: str,
                         valid: Tuple[str, ...] = DEFAULT_IMPLS) -> str:
    """Resolve ``impl`` through the kwarg -> env -> backend-default ladder."""
    if impl is None:
        impl = os.environ.get(env_var, "").lower() or None
    if impl is None:
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl not in valid:
        raise ValueError(
            f"unknown {what} impl {impl!r}; expected one of {valid}")
    return impl
