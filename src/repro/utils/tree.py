"""Small pytree / numeric helpers shared across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_bytes(tree) -> int:
    """Total bytes of all array leaves in a pytree."""
    return sum(
        x.size * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(tree)
        if hasattr(x, "dtype")
    )


def tree_count(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(
        x.size for x in jax.tree_util.tree_leaves(tree) if hasattr(x, "shape")
    )


def cast_tree(tree, dtype):
    """Cast every inexact array leaf to ``dtype`` (ints are left alone)."""
    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(_cast, tree)


def ste(x: jax.Array, x_hat: jax.Array) -> jax.Array:
    """Straight-through estimator.

    Forward value is ``x_hat``; the backward pass treats the
    quantize/dequantize round trip as identity (paper Eq. 1-3).
    """
    return x + jax.lax.stop_gradient(x_hat - x)
