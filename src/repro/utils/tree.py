"""Small pytree / numeric helpers shared across the framework."""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp

Path = Tuple[str, ...]


def tree_bytes(tree) -> int:
    """Total bytes of all array leaves in a pytree."""
    return sum(
        x.size * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(tree)
        if hasattr(x, "dtype")
    )


def tree_count(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(
        x.size for x in jax.tree_util.tree_leaves(tree) if hasattr(x, "shape")
    )


def cast_tree(tree, dtype):
    """Cast every inexact array leaf to ``dtype`` (ints are left alone)."""
    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(_cast, tree)


# ---------------------------------------------------------------------------
# structural weight-site selection (shared by repro.peft and repro.wq)
# ---------------------------------------------------------------------------

def key_name(entry) -> str:
    """Best-effort name of one path entry (DictKey / GetAttrKey / index)."""
    for attr in ("key", "name", "idx"):
        if hasattr(entry, attr):
            return str(getattr(entry, attr))
    return str(entry)


def is_weight_site(name: str, leaf) -> bool:
    """A projection weight: dict key ``w*`` with >= 2 dims.

    The single structural rule both ``repro.peft`` (LoRA adapter sites)
    and ``repro.wq`` (weight-only quantization sites) select by: the last
    two axes are read as ``(d_in, d_out)`` and anything in front (stage /
    layer / expert axes) is batch.  Covers GQA (``wq/wk/wv/wo``), MLA
    factored projections, SwiGLU (``w_gate/w_up/w_down``), RWKV channel
    mix and MoE expert banks, while skipping norm scales (``ln*``,
    ``q_norm``), the fp32 MoE ``router`` and biases.
    """
    return name.startswith("w") and getattr(leaf, "ndim", 0) >= 2


def weight_sites(tree) -> List[Tuple[Path, Any]]:
    """``(path, leaf)`` for every weight site in ``tree`` (stable order)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        names = tuple(key_name(p) for p in path)
        if names and is_weight_site(names[-1], leaf):
            out.append((names, leaf))
    return out


def ste(x: jax.Array, x_hat: jax.Array) -> jax.Array:
    """Straight-through estimator.

    Forward value is ``x_hat``; the backward pass treats the
    quantize/dequantize round trip as identity (paper Eq. 1-3).
    """
    return x + jax.lax.stop_gradient(x_hat - x)
