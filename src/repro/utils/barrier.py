"""Gradient-safe optimization barrier.

``jax.lax.optimization_barrier`` pins values in place so XLA cannot hoist
layer-invariant computation (the attention-mask tables built from
``positions``) out of the layer scan into layer-count-stacked buffers —
gigabytes per device on the dry-run shapes.  But it has no differentiation
rule (JAX 0.4.37 raises ``NotImplementedError`` the moment ``jax.grad``
traces through the stack), which killed every train path in the repo.

``grad_safe_barrier`` is a ``jax.custom_vjp`` wrapper that applies the
barrier to the primal AND to the cotangent, so the same hoisting
protection covers the backward scan: the transposed mask computation is
anchored inside the backward loop body exactly like the forward one.

Integer leaves (``positions``) get ``float0`` cotangents, which cannot be
lowered through an ``opt-barrier`` op — they pass through untouched.
"""
from __future__ import annotations

import jax
from jax.dtypes import float0


def _barrier_tree(tree):
    """optimization_barrier over a pytree, skipping empty/float0 leaves."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        return tree
    idx = [i for i, leaf in enumerate(leaves)
           if getattr(leaf, "dtype", None) != float0]
    if idx:
        pinned = jax.lax.optimization_barrier(
            tuple(leaves[i] for i in idx))
        for i, v in zip(idx, pinned):
            leaves[i] = v
    return jax.tree_util.tree_unflatten(treedef, leaves)


@jax.custom_vjp
def grad_safe_barrier(tree):
    """Differentiable ``optimization_barrier`` over an arbitrary pytree.

    Forward: identical to ``jax.lax.optimization_barrier(tree)``.
    Backward: the cotangent tree is itself pinned with a barrier, so XLA
    cannot hoist mask (or other layer-invariant) recomputation out of the
    backward layer scan either.
    """
    return _barrier_tree(tree)


def _fwd(tree):
    return _barrier_tree(tree), None


def _bwd(_res, ct):
    return (_barrier_tree(ct),)


grad_safe_barrier.defvjp(_fwd, _bwd)
