"""Batched serving demo: prefill + autoregressive decode against ring-
buffer KV caches, with the split compressor on the decode path.

Also demonstrates the sliding-window (long-context) serving mode and the
architecture zoo: pass any assigned arch id.

    PYTHONPATH=src python examples/serve_batched.py --arch llama3_2_3b
    PYTHONPATH=src python examples/serve_batched.py --arch rwkv6_7b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models import transformer as tf
from repro.serve.decode import generate, make_serve_step, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_3b", choices=list(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--window", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params = tf.init_params(key, cfg)
    cache_len = args.prompt_len + args.new_tokens \
        if args.window is None else args.window

    if cfg.modality == "vlm":
        batch = dict(
            image_embeds=jax.random.normal(
                key, (args.batch, cfg.n_image_tokens, cfg.d_vision)),
            tokens=jax.random.randint(key, (args.batch, args.prompt_len),
                                      0, cfg.vocab_size))
    elif cfg.modality == "audio":
        batch = dict(codes=jax.random.randint(
            key, (args.batch, cfg.n_codebooks, args.prompt_len), 0,
            cfg.vocab_size))
    else:
        batch = dict(tokens=jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size))

    t0 = time.perf_counter()
    logits, caches = prefill(params, cfg, batch, cache_len,
                             window=args.window)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"[{args.arch}] prefill({args.batch}x{args.prompt_len}) "
          f"in {t_prefill * 1e3:.1f} ms; cache_len={cache_len}")

    serve_step = jax.jit(make_serve_step(cfg, window=args.window))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    pos0 = args.prompt_len + (cfg.n_image_tokens
                              if cfg.modality == "vlm" else 0)
    times = []
    for i in range(args.new_tokens):
        qpos = jnp.full((args.batch,), pos0 + i, jnp.int32)
        if cfg.modality == "audio":
            step_batch = dict(codes=jnp.broadcast_to(
                tok[:, :, None][:, 0:1],
                (args.batch, cfg.n_codebooks, 1)).astype(jnp.int32))
        else:
            step_batch = dict(tokens=tok.reshape(args.batch, 1))
        t0 = time.perf_counter()
        logits, caches = serve_step(params, caches, step_batch, qpos)
        jax.block_until_ready(logits)
        times.append(time.perf_counter() - t0)
        last = logits[:, -1]
        if cfg.modality == "audio":
            last = last[:, 0]  # steer with codebook 0
        tok = jnp.argmax(last, -1).reshape(args.batch, -1)[:, :1]
    steady = sorted(times[1:])[len(times[1:]) // 2] if len(times) > 1 \
        else times[0]
    print(f"decoded {args.new_tokens} tokens; median step "
          f"{steady * 1e3:.2f} ms "
          f"({args.batch / steady:.1f} tok/s aggregate)")


if __name__ == "__main__":
    main()
