"""Batched serving demo: prefill + autoregressive decode against ring-
buffer KV caches, with the split compressor on the decode path.

Also demonstrates the sliding-window (long-context) serving mode and the
architecture zoo: pass any assigned arch id.

    PYTHONPATH=src python examples/serve_batched.py --arch llama3_2_3b
    PYTHONPATH=src python examples/serve_batched.py --arch rwkv6_7b

``--engine`` switches to the continuous-batching serving engine (paged
KV pool, slot scheduler, mid-flight admission/retirement); with a vlm
arch, ``--split-serve`` additionally ships the connector activations
over the quantized wire before the server streams tokens:

    PYTHONPATH=src python examples/serve_batched.py --engine
    PYTHONPATH=src python examples/serve_batched.py \
        --arch tinyllava --engine --split-serve
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models import transformer as tf
from repro.serve.decode import generate, make_serve_step, prefill
from repro.serve.engine import ServeEngine


def run_engine(cfg, params, args, key):
    rng = jax.random.split(key, 3)
    n_img = cfg.n_image_tokens if cfg.modality == "vlm" else 0
    page_size = 8
    max_target = n_img + args.prompt_len + args.new_tokens
    n_pages = 1 + args.batch * (-(-max_target // page_size))
    wq_calib = None
    if args.weight_quant:
        # small GPTQ calibration sample; without it the engine falls back
        # to round-to-nearest
        from repro.data.pipeline import make_pipeline
        wq_calib = next(make_pipeline(cfg, 4, 32))
    eng = ServeEngine(
        params, cfg, n_slots=max(2, args.batch // 2), page_size=page_size,
        n_pages=n_pages, window=args.window,
        split_wire=cfg.split.quant if args.split_serve else None,
        weight_quant=args.weight_quant, wq_calib=wq_calib)
    for i in range(args.batch):
        toks = jax.random.randint(jax.random.fold_in(rng[0], i),
                                  (args.prompt_len,), 0, cfg.vocab_size)
        img = None
        if cfg.modality == "vlm":
            img = jax.random.normal(jax.random.fold_in(rng[1], i),
                                    (cfg.n_image_tokens, cfg.d_vision))
        # staggered budgets: early retirements open slots for admissions
        eng.submit([int(t) for t in toks],
                   max_new=max(1, args.new_tokens - (i % 3) * 2),
                   image_embeds=img)
    t0 = time.perf_counter()
    results = eng.run()
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in results.values())
    print(f"[{args.arch}] engine: {len(results)} requests over "
          f"{eng.scheduler.n_slots} slots -> {total} tokens in "
          f"{dt * 1e3:.0f} ms ({total / dt:.1f} tok/s); "
          f"prefill_batches={eng.stats['prefill_batches']} "
          f"decode_ticks={eng.stats['decode_ticks']} "
          f"page_buckets={sorted(eng.stats['page_table_buckets'])}")
    if args.split_serve:
        print(f"  split-serve wire: {eng.stats['wire_bytes']} bytes of "
              f"quantized connector activations shipped")
    if args.weight_quant:
        d, p = eng.stats["weight_bytes_dense"], \
            eng.stats["weight_bytes_packed"]
        print(f"  {args.weight_quant} weights: {p} B packed vs {d} B "
              f"dense ({d / p:.2f}x smaller, GPTQ-calibrated)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_3b", choices=list(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--window", type=int, default=None)
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching ServeEngine instead of the "
                         "manual static loop")
    ap.add_argument("--split-serve", action="store_true",
                    help="(vlm archs, with --engine) ship connector "
                         "activations over the quantized wire")
    ap.add_argument("--weight-quant", default=None,
                    choices=("int4", "int3"),
                    help="(with --engine) serve from GPTQ-quantized "
                         "packed weights (repro.wq)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params = tf.init_params(key, cfg)
    if args.engine:
        if args.split_serve and cfg.modality != "vlm":
            ap.error("--split-serve needs a vlm arch (e.g. tinyllava)")
        run_engine(cfg, params, args, key)
        return
    if args.weight_quant:
        ap.error("--weight-quant needs --engine")
    cache_len = args.prompt_len + args.new_tokens \
        if args.window is None else args.window

    if cfg.modality == "vlm":
        batch = dict(
            image_embeds=jax.random.normal(
                key, (args.batch, cfg.n_image_tokens, cfg.d_vision)),
            tokens=jax.random.randint(key, (args.batch, args.prompt_len),
                                      0, cfg.vocab_size))
    elif cfg.modality == "audio":
        batch = dict(codes=jax.random.randint(
            key, (args.batch, cfg.n_codebooks, args.prompt_len), 0,
            cfg.vocab_size))
    else:
        batch = dict(tokens=jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size))

    t0 = time.perf_counter()
    logits, caches = prefill(params, cfg, batch, cache_len,
                             window=args.window)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"[{args.arch}] prefill({args.batch}x{args.prompt_len}) "
          f"in {t_prefill * 1e3:.1f} ms; cache_len={cache_len}")

    serve_step = jax.jit(make_serve_step(cfg, window=args.window))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    pos0 = args.prompt_len + (cfg.n_image_tokens
                              if cfg.modality == "vlm" else 0)
    times = []
    for i in range(args.new_tokens):
        qpos = jnp.full((args.batch,), pos0 + i, jnp.int32)
        if cfg.modality == "audio":
            step_batch = dict(codes=jnp.broadcast_to(
                tok[:, :, None][:, 0:1],
                (args.batch, cfg.n_codebooks, 1)).astype(jnp.int32))
        else:
            step_batch = dict(tokens=tok.reshape(args.batch, 1))
        t0 = time.perf_counter()
        logits, caches = serve_step(params, caches, step_batch, qpos)
        jax.block_until_ready(logits)
        times.append(time.perf_counter() - t0)
        last = logits[:, -1]
        if cfg.modality == "audio":
            last = last[:, 0]  # steer with codebook 0
        tok = jnp.argmax(last, -1).reshape(args.batch, -1)[:, :1]
    steady = sorted(times[1:])[len(times[1:]) // 2] if len(times) > 1 \
        else times[0]
    print(f"decoded {args.new_tokens} tokens; median step "
          f"{steady * 1e3:.2f} ms "
          f"({args.batch / steady:.1f} tok/s aggregate)")


if __name__ == "__main__":
    main()
