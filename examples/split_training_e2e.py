"""End-to-end driver: train a ~100M-parameter Quantized-TinyLLaVA for a
few hundred steps with the paper's full recipe (composite CE + alpha *
L_comm loss, 2-bit RD-FSQ compressor at the connector cut, warmup-cosine
AdamW, checkpointing).

Default arguments are sized for this CPU container (a ~15M model, 120
steps); on real hardware run the 100M configuration:

    PYTHONPATH=src python examples/split_training_e2e.py \
        --d-model 768 --layers 12 --steps 300 --batch 16

``--mode hub-async`` instead drives the refactored split stack
(stage programs / wire links / schedulers, ROADMAP item 2): N clients
with heterogeneous 2-bit/4-bit wire compressors and different tick
rates train their bottom halves against one shared server stage, the
server applying gradients per arrival (``launch/split_hub.train_hub``):

    PYTHONPATH=src python examples/split_training_e2e.py \
        --mode hub-async --clients 3 --steps 30

``--mode lora`` is the SplitLoRA variant (ROADMAP item 4): the same
async hub with base weights frozen, only rank-``--lora-rank`` adapters
training, and the gradient return shrunk to the quantized adapter-grad
payload; adapters land in an adapter-only checkpoint at the end:

    PYTHONPATH=src python examples/split_training_e2e.py \
        --mode lora --steps 80 --batch 8 --lr 1e-2 --lora-rank 8

(LoRA on a random-init base learns slowly by design — the adapters are
rank-bounded and the B factor starts at zero; the descent is gradual,
unlike the full fine-tune modes.)
"""
import argparse
import dataclasses

import jax.numpy as jnp

from repro import checkpoint
from repro.configs import get_config
from repro.core import HubConfig, QuantConfig, SplitConfig
from repro.data.pipeline import make_pipeline
from repro.launch.roofline import param_counts
from repro.optim import AdamWConfig
from repro.train.loop import train_loop


def build_cfg(d_model: int, layers: int, method: str, bits: int):
    base = get_config("tinyllava")
    heads = max(d_model // 64, 4)
    cfg = dataclasses.replace(
        base,
        n_layers=layers, d_model=d_model, n_heads=heads,
        n_kv_heads=max(heads // 4, 1), head_dim=64,
        d_ff=int(d_model * 8 / 3) // 64 * 64,
        vocab_size=8192, n_image_tokens=36, d_vision=256,
        d_connector=d_model,
        param_dtype="float32", compute_dtype="float32", remat=False,
        split=SplitConfig(cut_layer=0,
                          quant=QuantConfig(method=method, bits=bits),
                          learnable_codec=True),
    )
    return cfg


def run_e2e(cfg, args) -> None:
    """The paper's recipe: monolithic forward with the in-graph
    compressor roundtrip at the cut, composite loss, checkpointing."""
    data = make_pipeline(cfg, args.batch, args.seq, seed=0)
    state, history = train_loop(
        cfg, AdamWConfig(lr=args.lr), data, n_steps=args.steps,
        log_every=max(args.steps // 10, 1),
        callback=lambda i, m: print(
            f"  step {i:4d} loss={m['loss']:.4f} ce={m['ce']:.4f} "
            f"commit={m['commit']:.4f} lr={m['lr']:.2e}"))

    first, last = history[0][1]["ce"], history[-1][1]["ce"]
    print(f"CE {first:.4f} -> {last:.4f} "
          f"({(1 - last / first) * 100:.1f}% reduction)")
    checkpoint.save(args.ckpt, state)
    print("checkpoint:", args.ckpt)


def run_hub_async(cfg, args) -> None:
    """BEYOND-PAPER: the many-client hub on the refactored layers.

    Clients alternate 2-bit RD-FSQ / 4-bit NF wire compressors and tick
    at different rates; the shared server applies gradients per arrival
    (staleness-tolerant) and per-client codec calibration EMAs stay
    isolated.  Mesh-free (in-graph wire form) — the SPMD lockstep twin
    with real collective-permutes is ``launch/split_hub --smoke``.

    The hub schedules the LLM stack (embed + blocks + head), so the VLM
    config runs in text modality here — the split cut the hub exercises
    is the block-stack midpoint, not the paper's connector cut.
    """
    from repro.launch.split_hub import train_hub

    cfg = dataclasses.replace(cfg, modality="text")
    n = args.clients
    hub = HubConfig(
        n_clients=n,
        client_quants=tuple(
            QuantConfig(method="rdfsq", bits=2) if c % 2 == 0
            else QuantConfig(method="nf", bits=4) for c in range(n)),
        bwd_quant=QuantConfig(method=args.method, bits=args.bits),
        tick_rates=tuple(1 + c % 3 for c in range(n)))
    pipe = make_pipeline(cfg, n * args.batch, args.seq, seed=0)

    def batches():
        while True:
            b = next(pipe)
            yield (b["tokens"].reshape(n, args.batch, -1),
                   b["labels"].reshape(n, args.batch, -1))

    out = train_hub(cfg, hub, AdamWConfig(lr=args.lr), batches(),
                    micro_batch=args.batch, seq=args.seq, mode="async",
                    n_ticks=args.steps)
    hist = out["history"]
    for i in range(0, len(hist), max(len(hist) // 10, 1)):
        arrived = int(out["masks"][i].sum())
        print(f"  tick {i:4d} loss={hist[i]:.4f} arrivals={arrived}/{n}")
    print(f"hub loss {hist[0]:.4f} -> {hist[-1]:.4f} over {args.steps} "
          f"ticks; per-client wire rel err "
          + ", ".join(f"{v:.4f}" for v in out["quant_rel_err"]))


def run_lora(cfg, args) -> None:
    """SplitLoRA: parameter-efficient split fine-tuning on the async hub.

    Base weights stay bit-frozen; only the LoRA adapter factors train
    (optimizer moments sized by adapters), and the server's gradient
    return carries the 8-bit-quantized adapter-grad tree instead of full
    param-grads.  The adapters are saved alone at the end — the whole
    fine-tune fits in a checkpoint orders of magnitude smaller than the
    model.
    """
    from repro.launch.split_hub import train_hub
    from repro.optim import param_bytes
    from repro.peft import adapter_bytes

    cfg = dataclasses.replace(cfg, modality="text")
    n, r = args.clients, args.lora_rank
    hub = HubConfig(
        n_clients=n,
        client_quants=tuple(
            QuantConfig(method="rdfsq", bits=2) if c % 2 == 0
            else QuantConfig(method="nf", bits=4) for c in range(n)),
        grad_quant=QuantConfig(method="rdfsq", bits=8,
                               stats_axis="tensor"),
        tick_rates=tuple(1 + c % 2 for c in range(n)))
    pipe = make_pipeline(cfg, n * args.batch, args.seq, seed=0)

    def batches():
        while True:
            b = next(pipe)
            yield (b["tokens"].reshape(n, args.batch, -1),
                   b["labels"].reshape(n, args.batch, -1))

    out = train_hub(cfg, hub, AdamWConfig(lr=args.lr), batches(),
                    micro_batch=args.batch, seq=args.seq, mode="async",
                    n_ticks=args.steps, lora_rank=r)
    hist = out["history"]
    for i in range(0, len(hist), max(len(hist) // 10, 1)):
        print(f"  tick {i:4d} loss={hist[i]:.4f}")
    state = out["state"]
    adapters = dict(server=state["server"].params["adapters"],
                    clients=state["client_adapters"])
    full_b = param_bytes(state["client_params"]) \
        + param_bytes(state["server"].params["blocks"])
    ad_b = adapter_bytes(adapters)
    print(f"lora(r={r}) loss {hist[0]:.4f} -> {hist[-1]:.4f} over "
          f"{args.steps} ticks; adapters {ad_b / 1024:.0f} KiB vs frozen "
          f"base {full_b / 1024:.0f} KiB ({full_b / max(ad_b, 1):.0f}x)")
    checkpoint.save_adapters(args.ckpt, adapters)
    print("adapter checkpoint:", args.ckpt)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("e2e", "hub-async", "lora"),
                    default="e2e")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=48)
    ap.add_argument("--method", default="rdfsq")
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--lora-rank", type=int, default=4)
    ap.add_argument("--ckpt", default="/tmp/qtllava_e2e.npz")
    args = ap.parse_args()

    cfg = build_cfg(args.d_model, args.layers, args.method, args.bits)
    n = param_counts(cfg)["total"]
    print(f"training {cfg.name}: ~{n / 1e6:.1f}M params, "
          f"{args.method}-{args.bits}bit split compressor, "
          f"{args.steps} steps, mode={args.mode}")
    if args.mode == "hub-async":
        run_hub_async(cfg, args)
    elif args.mode == "lora":
        run_lora(cfg, args)
    else:
        run_e2e(cfg, args)


if __name__ == "__main__":
    main()
