"""End-to-end driver: train a ~100M-parameter Quantized-TinyLLaVA for a
few hundred steps with the paper's full recipe (composite CE + alpha *
L_comm loss, 2-bit RD-FSQ compressor at the connector cut, warmup-cosine
AdamW, checkpointing).

Default arguments are sized for this CPU container (a ~15M model, 120
steps); on real hardware run the 100M configuration:

    PYTHONPATH=src python examples/split_training_e2e.py \
        --d-model 768 --layers 12 --steps 300 --batch 16
"""
import argparse
import dataclasses

import jax.numpy as jnp

from repro import checkpoint
from repro.configs import get_config
from repro.core import QuantConfig, SplitConfig
from repro.data.pipeline import make_pipeline
from repro.launch.roofline import param_counts
from repro.optim import AdamWConfig
from repro.train.loop import train_loop


def build_cfg(d_model: int, layers: int, method: str, bits: int):
    base = get_config("tinyllava")
    heads = max(d_model // 64, 4)
    cfg = dataclasses.replace(
        base,
        n_layers=layers, d_model=d_model, n_heads=heads,
        n_kv_heads=max(heads // 4, 1), head_dim=64,
        d_ff=int(d_model * 8 / 3) // 64 * 64,
        vocab_size=8192, n_image_tokens=36, d_vision=256,
        d_connector=d_model,
        param_dtype="float32", compute_dtype="float32", remat=False,
        split=SplitConfig(cut_layer=0,
                          quant=QuantConfig(method=method, bits=bits),
                          learnable_codec=True),
    )
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=48)
    ap.add_argument("--method", default="rdfsq")
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/qtllava_e2e.npz")
    args = ap.parse_args()

    cfg = build_cfg(args.d_model, args.layers, args.method, args.bits)
    n = param_counts(cfg)["total"]
    print(f"training {cfg.name}: ~{n / 1e6:.1f}M params, "
          f"{args.method}-{args.bits}bit split compressor, "
          f"{args.steps} steps")

    data = make_pipeline(cfg, args.batch, args.seq, seed=0)
    state, history = train_loop(
        cfg, AdamWConfig(lr=args.lr), data, n_steps=args.steps,
        log_every=max(args.steps // 10, 1),
        callback=lambda i, m: print(
            f"  step {i:4d} loss={m['loss']:.4f} ce={m['ce']:.4f} "
            f"commit={m['commit']:.4f} lr={m['lr']:.2e}"))

    first, last = history[0][1]["ce"], history[-1][1]["ce"]
    print(f"CE {first:.4f} -> {last:.4f} "
          f"({(1 - last / first) * 100:.1f}% reduction)")
    checkpoint.save(args.ckpt, state)
    print("checkpoint:", args.ckpt)


if __name__ == "__main__":
    main()
