"""Feature-inversion privacy attack demo (paper Section 5, Figure 4/5).

Trains the convolutional inversion decoder against the wire features of
three deployments (original 16-bit, QLoRA-NF 2-bit, RD-FSQ 2-bit) and
reports the validation reconstruction losses — higher is more private.

    PYTHONPATH=src python examples/privacy_attack.py [--steps 150]
"""
import argparse

from benchmarks.fig4_attack import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()
    results = run(n_steps=args.steps)
    print("\nvalidation reconstruction loss (higher = more private):")
    for name, loss in sorted(results.items(), key=lambda kv: kv[1]):
        print(f"  {name:18s} {loss:.4f}")


if __name__ == "__main__":
    main()
