"""Quickstart: train Quantized-TinyLLaVA (reduced) with a 2-bit RD-FSQ
split compressor on the synthetic VQA task, evaluate, and generate.

    PYTHONPATH=src python examples/quickstart.py [--steps 100]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import make_pipeline
from repro.models import transformer as tf
from repro.optim import AdamWConfig
from repro.serve.decode import generate
from repro.train.loop import train_loop
from repro.train.losses import IGNORE


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    args = ap.parse_args()

    cfg = get_config("tinyllava").reduced()
    print(f"model: {cfg.name} (reduced) | split cut after connector | "
          f"compressor: {cfg.split.quant.method}-{cfg.split.quant.bits}bit")

    data = make_pipeline(cfg, batch_size=8, seq_len=32, seed=0)
    state, history = train_loop(
        cfg, AdamWConfig(lr=2e-3), data, n_steps=args.steps,
        log_every=max(args.steps // 5, 1),
        callback=lambda i, m: print(
            f"  step {i:4d} loss={m['loss']:.4f} ce={m['ce']:.4f} "
            f"commit={m['commit']:.4f}"))

    # eval answer accuracy on fresh data
    batch = {k: jnp.asarray(v) for k, v in
             next(make_pipeline(cfg, 16, 32, seed=99)).items()}
    logits, _ = tf.forward(state.params, cfg, batch)
    labels = batch["labels"]
    mask = labels != IGNORE
    acc = float((jnp.where(mask, jnp.argmax(logits, -1) == labels,
                           False)).sum() / mask.sum())
    print(f"answer-token accuracy: {acc:.3f}")

    # autoregressive generation through the quantized cut
    gen_batch = dict(
        image_embeds=batch["image_embeds"][:2],
        tokens=batch["tokens"][:2, :8],
    )
    out = generate(state.params, cfg, gen_batch, n_new=8, cache_len=64)
    print("generated token ids:", out.tolist())


if __name__ == "__main__":
    main()
